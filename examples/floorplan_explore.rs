//! Floorplan exploration (§4.2 / Figure 12): sweep the per-slot
//! utilization ceiling on the LLaMA2 design and print the congestion /
//! wirelength / frequency trade-off — the paper's "standalone RIR plugin
//! in 207 lines of Python", as a library call here.
//!
//! ```sh
//! cargo run --release --example floorplan_explore [-- device]
//! ```

use rsir::coordinator::explore;
use rsir::coordinator::flow::FlowConfig;
use rsir::device::builtin;
use rsir::util::bench::Table;
use rsir::util::pool::Pool;

fn main() -> anyhow::Result<()> {
    let device = std::env::args().nth(1).unwrap_or_else(|| "vhk158".into());
    let dev = builtin::by_name(&device)?;
    let g = rsir::designs::llama2::generate(&Default::default())?;
    let cfg = FlowConfig {
        sa_refine: true,
        ..Default::default()
    };
    // One pool job per sweep point (RSIR_WORKERS overrides the width).
    let pool = Pool::from_env(None);
    println!(
        "exploring {} floorplans of llama2 on {device} ({} workers)...",
        explore::default_limits().len(),
        pool.workers()
    );
    let rows = explore::explore(&g.design, &dev, &explore::default_limits(), &cfg, &pool)?;

    let mut t = Table::new(&["util_limit", "max_slot_util", "wirelength", "Fmax (MHz)"]);
    for r in &rows {
        t.row(&[
            format!("{:.2}", r.util_limit),
            if r.max_slot_util.is_nan() {
                "-".into()
            } else {
                format!("{:.2}", r.max_slot_util)
            },
            if r.wirelength.is_nan() {
                "-".into()
            } else {
                format!("{:.0}", r.wirelength)
            },
            if r.routable {
                format!("{:.0}", r.fmax_mhz)
            } else {
                "-".into()
            },
        ]);
    }
    t.print();
    match explore::tradeoff_correlation(&rows) {
        Some(corr) => println!(
            "util_limit vs wirelength correlation: {corr:.2} \
             (negative = packing tighter shortens wires, the Fig 12 trade-off)"
        ),
        None => println!("util_limit vs wirelength correlation: undefined (degenerate sweep)"),
    }
    let best = rows
        .iter()
        .filter(|r| r.routable)
        .max_by(|a, b| a.fmax_mhz.partial_cmp(&b.fmax_mhz).unwrap());
    if let Some(b) = best {
        println!(
            "best floorplan: util_limit {:.2} -> {:.0} MHz",
            b.util_limit, b.fmax_mhz
        );
    }
    Ok(())
}

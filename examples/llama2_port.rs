//! End-to-end driver (DESIGN.md deliverable): the paper's headline
//! experiment — port the hybrid-source LLaMA2 accelerator across six FPGA
//! platforms "without code modifications", reporting baseline vs RIR
//! frequency on each (Table 2's LLaMA2 block; §1 claims 30–62 % gains
//! and an average around 244 MHz).
//!
//! The full system composes here: Verilog import + pragmas + XCI IPs +
//! HLS reports (plugins) → hierarchy rebuild / inference / partition /
//! passthrough / flatten (passes) → ILP floorplan + batched SA through
//! the AOT-compiled Pallas kernel when artifacts exist (runtime) →
//! relay-station insertion (interconnect) → placement/STA (EDA backend).
//!
//! ```sh
//! make artifacts && cargo run --release --example llama2_port
//! ```

use rsir::coordinator::flow::{run_hlps, FlowConfig};
use rsir::designs::llama2::{self, Llama2Config};
use rsir::device::builtin;
use rsir::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let devices = ["vp1552", "vhk158", "u55c", "vu9p", "u250", "u280"];
    let have_artifacts =
        rsir::runtime::artifacts_dir().join("manifest.json").exists();
    println!(
        "floorplan scoring: {}",
        if have_artifacts {
            "PJRT (AOT Pallas kernel)"
        } else {
            "CPU oracle (run `make artifacts` for PJRT)"
        }
    );

    let mut t = Table::new(&[
        "Device",
        "Baseline (MHz)",
        "RIR (MHz)",
        "Gain",
        "Partitions",
        "Relays",
    ]);
    let mut gains = Vec::new();
    let mut rir_fmaxes = Vec::new();
    for device in devices {
        let dev = builtin::by_name(device)?;
        // Same design, no code modifications — only the target changes.
        let g = llama2::generate(&Llama2Config::default())?;
        let mut design = g.design;
        let cfg = FlowConfig {
            use_pjrt: have_artifacts,
            ..Default::default()
        };
        let report = run_hlps(&mut design, &dev, &cfg)?;
        let base = report.baseline_fmax();
        let rir = report.optimized.fmax_mhz();
        rir_fmaxes.push(rir);
        let gain = match base {
            Some(b) => {
                gains.push(100.0 * (rir - b) / b);
                format!("+{:.0}%", 100.0 * (rir - b) / b)
            }
            None => "+inf".to_string(),
        };
        t.row(&[
            device.to_string(),
            base.map(|b| format!("{b:.0}")).unwrap_or("-".into()),
            format!("{rir:.0}"),
            gain,
            report.partitions.to_string(),
            report.relay_stations.to_string(),
        ]);
    }
    t.print();
    println!(
        "average RIR frequency: {:.0} MHz (paper: 244 MHz avg for LLaMA2)",
        rir_fmaxes.iter().sum::<f64>() / rir_fmaxes.len() as f64
    );
    if !gains.is_empty() {
        println!(
            "average gain: +{:.0}% (paper: 30-62% per device)",
            gains.iter().sum::<f64>() / gains.len() as f64
        );
    }
    Ok(())
}

//! Custom virtual device (§3.1 Fig 7): define a new FPGA platform with
//! the builder API — "portability to user-customizable new FPGA
//! platforms" — and run the same design on it without touching any pass.
//!
//! ```sh
//! cargo run --release --example custom_device
//! ```

use rsir::coordinator::flow::{run_hlps, FlowConfig};
use rsir::device::DeviceBuilder;
use rsir::ir::core::Resources;

fn main() -> anyhow::Result<()> {
    // A hypothetical two-die research board: 2x3 slot grid, modest SLLs,
    // an HBM-like derate on the bottom edge (cf. the VP1552 definition in
    // Figure 7 of the paper).
    let dev = DeviceBuilder::new("labboard", "xclab1-demo")
        .grid(2, 3)
        .die_boundary_after_row(1)
        .uniform_slot_capacity(Resources::new(180e3, 360e3, 300.0, 1200.0, 120.0))
        .derate_slot(0, 0, 0.20)
        .derate_slot(1, 0, 0.20)
        .sll_per_column(9000)
        .wire_capacity(18_000, 18_000)
        .build()?;
    println!(
        "custom device '{}': {}x{} slots, {} dies, {:.0} kLUT total",
        dev.name,
        dev.cols,
        dev.rows,
        dev.num_dies(),
        dev.total_capacity().lut / 1000.0
    );
    // Serialize / reload the device description (the IR carries it).
    let j = dev.to_json();
    let dev2 = rsir::device::VirtualDevice::from_json(&j)?;
    assert_eq!(dev, dev2);
    println!("device JSON round-trip: ok ({} bytes)", j.dump().len());

    // Port the LLaMA2 accelerator to it — no analyzer or pass changes.
    let g = rsir::designs::llama2::generate(&Default::default())?;
    let mut design = g.design;
    let report = run_hlps(&mut design, &dev, &FlowConfig::default())?;
    match report.baseline_fmax() {
        Some(f) => println!("baseline:  {f:.0} MHz"),
        None => println!("baseline:  unroutable"),
    }
    println!(
        "optimized: {:.0} MHz ({} partitions, {} relay stations)",
        report.optimized.fmax_mhz(),
        report.partitions,
        report.relay_stations
    );
    Ok(())
}

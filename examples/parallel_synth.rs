//! Parallel synthesis (§4.3 / Figure 13): floorplan a CNN systolic array,
//! then synthesize the slot groups in parallel and compare wall time
//! against the monolithic flow.
//!
//! ```sh
//! cargo run --release --example parallel_synth [-- 13x8]
//! ```

use rsir::coordinator::flow::{run_hlps, FlowConfig};
use rsir::coordinator::parallel_synth;
use rsir::designs::cnn::{self, CnnConfig};
use rsir::device::builtin;
use rsir::eda::SynthTimeModel;

fn main() -> anyhow::Result<()> {
    let dims = std::env::args().nth(1).unwrap_or_else(|| "13x8".into());
    let (r, c) = dims.split_once('x').expect("dims like 13x8");
    let cfg = CnnConfig {
        rows: r.parse()?,
        cols: c.parse()?,
    };
    let dev = builtin::by_name("u250")?;
    println!("floorplanning cnn_{dims} on u250...");
    let g = cnn::generate(&cfg)?;
    let mut design = g.design;
    run_hlps(
        &mut design,
        &dev,
        &FlowConfig {
            sa_refine: false,
            ..Default::default()
        },
    )?;

    // The modeled scenario assumes an 8-job vendor farm (the paper ran
    // slot syntheses concurrently); the measured numbers use however many
    // cores this host actually has.
    let workers = 8usize.max(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    let rep = parallel_synth::run(&design, &dev, workers, &SynthTimeModel::default())?;
    println!("slot groups: {}", rep.groups.len());
    for (i, gres) in rep.groups.iter().enumerate() {
        println!("  group {i}: {:.0} kLUT, {:.0} DSP", gres.lut / 1000.0, gres.dsp);
    }
    println!(
        "modeled vendor wall time: monolithic {:.0} s, parallel {:.0} s -> {:.2}x speedup (paper avg: 2.49x)",
        rep.modeled_monolithic_s, rep.modeled_parallel_s, rep.modeled_speedup
    );
    println!(
        "measured surrogate-synthesis wall time: sequential {:?}, {}-thread parallel {:?}",
        rep.measured_sequential, rep.workers, rep.measured_parallel
    );
    Ok(())
}

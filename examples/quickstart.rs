//! Quickstart: import a small mixed Verilog design, run the full HLPS
//! flow on an Alveo U280, and print before/after frequency.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use rsir::coordinator::flow::{run_hlps, FlowConfig};
use rsir::device::builtin;
use rsir::ir::core::{Interface, Resources};
use rsir::plugins;

fn main() -> anyhow::Result<()> {
    // A producer -> consumer design, written as plain Verilog.
    // Interfaces come from pragma comments; resources are set explicitly
    // (standing in for an HLS report).
    let producer = r#"
module Producer (
  input  wire ap_clk, input wire ap_rst_n,
  output wire [63:0] o, output wire o_vld, input wire o_rdy
);
// pragma clock port=ap_clk
// pragma reset port=ap_rst_n active=low
// pragma handshake pattern=o{role} role.valid=_vld role.ready=_rdy role.data=.*
  reg [63:0] counter;
  always @(posedge ap_clk) if (o_rdy) counter <= counter + 1;
  assign o = counter;
  assign o_vld = 1'b1;
endmodule
"#;
    let consumer = r#"
module Consumer (
  input  wire ap_clk, input wire ap_rst_n,
  input  wire [63:0] i, input wire i_vld, output wire i_rdy
);
// pragma clock port=ap_clk
// pragma reset port=ap_rst_n active=low
// pragma handshake pattern=i{role} role.valid=_vld role.ready=_rdy role.data=.*
  reg [63:0] acc;
  always @(posedge ap_clk) if (i_vld) acc <= acc + i;
  assign i_rdy = 1'b1;
endmodule
"#;
    let filter = r#"
module Filter (
  input  wire ap_clk, input wire ap_rst_n,
  input  wire [63:0] i, input wire i_vld, output wire i_rdy,
  output wire [63:0] o, output wire o_vld, input wire o_rdy
);
// pragma clock port=ap_clk
// pragma reset port=ap_rst_n active=low
// pragma handshake pattern=i{role} role.valid=_vld role.ready=_rdy role.data=.*
// pragma handshake pattern=o{role} role.valid=_vld role.ready=_rdy role.data=.*
  assign o = i ^ 64'hA5A5;
  assign o_vld = i_vld;
  assign i_rdy = o_rdy;
endmodule
"#;
    let top = r#"
module QuickTop (input wire ap_clk, input wire ap_rst_n);
  wire [63:0] d; wire d_v; wire d_r;
  wire [63:0] e; wire e_v; wire e_r;
  Producer p (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
              .o(d), .o_vld(d_v), .o_rdy(d_r));
  Filter f (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
            .i(d), .i_vld(d_v), .i_rdy(d_r),
            .o(e), .o_vld(e_v), .o_rdy(e_r));
  Consumer c (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
              .i(e), .i_vld(e_v), .i_rdy(e_r));
endmodule
"#;

    // 1. Import (pragmas are applied automatically).
    let mut design = plugins::import_design("QuickTop", &[producer, filter, consumer, top])?;
    design.module_mut("QuickTop").unwrap().interfaces.extend([
        Interface::Clock {
            port: "ap_clk".into(),
        },
        Interface::Reset {
            port: "ap_rst_n".into(),
            active_high: false,
        },
    ]);
    // Pretend these are large kernels so the floorplanner has work to do.
    for (m, lut) in [
        ("Producer", 150_000.0),
        ("Filter", 150_000.0),
        ("Consumer", 150_000.0),
    ] {
        rsir::ir::builder::set_module_resources(
            design.module_mut(m).unwrap(),
            Resources::new(lut, lut, 64.0, 256.0, 16.0),
        );
        let mut t = rsir::util::json::JsonObj::new();
        t.insert("internal_ns", rsir::util::json::Json::num(3.0));
        design
            .module_mut(m)
            .unwrap()
            .metadata
            .insert("timing", rsir::util::json::Json::Obj(t));
    }

    // 2. Run the four-stage HLPS flow. Stages 1-2 execute the registered
    //    `analyze-structure` pass pipeline (`rsir passes` lists it along
    //    with every individual pass).
    let dev = builtin::by_name("u280")?;
    let report = run_hlps(&mut design, &dev, &FlowConfig::default())?;
    println!(
        "analysis pipeline ran {} passes: {}",
        report.analysis.passes.len(),
        report.analysis.pass_names().join(" -> ")
    );
    println!("{}", report.stats.render_passes());

    // 3. Results.
    match report.baseline_fmax() {
        Some(f) => println!("baseline (vendor-only):   {f:.0} MHz"),
        None => println!("baseline (vendor-only):   unroutable"),
    }
    println!(
        "RapidStream IR optimized: {:.0} MHz  ({} partitions, {} relay stations)",
        report.optimized.fmax_mhz(),
        report.partitions,
        report.relay_stations
    );

    // 4. Export the optimized design (Verilog + XDC floorplan).
    let bundle = plugins::export(&design)?;
    let out = std::path::Path::new("target/quickstart_out");
    bundle.write_to_dir(out)?;
    println!("exported {} files to {}", bundle.files.len(), out.display());
    Ok(())
}

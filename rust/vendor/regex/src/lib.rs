//! Minimal in-tree regex engine.
//!
//! Implements the subset of the `regex` crate API that rsir uses
//! (`Regex::new`, `is_match`, `captures`, named groups, `escape`) so the
//! repository builds without any external dependency. The engine is a
//! straightforward parse-to-AST, compile-to-bytecode, backtracking matcher.
//!
//! Supported syntax: literals, `\`-escapes (incl. `\d \D \w \W \s \S`),
//! `.`, `|`, `*`, `+`, `?` (each with a lazy `?` suffix), `{m}`/`{m,}`/
//! `{m,n}` counted repeats, `^`, `$`, `(...)`, `(?:...)`, `(?P<name>...)`,
//! and `[...]` classes with ranges and negation. A bare `{` that does not
//! start a valid counted repeat is a literal, matching the real crate's
//! lenient behaviour for patterns like `m_axi_{bundle}{role}` before
//! placeholder substitution.
//!
//! Backtracking is bounded by a step budget; pathological patterns fail to
//! match rather than hang.

use std::collections::HashMap;
use std::fmt;

/// Pattern compilation error.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "regex parse error: {}", self.msg)
    }
}

impl std::error::Error for Error {}

fn err<T>(msg: impl Into<String>) -> Result<T, Error> {
    Err(Error { msg: msg.into() })
}

/// Escape all regex metacharacters in `s` so it matches literally.
/// Word characters (`[A-Za-z0-9_]`) pass through unchanged; everything
/// else gets a backslash prefix (so `escape("{b}_{r}") == r"\{b\}_\{r\}"`).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() * 2);
    for c in s.chars() {
        if !(c.is_ascii_alphanumeric() || c == '_') {
            out.push('\\');
        }
        out.push(c);
    }
    out
}

// ---------------------------------------------------------------------------
// AST
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum ClassItem {
    Ch(char),
    Range(char, char),
    Digit,
    NotDigit,
    Word,
    NotWord,
    Space,
    NotSpace,
}

impl ClassItem {
    fn matches(&self, c: char) -> bool {
        match self {
            ClassItem::Ch(x) => c == *x,
            ClassItem::Range(a, b) => *a <= c && c <= *b,
            ClassItem::Digit => c.is_ascii_digit(),
            ClassItem::NotDigit => !c.is_ascii_digit(),
            ClassItem::Word => c.is_ascii_alphanumeric() || c == '_',
            ClassItem::NotWord => !(c.is_ascii_alphanumeric() || c == '_'),
            ClassItem::Space => c.is_whitespace(),
            ClassItem::NotSpace => !c.is_whitespace(),
        }
    }
}

#[derive(Debug, Clone)]
enum Ast {
    Empty,
    Char(char),
    Any,
    Start,
    End,
    Class { neg: bool, items: Vec<ClassItem> },
    Concat(Vec<Ast>),
    Alt(Vec<Ast>),
    Repeat { inner: Box<Ast>, min: u32, max: Option<u32>, greedy: bool },
    Group { slot: Option<usize>, inner: Box<Ast> },
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser {
    chars: Vec<char>,
    pos: usize,
    n_groups: usize,
    names: HashMap<String, usize>,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn eat(&mut self, c: char) -> bool {
        if self.peek() == Some(c) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn alt(&mut self) -> Result<Ast, Error> {
        let mut arms = vec![self.concat()?];
        while self.eat('|') {
            arms.push(self.concat()?);
        }
        if arms.len() == 1 {
            Ok(arms.pop().unwrap())
        } else {
            Ok(Ast::Alt(arms))
        }
    }

    fn concat(&mut self) -> Result<Ast, Error> {
        let mut seq = Vec::new();
        while let Some(c) = self.peek() {
            if c == '|' || c == ')' {
                break;
            }
            seq.push(self.repeat()?);
        }
        Ok(match seq.len() {
            0 => Ast::Empty,
            1 => seq.pop().unwrap(),
            _ => Ast::Concat(seq),
        })
    }

    fn repeat(&mut self) -> Result<Ast, Error> {
        let mut node = self.atom()?;
        loop {
            let (min, max) = match self.peek() {
                Some('*') => {
                    self.pos += 1;
                    (0, None)
                }
                Some('+') => {
                    self.pos += 1;
                    (1, None)
                }
                Some('?') => {
                    self.pos += 1;
                    (0, Some(1))
                }
                Some('{') => match self.counted_repeat() {
                    Some(r) => r,
                    None => break, // literal `{`, handled by the next atom()
                },
                _ => break,
            };
            if matches!(node, Ast::Start | Ast::End | Ast::Empty) {
                return err("repetition operator applied to an anchor");
            }
            let greedy = !self.eat('?');
            node = Ast::Repeat { inner: Box::new(node), min, max, greedy };
        }
        Ok(node)
    }

    /// Try to parse `{m}`, `{m,}` or `{m,n}` at the current `{`. Returns
    /// `None` (without consuming) when the braces are not a valid counted
    /// repeat, so the `{` falls through as a literal character.
    fn counted_repeat(&mut self) -> Option<(u32, Option<u32>)> {
        let save = self.pos;
        self.pos += 1; // `{`
        let mut num = |p: &mut Self| -> Option<u32> {
            let start = p.pos;
            while matches!(p.peek(), Some(c) if c.is_ascii_digit()) {
                p.pos += 1;
            }
            if p.pos == start {
                return None;
            }
            p.chars[start..p.pos].iter().collect::<String>().parse().ok()
        };
        let min = match num(self) {
            Some(m) if m <= 1000 => m,
            _ => {
                self.pos = save;
                return None;
            }
        };
        let max = if self.eat(',') {
            match self.peek() {
                Some('}') => None,
                _ => match num(self) {
                    Some(m) if m >= min && m <= 1000 => Some(m),
                    _ => {
                        self.pos = save;
                        return None;
                    }
                },
            }
        } else {
            Some(min)
        };
        if !self.eat('}') {
            self.pos = save;
            return None;
        }
        Some((min, max))
    }

    fn atom(&mut self) -> Result<Ast, Error> {
        match self.bump() {
            None => err("unexpected end of pattern"),
            Some('(') => self.group(),
            Some(')') => err("unmatched `)`"),
            Some('[') => self.class(),
            Some(']') => Ok(Ast::Char(']')),
            Some('.') => Ok(Ast::Any),
            Some('^') => Ok(Ast::Start),
            Some('$') => Ok(Ast::End),
            Some('*') | Some('+') => err("repetition operator with nothing to repeat"),
            Some('?') => err("`?` with nothing to repeat"),
            Some('\\') => self.escape_atom(),
            Some(c) => Ok(Ast::Char(c)),
        }
    }

    fn group(&mut self) -> Result<Ast, Error> {
        let mut slot = None;
        if self.eat('?') {
            if self.eat(':') {
                // non-capturing
            } else if self.eat('P') || self.peek() == Some('<') {
                if !self.eat('<') {
                    return err("expected `<` after `(?P`");
                }
                let mut name = String::new();
                loop {
                    match self.bump() {
                        Some('>') => break,
                        Some(c) if c.is_ascii_alphanumeric() || c == '_' => name.push(c),
                        Some(c) => return err(format!("bad character `{c}` in group name")),
                        None => return err("unterminated group name"),
                    }
                }
                if name.is_empty() {
                    return err("empty group name");
                }
                self.n_groups += 1;
                let idx = self.n_groups;
                if self.names.insert(name.clone(), idx).is_some() {
                    return err(format!("duplicate group name `{name}`"));
                }
                slot = Some(idx);
            } else {
                return err("unsupported group modifier after `(?`");
            }
        } else {
            self.n_groups += 1;
            slot = Some(self.n_groups);
        }
        let inner = self.alt()?;
        if !self.eat(')') {
            return err("unclosed group");
        }
        Ok(Ast::Group { slot, inner: Box::new(inner) })
    }

    fn class(&mut self) -> Result<Ast, Error> {
        let neg = self.eat('^');
        let mut items = Vec::new();
        if self.eat(']') {
            items.push(ClassItem::Ch(']'));
        }
        loop {
            let c = match self.bump() {
                None => return err("unterminated character class"),
                Some(']') => break,
                Some('\\') => match self.bump() {
                    None => return err("trailing backslash in class"),
                    Some('d') => {
                        items.push(ClassItem::Digit);
                        continue;
                    }
                    Some('D') => {
                        items.push(ClassItem::NotDigit);
                        continue;
                    }
                    Some('w') => {
                        items.push(ClassItem::Word);
                        continue;
                    }
                    Some('W') => {
                        items.push(ClassItem::NotWord);
                        continue;
                    }
                    Some('s') => {
                        items.push(ClassItem::Space);
                        continue;
                    }
                    Some('S') => {
                        items.push(ClassItem::NotSpace);
                        continue;
                    }
                    Some('n') => '\n',
                    Some('t') => '\t',
                    Some('r') => '\r',
                    Some(c) => c,
                },
                Some(c) => c,
            };
            if self.peek() == Some('-') && self.chars.get(self.pos + 1) != Some(&']') {
                self.pos += 1; // `-`
                let hi = match self.bump() {
                    None => return err("unterminated character class"),
                    Some('\\') => match self.bump() {
                        Some('n') => '\n',
                        Some('t') => '\t',
                        Some('r') => '\r',
                        Some(c) => c,
                        None => return err("trailing backslash in class"),
                    },
                    Some(c) => c,
                };
                if hi < c {
                    return err(format!("invalid class range `{c}-{hi}`"));
                }
                items.push(ClassItem::Range(c, hi));
            } else {
                items.push(ClassItem::Ch(c));
            }
        }
        Ok(Ast::Class { neg, items })
    }

    fn escape_atom(&mut self) -> Result<Ast, Error> {
        match self.bump() {
            None => err("trailing backslash"),
            Some('d') => Ok(Ast::Class { neg: false, items: vec![ClassItem::Digit] }),
            Some('D') => Ok(Ast::Class { neg: false, items: vec![ClassItem::NotDigit] }),
            Some('w') => Ok(Ast::Class { neg: false, items: vec![ClassItem::Word] }),
            Some('W') => Ok(Ast::Class { neg: false, items: vec![ClassItem::NotWord] }),
            Some('s') => Ok(Ast::Class { neg: false, items: vec![ClassItem::Space] }),
            Some('S') => Ok(Ast::Class { neg: false, items: vec![ClassItem::NotSpace] }),
            Some('n') => Ok(Ast::Char('\n')),
            Some('t') => Ok(Ast::Char('\t')),
            Some('r') => Ok(Ast::Char('\r')),
            Some('0') => Ok(Ast::Char('\0')),
            Some(c) if c.is_ascii_alphanumeric() => {
                err(format!("unsupported escape sequence `\\{c}`"))
            }
            Some(c) => Ok(Ast::Char(c)),
        }
    }
}

// ---------------------------------------------------------------------------
// Compiler (AST -> backtracking bytecode)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum Inst {
    Char(char),
    Any,
    Class { neg: bool, items: Vec<ClassItem> },
    Start,
    End,
    Save(usize),
    /// Try the first target before the second.
    Split(usize, usize),
    Jump(usize),
    Match,
}

fn compile(ast: &Ast, prog: &mut Vec<Inst>) {
    match ast {
        Ast::Empty => {}
        Ast::Char(c) => prog.push(Inst::Char(*c)),
        Ast::Any => prog.push(Inst::Any),
        Ast::Start => prog.push(Inst::Start),
        Ast::End => prog.push(Inst::End),
        Ast::Class { neg, items } => {
            prog.push(Inst::Class { neg: *neg, items: items.clone() })
        }
        Ast::Concat(seq) => {
            for a in seq {
                compile(a, prog);
            }
        }
        Ast::Alt(arms) => {
            // split a1, (split a2, (... an)), each arm jumps to the common end
            let mut jump_fixups = Vec::new();
            let mut split_fixups = Vec::new();
            for (k, arm) in arms.iter().enumerate() {
                if k + 1 < arms.len() {
                    let sp = prog.len();
                    prog.push(Inst::Split(sp + 1, 0)); // second target patched
                    split_fixups.push(sp);
                }
                compile(arm, prog);
                if k + 1 < arms.len() {
                    let jp = prog.len();
                    prog.push(Inst::Jump(0)); // patched to end
                    jump_fixups.push(jp);
                    let next = prog.len();
                    if let Inst::Split(_, b) = &mut prog[split_fixups[k]] {
                        *b = next;
                    }
                }
            }
            let end = prog.len();
            for jp in jump_fixups {
                if let Inst::Jump(t) = &mut prog[jp] {
                    *t = end;
                }
            }
        }
        Ast::Repeat { inner, min, max, greedy } => {
            for _ in 0..*min {
                compile(inner, prog);
            }
            match max {
                None => {
                    // star loop over the remaining (unbounded) part
                    let l1 = prog.len();
                    prog.push(Inst::Split(0, 0)); // patched below
                    let body = prog.len();
                    compile(inner, prog);
                    prog.push(Inst::Jump(l1));
                    let out = prog.len();
                    prog[l1] = if *greedy {
                        Inst::Split(body, out)
                    } else {
                        Inst::Split(out, body)
                    };
                }
                Some(max) => {
                    // (max - min) nested optionals; failing out of any one
                    // jumps straight past the rest.
                    let mut fixups = Vec::new();
                    for _ in *min..*max {
                        let sp = prog.len();
                        prog.push(Inst::Split(0, 0));
                        fixups.push(sp);
                        compile(inner, prog);
                    }
                    let out = prog.len();
                    for sp in fixups {
                        let body = sp + 1;
                        prog[sp] = if *greedy {
                            Inst::Split(body, out)
                        } else {
                            Inst::Split(out, body)
                        };
                    }
                }
            }
        }
        Ast::Group { slot, inner } => {
            if let Some(i) = slot {
                prog.push(Inst::Save(2 * i));
                compile(inner, prog);
                prog.push(Inst::Save(2 * i + 1));
            } else {
                compile(inner, prog);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

/// Backtracking step budget: generous for the small patterns rsir compiles,
/// but bounds pathological blowup (the engine then reports "no match").
const STEP_LIMIT: usize = 1_000_000;

struct Input<'t> {
    /// (byte offset, char) for each char of the haystack.
    chars: Vec<(usize, char)>,
    /// Total byte length of the haystack.
    len: usize,
}

impl<'t> Input<'t> {
    fn new(text: &'t str) -> Self {
        Input { chars: text.char_indices().collect(), len: text.len() }
    }

    fn byte_at(&self, sp: usize) -> usize {
        self.chars.get(sp).map(|(b, _)| *b).unwrap_or(self.len)
    }
}

fn exec(
    prog: &[Inst],
    input: &Input,
    mut pc: usize,
    mut sp: usize,
    saves: &mut Vec<Option<usize>>,
    steps: &mut usize,
) -> bool {
    loop {
        *steps += 1;
        if *steps > STEP_LIMIT {
            return false;
        }
        match &prog[pc] {
            Inst::Char(c) => {
                if sp < input.chars.len() && input.chars[sp].1 == *c {
                    sp += 1;
                    pc += 1;
                } else {
                    return false;
                }
            }
            Inst::Any => {
                if sp < input.chars.len() && input.chars[sp].1 != '\n' {
                    sp += 1;
                    pc += 1;
                } else {
                    return false;
                }
            }
            Inst::Class { neg, items } => {
                if sp < input.chars.len() {
                    let c = input.chars[sp].1;
                    let hit = items.iter().any(|it| it.matches(c));
                    if hit != *neg {
                        sp += 1;
                        pc += 1;
                        continue;
                    }
                }
                return false;
            }
            Inst::Start => {
                if sp == 0 {
                    pc += 1;
                } else {
                    return false;
                }
            }
            Inst::End => {
                if sp == input.chars.len() {
                    pc += 1;
                } else {
                    return false;
                }
            }
            Inst::Save(i) => {
                if saves.len() <= *i {
                    saves.resize(*i + 1, None);
                }
                saves[*i] = Some(input.byte_at(sp));
                pc += 1;
            }
            Inst::Split(a, b) => {
                let snapshot = saves.clone();
                if exec(prog, input, *a, sp, saves, steps) {
                    return true;
                }
                *saves = snapshot;
                pc = *b;
            }
            Inst::Jump(t) => pc = *t,
            Inst::Match => return true,
        }
    }
}

// ---------------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------------

/// A compiled regular expression.
#[derive(Debug, Clone)]
pub struct Regex {
    pattern: String,
    prog: Vec<Inst>,
    n_groups: usize,
    names: HashMap<String, usize>,
}

impl Regex {
    pub fn new(pattern: &str) -> Result<Regex, Error> {
        let mut p = Parser {
            chars: pattern.chars().collect(),
            pos: 0,
            n_groups: 0,
            names: HashMap::new(),
        };
        let ast = p.alt()?;
        if p.pos != p.chars.len() {
            // the only way alt() stops early is an unmatched `)`
            return err("unmatched `)`");
        }
        let mut prog = vec![Inst::Save(0)];
        compile(&ast, &mut prog);
        prog.push(Inst::Save(1));
        prog.push(Inst::Match);
        Ok(Regex { pattern: pattern.to_string(), prog, n_groups: p.n_groups, names: p.names })
    }

    pub fn as_str(&self) -> &str {
        &self.pattern
    }

    fn exec_at<'t>(&self, input: &Input<'t>, start: usize) -> Option<Vec<Option<usize>>> {
        let mut saves = vec![None; 2 * (self.n_groups + 1)];
        let mut steps = 0usize;
        if exec(&self.prog, input, 0, start, &mut saves, &mut steps) {
            Some(saves)
        } else {
            None
        }
    }

    pub fn is_match(&self, text: &str) -> bool {
        let input = Input::new(text);
        (0..=input.chars.len()).any(|s| self.exec_at(&input, s).is_some())
    }

    /// Leftmost match with capture groups, or `None`.
    pub fn captures<'t>(&self, text: &'t str) -> Option<Captures<'t>> {
        let input = Input::new(text);
        for s in 0..=input.chars.len() {
            if let Some(saves) = self.exec_at(&input, s) {
                return Some(Captures { text, saves, names: self.names.clone() });
            }
        }
        None
    }

    /// Leftmost whole-pattern match, or `None`.
    pub fn find<'t>(&self, text: &'t str) -> Option<Match<'t>> {
        self.captures(text).and_then(|c| c.get(0))
    }
}

/// Capture groups of a single match. Group 0 is the whole match.
pub struct Captures<'t> {
    text: &'t str,
    saves: Vec<Option<usize>>,
    names: HashMap<String, usize>,
}

impl<'t> Captures<'t> {
    pub fn get(&self, i: usize) -> Option<Match<'t>> {
        let start = *self.saves.get(2 * i)?;
        let end = *self.saves.get(2 * i + 1)?;
        match (start, end) {
            (Some(s), Some(e)) if s <= e => {
                Some(Match { text: &self.text[s..e], start: s, end: e })
            }
            _ => None,
        }
    }

    pub fn name(&self, name: &str) -> Option<Match<'t>> {
        self.get(*self.names.get(name)?)
    }
}

/// A single matched region of the haystack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Match<'t> {
    text: &'t str,
    start: usize,
    end: usize,
}

impl<'t> Match<'t> {
    pub fn as_str(&self) -> &'t str {
        self.text
    }

    pub fn start(&self) -> usize {
        self.start
    }

    pub fn end(&self) -> usize {
        self.end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_and_alternation() {
        let re = Regex::new("^(?:clk|clock)$").unwrap();
        assert!(re.is_match("clk"));
        assert!(re.is_match("clock"));
        assert!(!re.is_match("clk2"));
        assert!(!re.is_match("aclk"));
    }

    #[test]
    fn bad_patterns_error() {
        assert!(Regex::new("(").is_err());
        assert!(Regex::new(")").is_err());
        assert!(Regex::new("a)b").is_err());
        assert!(Regex::new("[abc").is_err());
        assert!(Regex::new("*a").is_err());
        assert!(Regex::new("(?P<x").is_err());
        assert!(Regex::new("(?=look)").is_err());
    }

    #[test]
    fn escape_matches_real_crate_shape() {
        assert_eq!(escape("{bundle}_{role}"), r"\{bundle\}_\{role\}");
        assert_eq!(escape("m_axi_x"), "m_axi_x");
        assert_eq!(escape("a.b*c"), r"a\.b\*c");
    }

    #[test]
    fn named_lazy_groups_like_iface_rules() {
        // The exact shape apply_handshake_pattern builds after substitution.
        let re =
            Regex::new(r"^m_axi_(?P<bundle>.*?)(?P<role>(?:AWVALID|WVALID|ARVALID))$").unwrap();
        let c = re.captures("m_axi_gmem0AWVALID").unwrap();
        assert_eq!(c.name("bundle").unwrap().as_str(), "gmem0");
        assert_eq!(c.name("role").unwrap().as_str(), "AWVALID");
        assert!(re.captures("m_axi_gmem0BOGUS").is_none());
    }

    #[test]
    fn lazy_vs_greedy() {
        let re = Regex::new("^(?P<b>.*?)(?P<r>_vld|_rdy|)$").unwrap();
        let c = re.captures("b0_vld").unwrap();
        assert_eq!(c.name("b").unwrap().as_str(), "b0");
        assert_eq!(c.name("r").unwrap().as_str(), "_vld");
        let g = Regex::new("^(?P<b>.*)(?P<r>_vld|)$").unwrap();
        let c = g.captures("b0_vld").unwrap();
        // greedy .* swallows everything; the empty alternative then matches
        assert_eq!(c.name("b").unwrap().as_str(), "b0_vld");
    }

    #[test]
    fn escaped_braces_are_literal() {
        let re = Regex::new(r"^\{bundle\}_\{role\}$").unwrap();
        assert!(re.is_match("{bundle}_{role}"));
        // bare braces that are not counted repeats stay literal
        let re2 = Regex::new("^a{bundle}$").unwrap();
        assert!(re2.is_match("a{bundle}"));
    }

    #[test]
    fn counted_repeats() {
        let re = Regex::new("^a{2,3}$").unwrap();
        assert!(!re.is_match("a"));
        assert!(re.is_match("aa"));
        assert!(re.is_match("aaa"));
        assert!(!re.is_match("aaaa"));
        let re = Regex::new(r"^\d{2}$").unwrap();
        assert!(re.is_match("42"));
        assert!(!re.is_match("4"));
    }

    #[test]
    fn classes_and_predefined() {
        let re = Regex::new("^[a-z_][a-z0-9_]*$").unwrap();
        assert!(re.is_match("ap_clk"));
        assert!(!re.is_match("0bad"));
        let re = Regex::new(r"^\w+$").unwrap();
        assert!(re.is_match("wide_word_7"));
        assert!(!re.is_match("no space"));
        let re = Regex::new("^[^0-9]+$").unwrap();
        assert!(re.is_match("abc"));
        assert!(!re.is_match("a1"));
    }

    #[test]
    fn unanchored_search_finds_leftmost() {
        let re = Regex::new("b+").unwrap();
        let m = re.find("aabbbcc").unwrap();
        assert_eq!(m.as_str(), "bbb");
        assert_eq!((m.start(), m.end()), (2, 5));
    }

    #[test]
    fn plain_star_and_dot() {
        let re = Regex::new("^scalar_.*$").unwrap();
        assert!(re.is_match("scalar_in0"));
        assert!(!re.is_match("vector_in0"));
        let re = Regex::new("^.*_mc$").unwrap();
        assert!(re.is_match("leaf0_mc"));
        assert!(!re.is_match("leaf0"));
    }

    #[test]
    fn dot_does_not_match_newline() {
        let re = Regex::new("^a.b$").unwrap();
        assert!(re.is_match("axb"));
        assert!(!re.is_match("a\nb"));
    }

    #[test]
    fn unnamed_groups_capture() {
        let re = Regex::new("^(in|out)(\\d+)$").unwrap();
        let c = re.captures("in42").unwrap();
        assert_eq!(c.get(1).unwrap().as_str(), "in");
        assert_eq!(c.get(2).unwrap().as_str(), "42");
        assert_eq!(c.get(0).unwrap().as_str(), "in42");
    }
}

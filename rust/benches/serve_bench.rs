//! Bench: the `rsir serve` warm-cache path — a cold `flow` job against a
//! freshly booted daemon vs an identical resubmit on the same (now warm)
//! daemon, where the result memo answers without recompiling. Every
//! response is also checked byte-identical to the one-shot
//! `run_batch_local` lane, so the speedup being measured is provably
//! "same bytes, less work".
//!
//! `--smoke` shrinks the design and run count for CI; `--out FILE` writes
//! the stats as JSON (uploaded as the `BENCH_serve.json` CI artifact).
//! CI asserts the warm resubmit is at least 2x faster than the cold run.

use std::thread;
use std::time::{Duration, Instant};

use rsir::server::client::{run_batch_local, run_batch_remote};
use rsir::server::{scratch_socket, Bind, ServeConfig, Server};
use rsir::util::bench::fmt_dur;
use rsir::util::json::{Json, JsonObj};

fn median(mut v: Vec<Duration>) -> Duration {
    v.sort();
    v[v.len() / 2]
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let bench_name = if smoke { "cnn:6x4" } else { "cnn:13x8" };
    let runs = if smoke { 3 } else { 5 };
    let cold_line = format!(
        r#"{{"id":"c","type":"flow","params":{{"bench":"{bench_name}","device":"u250","sa_refine":false,"seed":7}}}}"#
    );
    // Identical params, different id: a result-memo hit on a warm daemon.
    let warm_line = cold_line.replacen(r#""id":"c""#, r#""id":"w""#, 1);
    let timeout = Duration::from_secs(600);

    // The one-shot lane's verdict on the same two requests — the byte
    // baseline every daemon response must match exactly.
    let local = run_batch_local(&[cold_line.clone(), warm_line.clone()]);
    assert!(local[0].contains(r#""ok":true"#), "{}", local[0]);

    println!("== rsir serve warm-cache path ({bench_name}, {runs} cold/warm pairs) ==");
    let (mut cold_times, mut warm_times) = (Vec::new(), Vec::new());
    for run in 0..runs {
        // A fresh daemon per run keeps the cold measurement honest: no
        // cache state survives from the previous pair.
        let mut cfg = ServeConfig::new(Bind::Unix(scratch_socket("bench")));
        cfg.workers = 2;
        cfg.quiet = true;
        let server = Server::bind(cfg).unwrap();
        let endpoint = server.endpoint();
        let handle = thread::spawn(move || server.run());

        let t0 = Instant::now();
        let cold = run_batch_remote(&endpoint, &[cold_line.clone()], timeout).unwrap();
        let cold_t = t0.elapsed();
        let t1 = Instant::now();
        let warm = run_batch_remote(&endpoint, &[warm_line.clone()], timeout).unwrap();
        let warm_t = t1.elapsed();

        assert_eq!(cold[0], local[0], "cold daemon response drifted from one-shot");
        assert_eq!(warm[0], local[1], "warm daemon response drifted from one-shot");

        let ack = run_batch_remote(
            &endpoint,
            &[r#"{"id":"q","type":"shutdown"}"#.to_string()],
            Duration::from_secs(30),
        )
        .unwrap();
        assert!(ack[0].contains("shutting_down"), "{}", ack[0]);
        handle.join().unwrap().unwrap();

        println!(
            "run {run}: cold={:>10} warm={:>10}",
            fmt_dur(cold_t),
            fmt_dur(warm_t)
        );
        cold_times.push(cold_t);
        warm_times.push(warm_t);
    }

    let cold_med = median(cold_times);
    let warm_med = median(warm_times);
    let speedup = cold_med.as_secs_f64() / warm_med.as_secs_f64().max(1e-12);
    println!(
        "cold median={} warm median={} speedup={speedup:.1}x",
        fmt_dur(cold_med),
        fmt_dur(warm_med)
    );

    if let Some(path) = &out {
        let mut o = JsonObj::new();
        o.insert("bench", Json::str("serve"));
        o.insert("design", Json::str(bench_name));
        o.insert("runs", Json::num(runs as f64));
        o.insert("smoke", Json::Bool(smoke));
        o.insert("cold_median_ns", Json::num(cold_med.as_nanos() as f64));
        o.insert("warm_median_ns", Json::num(warm_med.as_nanos() as f64));
        o.insert("speedup", Json::num(speedup));
        o.insert("byte_identical", Json::Bool(true));
        std::fs::write(path, Json::Obj(o).pretty()).unwrap();
        println!("wrote {path}");
    }
    assert!(
        speedup >= 2.0,
        "warm resubmit must beat the cold run >=2x (got {speedup:.2}x)"
    );
    println!("\nserve bench complete");
}

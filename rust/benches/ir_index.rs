//! Bench: the `ir_index` hot path — cached connectivity queries through
//! `DesignIndex` vs the legacy per-pass `BlockGraph` rebuild loop, on the
//! largest built-in design (CNN 13x12, analyzed down to its flat top —
//! the shape every post-analysis pass queries).
//!
//! `--smoke` shrinks the iteration counts for CI; `--out FILE` writes the
//! stats as JSON (uploaded as the `BENCH_ir_index.json` CI artifact to
//! track the perf trajectory).

use rsir::coordinator::flow;
use rsir::ir::core::{ConnExpr, Module};
use rsir::ir::graph::{BlockGraph, Endpoint, NetInfo};
use rsir::ir::index::DesignIndex;
use rsir::passes::PassContext;
use rsir::util::bench::bench;
use rsir::util::json::{Json, JsonObj};
use std::collections::BTreeMap;

/// The pre-refactor string-keyed `BlockGraph::build`, kept verbatim as
/// the baseline (the in-tree `build` is now a view over `ModuleConn`, so
/// timing it would charge the baseline for interning it never did —
/// same reference implementation as tests/ir_index.rs).
fn legacy_block_graph(m: &Module) -> BlockGraph {
    let mut nets: BTreeMap<String, NetInfo> = BTreeMap::new();
    for w in m.wires() {
        nets.entry(w.name.clone()).or_default().width = w.width;
    }
    for p in &m.ports {
        let e = nets.entry(p.name.clone()).or_default();
        e.width = p.width;
        e.endpoints.push(Endpoint::Parent {
            port: p.name.clone(),
        });
    }
    let mut instances = Vec::new();
    for inst in m.instances() {
        instances.push(inst.instance_name.clone());
        for conn in &inst.connections {
            if let ConnExpr::Id(id) = &conn.value {
                nets.entry(id.clone()).or_default().endpoints.push(Endpoint::Inst {
                    inst: inst.instance_name.clone(),
                    port: conn.port.clone(),
                });
            }
        }
    }
    BlockGraph { nets, instances }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let g = rsir::designs::cnn::generate(&rsir::designs::cnn::CnnConfig { rows: 13, cols: 12 })
        .unwrap();
    let mut d = g.design;
    let mut ctx = PassContext::new();
    ctx.drc_after_each = false;
    flow::analyze_structure(&mut d, &mut ctx).unwrap();
    let grouped: Vec<String> = d
        .modules
        .values()
        .filter(|m| m.is_grouped())
        .map(|m| m.name.clone())
        .collect();
    let queries = if smoke { 50 } else { 1000 };
    let runs = if smoke { 3 } else { 7 };
    println!(
        "== ir_index hot path (cnn 13x12 analyzed: {} grouped modules, {queries} query rounds) ==",
        grouped.len()
    );

    // Legacy: what DRC / iface-infer / channel discovery did per pass —
    // rebuild the whole string-keyed block graph for every query.
    let legacy = bench("legacy rebuild loop", 1, runs, || {
        let mut total = 0usize;
        for _ in 0..queries {
            for name in &grouped {
                let bg = legacy_block_graph(d.module(name).unwrap());
                total += bg.nets.len();
            }
        }
        total
    });

    // Indexed: build the cache once, then every query is a table lookup.
    let indexed = bench("index build + cached query", 1, runs, || {
        let mut index = DesignIndex::for_design(&d);
        let mut total = 0usize;
        for _ in 0..queries {
            for name in &grouped {
                let (conn, _) = index.conn(&d, name).unwrap();
                total += conn.nets.len();
            }
        }
        total
    });

    let speedup = legacy.median.as_secs_f64() / indexed.median.as_secs_f64().max(1e-12);
    println!("speedup (legacy median / indexed median): {speedup:.1}x");

    if let Some(path) = &out {
        let mut o = JsonObj::new();
        o.insert("bench", Json::str("ir_index"));
        o.insert("design", Json::str("cnn:13x12 (analyzed)"));
        o.insert("grouped_modules", Json::num(grouped.len() as f64));
        o.insert("query_rounds", Json::num(queries as f64));
        o.insert("runs", Json::num(runs as f64));
        o.insert("smoke", Json::Bool(smoke));
        o.insert("legacy_median_ns", Json::num(legacy.median.as_nanos() as f64));
        o.insert("indexed_median_ns", Json::num(indexed.median.as_nanos() as f64));
        o.insert("speedup", Json::num(speedup));
        std::fs::write(path, Json::Obj(o).pretty()).unwrap();
        println!("wrote {path}");
    }
    assert!(
        speedup >= 2.0,
        "cached index path must beat the rebuild loop >=2x (got {speedup:.2}x)"
    );
    println!("\nir_index bench complete");
}

//! Bench: §Perf hot path — batched floorplan-candidate scoring.
//!
//! Compares three evaluators on identical batches:
//! * `cpu-sparse` — edge-list scalar evaluation (the CPU fast path and
//!                  the flow's default);
//! * `cpu-dense`  — the batched matmul identity (the Pallas kernel's
//!                  math, on the CPU — the bit-exact oracle);
//! * `pjrt`       — the AOT-compiled Pallas kernel through the PJRT
//!                  runtime (requires `make artifacts`).
//!
//! Also times the SA explorer: the incremental delta lane vs the
//! full-rescoring baseline (same seed, asserted identical results,
//! ≥ 5x speedup gate — the `BENCH_floorplan_sa.json` CI artifact), 1 vs
//! N parallel chains, CPU vs PJRT scoring, and a full `run_hlps` flow
//! (the L3 hot path the coordinator actually runs).
//!
//! Also times the incremental re-flow engine (`--reflow`): the HLPS
//! flow re-run after a one-leaf timing edit, memoized through a shared
//! [`StageMemo`](rsir::coordinator::memo::StageMemo) vs from-scratch
//! (byte-identity asserted first, ≥ 5x speedup gate — the
//! `BENCH_reflow.json` CI artifact).
//!
//! `--sa-only` runs just the SA comparison; `--reflow` runs just the
//! re-flow comparison; `--smoke` shrinks iteration counts for CI;
//! `--out FILE` writes the section's stats as JSON.

use rsir::coordinator::flow::{run_hlps, FlowConfig};
use rsir::device::builtin;
use rsir::floorplan::cost::{
    BatchEvaluator, CostModel, CpuEvaluator, DenseCpuEvaluator, FullRescore,
};
use rsir::floorplan::problem::{Problem, Unit, UnitEdge};
use rsir::floorplan::sa::{anneal, SaConfig, SaResult};
use rsir::ir::core::Resources;
use rsir::util::bench::bench;
use rsir::util::json::{Json, JsonObj};
use rsir::util::rng::Rng;

fn synth_problem(n: usize, seed: u64) -> Problem {
    let mut rng = Rng::new(seed);
    let units = (0..n)
        .map(|i| Unit {
            nodes: vec![i],
            resources: Resources::new(
                2_000.0 + rng.below(40_000) as f64,
                1_500.0 + rng.below(30_000) as f64,
                rng.below(40) as f64,
                rng.below(120) as f64,
                rng.below(8) as f64,
            ),
            fixed_slot: None,
            name: format!("u{i}"),
        })
        .collect();
    let mut edges = Vec::new();
    for i in 0..n {
        if i + 1 < n {
            edges.push(UnitEdge {
                a: i,
                b: i + 1,
                width: 64 + (rng.below(8) as u64) * 32,
            });
        }
        if i + 5 < n && rng.chance(0.4) {
            edges.push(UnitEdge {
                a: i,
                b: i + 5,
                width: 32,
            });
        }
    }
    Problem {
        units,
        edges,
        die_weight: 3.0,
    }
}

/// The incremental-vs-full-rescore SA comparison: identical seeds and
/// therefore (asserted) identical results, wall-clock compared, 1 vs N
/// workers, results written to `out` and gated at ≥ 5x.
fn sa_delta_section(smoke: bool, out: Option<&str>) {
    let dev = builtin::by_name("u280").unwrap();
    let m = 240usize;
    let steps = if smoke { 40 } else { 120 };
    let runs = if smoke { 3 } else { 5 };
    let par_workers = 4usize;
    println!("== SA scoring: full re-score vs incremental delta (M={m}, {steps} steps) ==");
    let p = synth_problem(m, 17);
    let model = CostModel::build(&p, &dev, 0.7, 1e-4);
    let sa_cfg = SaConfig {
        steps,
        ..Default::default()
    };

    let mut full = FullRescore(CpuEvaluator {
        model: model.clone(),
    });
    let mut inc = CpuEvaluator {
        model: model.clone(),
    };
    // Same seed ⇒ the two lanes must agree exactly before we time them.
    let r_full = anneal(&p, &dev, &mut full, None, &sa_cfg);
    let r_inc = anneal(&p, &dev, &mut inc, None, &sa_cfg);
    assert_results_identical(&r_full, &r_inc, "incremental vs full-rescore");
    let par_cfg = SaConfig {
        workers: par_workers,
        ..sa_cfg.clone()
    };
    let r_par = anneal(&p, &dev, &mut inc, None, &par_cfg);
    assert_results_identical(&r_inc, &r_par, "1 vs N workers");

    let full_stats = bench(&format!("sa full-rescore   M={m}"), 1, runs, || {
        anneal(&p, &dev, &mut full, None, &sa_cfg).best_cost
    });
    let inc_stats = bench(&format!("sa incremental    M={m}"), 1, runs, || {
        anneal(&p, &dev, &mut inc, None, &sa_cfg).best_cost
    });
    let par_stats = bench(&format!("sa incremental w={par_workers}"), 1, runs, || {
        anneal(&p, &dev, &mut inc, None, &par_cfg).best_cost
    });
    let speedup = full_stats.median.as_secs_f64() / inc_stats.median.as_secs_f64().max(1e-12);
    println!("speedup (full-rescore median / incremental median): {speedup:.1}x");

    if let Some(path) = out {
        let mut o = JsonObj::new();
        o.insert("bench", Json::str("floorplan_sa"));
        o.insert("units", Json::num(m as f64));
        o.insert("edges", Json::num(p.edges.len() as f64));
        o.insert("steps", Json::num(steps as f64));
        o.insert("population", Json::num(sa_cfg.population as f64));
        o.insert("proposals", Json::num(sa_cfg.proposals as f64));
        o.insert("runs", Json::num(runs as f64));
        o.insert("smoke", Json::Bool(smoke));
        o.insert(
            "full_rescore_median_ns",
            Json::num(full_stats.median.as_nanos() as f64),
        );
        o.insert(
            "incremental_median_ns",
            Json::num(inc_stats.median.as_nanos() as f64),
        );
        o.insert("parallel_workers", Json::num(par_workers as f64));
        o.insert(
            "parallel_median_ns",
            Json::num(par_stats.median.as_nanos() as f64),
        );
        o.insert("speedup", Json::num(speedup));
        std::fs::write(path, Json::Obj(o).pretty()).unwrap();
        println!("wrote {path}");
    }
    assert!(
        speedup >= 5.0,
        "incremental SA must beat full re-scoring >=5x (got {speedup:.2}x)"
    );
}

/// The incremental re-flow comparison (`--reflow`): prime a
/// [`StageMemo`] with one pristine flow, then re-flow after fresh
/// one-leaf timing edits — memoized vs from-scratch. Byte-identity is
/// asserted (via [`oracle::flow_fingerprint`]) before anything is timed,
/// and the wall-clock gate is ≥ 5x.
///
/// Every timed invocation applies a *new* monotone edit, so the
/// whole-request tier can never answer — the memoized lane wins only
/// through per-stage reuse (placements, floorplan, flatten fragments,
/// characterization, delta STA), the honest incremental path.
fn reflow_section(smoke: bool, out: Option<&str>) {
    use rsir::coordinator::flow::{run_hlps_warm, FlowWarm};
    use rsir::coordinator::memo::StageMemo;
    use rsir::designs::cnn::{self, CnnConfig};
    use rsir::ir::core::Design;
    use rsir::testing::oracle;
    use std::sync::Arc;

    let dev = builtin::by_name("u250").unwrap();
    let cfg = FlowConfig {
        sa_refine: false,
        ..Default::default()
    };
    let (rows, cols) = if smoke { (4usize, 4usize) } else { (6, 6) };
    let runs = if smoke { 3 } else { 5 };
    let pristine = cnn::generate(&CnnConfig { rows, cols }).unwrap().design;
    let leaf = pristine
        .modules
        .values()
        .find(|m| !m.is_grouped())
        .map(|m| m.name.clone())
        .unwrap();
    println!("== incremental re-flow: one-leaf edit, memoized vs from-scratch (cnn {rows}x{cols}) ==");

    let edited = |delta: f64| -> Design {
        let mut d = pristine.clone();
        let m = d.module_mut(&leaf).unwrap();
        let mut t = JsonObj::new();
        t.insert("internal_ns", Json::num(2.0 + delta));
        m.metadata.insert("timing", Json::Obj(t));
        d
    };
    let fp = |d: &Design, stage: Option<Arc<StageMemo>>| -> u64 {
        let mut d = d.clone();
        let mut warm = FlowWarm {
            stage,
            ..Default::default()
        };
        let rep = run_hlps_warm(&mut d, &dev, &cfg, &mut warm).unwrap();
        oracle::flow_fingerprint(&d, &rep)
    };

    // Prime the memo, then require bit-identity on three distinct edits
    // before timing anything: a fast wrong answer is worthless.
    let memo = Arc::new(StageMemo::new(64));
    fp(&pristine, Some(memo.clone()));
    for i in 0..3 {
        let d = edited(0.1 + 0.01 * i as f64);
        assert_eq!(
            fp(&d, Some(memo.clone())),
            fp(&d, None),
            "memoized re-flow diverged from from-scratch on edit {i}"
        );
    }

    let mut n = 0f64;
    let cold_stats = bench(&format!("reflow from-scratch cnn {rows}x{cols}"), 1, runs, || {
        n += 0.01;
        fp(&edited(1.0 + n), None)
    });
    let mut k = 0f64;
    let warm_memo = memo.clone();
    let warm_stats = bench(&format!("reflow memoized     cnn {rows}x{cols}"), 1, runs, || {
        k += 0.01;
        fp(&edited(2.0 + k), Some(warm_memo.clone()))
    });
    let speedup = cold_stats.median.as_secs_f64() / warm_stats.median.as_secs_f64().max(1e-12);
    println!("speedup (from-scratch median / memoized median): {speedup:.1}x");

    if let Some(path) = out {
        let mut o = JsonObj::new();
        o.insert("bench", Json::str("reflow"));
        o.insert("design", Json::str(format!("cnn:{rows}x{cols}")));
        o.insert("modules", Json::num(pristine.modules.len() as f64));
        o.insert("runs", Json::num(runs as f64));
        o.insert("smoke", Json::Bool(smoke));
        o.insert(
            "from_scratch_median_ns",
            Json::num(cold_stats.median.as_nanos() as f64),
        );
        o.insert(
            "memoized_median_ns",
            Json::num(warm_stats.median.as_nanos() as f64),
        );
        o.insert("speedup", Json::num(speedup));
        std::fs::write(path, Json::Obj(o).pretty()).unwrap();
        println!("wrote {path}");
    }
    assert!(
        speedup >= 5.0,
        "memoized re-flow must beat from-scratch >=5x (got {speedup:.2}x)"
    );
}

fn assert_results_identical(a: &SaResult, b: &SaResult, what: &str) {
    assert_eq!(a.best, b.best, "{what}: best diverged");
    assert_eq!(
        a.best_cost.to_bits(),
        b.best_cost.to_bits(),
        "{what}: best_cost diverged"
    );
    assert_eq!(a.trace, b.trace, "{what}: trace diverged");
    assert_eq!(a.evaluated, b.evaluated, "{what}: evaluated diverged");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let sa_only = args.iter().any(|a| a == "--sa-only");
    let reflow_only = args.iter().any(|a| a == "--reflow");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned();
    if reflow_only {
        reflow_section(smoke, out.as_deref());
        println!("\nperf_hotpath bench complete (re-flow section only)");
        return;
    }
    sa_delta_section(smoke, out.as_deref());
    if sa_only {
        println!("\nperf_hotpath bench complete (SA section only)");
        return;
    }

    let dev = builtin::by_name("u280").unwrap();
    let have_artifacts = rsir::runtime::artifacts_dir().join("manifest.json").exists();
    println!("\n== batched candidate scoring (B = 1024) ==");
    for n in [24usize, 60, 120] {
        let p = synth_problem(n, 7);
        let model = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut rng = Rng::new(11);
        let batch: Vec<Vec<usize>> = (0..1024)
            .map(|_| (0..n).map(|_| rng.below(dev.num_slots())).collect())
            .collect();

        let mut cpu = CpuEvaluator {
            model: model.clone(),
        };
        bench(&format!("cpu-sparse M={n} B=1024"), 1, 5, || {
            cpu.evaluate(&batch).iter().sum::<f32>()
        });
        let mut dense = DenseCpuEvaluator {
            model: model.clone(),
        };
        bench(&format!("cpu-dense  M={n} B=1024"), 1, 5, || {
            dense.evaluate(&batch).iter().sum::<f32>()
        });
        if have_artifacts {
            let man = rsir::runtime::Manifest::load(&rsir::runtime::artifacts_dir()).unwrap();
            match rsir::runtime::PjrtEvaluator::new(model.clone(), &man) {
                Ok(mut pjrt) => {
                    // sanity: same numbers
                    let a = pjrt.evaluate(&batch[..64].to_vec());
                    let b = cpu.evaluate(&batch[..64].to_vec());
                    for (x, y) in a.iter().zip(&b) {
                        assert!((x - y).abs() <= 1e-3 * y.abs().max(1.0));
                    }
                    bench(&format!("pjrt       M={n} B=1024"), 1, 5, || {
                        pjrt.evaluate(&batch).iter().sum::<f32>()
                    });
                }
                Err(e) => println!("pjrt unavailable for M={n}: {e}"),
            }
        }
    }

    println!("\n== SA explorer end-to-end (M=60, 120 steps) ==");
    let p = synth_problem(60, 13);
    let model = CostModel::build(&p, &dev, 0.7, 1e-4);
    let sa_cfg = SaConfig {
        steps: 120,
        ..Default::default()
    };
    {
        let mut cpu = CpuEvaluator {
            model: model.clone(),
        };
        bench("sa/cpu  M=60", 1, 3, || {
            anneal(&p, &dev, &mut cpu, None, &sa_cfg).best_cost
        });
    }
    if have_artifacts {
        let man = rsir::runtime::Manifest::load(&rsir::runtime::artifacts_dir()).unwrap();
        if let Ok(mut pjrt) = rsir::runtime::PjrtEvaluator::new(model, &man) {
            bench("sa/pjrt M=60", 1, 3, || {
                anneal(&p, &dev, &mut pjrt, None, &sa_cfg).best_cost
            });
        }
    }

    println!("\n== full HLPS flow (llama2 on u280) ==");
    bench("run_hlps llama2/u280 (no SA)", 0, 3, || {
        let g = rsir::designs::llama2::generate(&Default::default()).unwrap();
        let mut d = g.design;
        run_hlps(
            &mut d,
            &dev,
            &FlowConfig {
                sa_refine: false,
                ..Default::default()
            },
        )
        .unwrap()
        .optimized
        .fmax_mhz()
    });
    println!("\nperf_hotpath bench complete");
}

//! Bench: §Perf hot path — batched floorplan-candidate scoring.
//!
//! Compares three evaluators on identical batches:
//! * `cpu-sparse` — edge-list scalar evaluation (the CPU fast path and
//!                  the flow's default);
//! * `cpu-dense`  — the batched matmul identity (the Pallas kernel's
//!                  math, on the CPU — the bit-exact oracle);
//! * `pjrt`       — the AOT-compiled Pallas kernel through the PJRT
//!                  runtime (requires `make artifacts`).
//!
//! Also times the SA explorer end-to-end with CPU vs PJRT scoring, and a
//! full `run_hlps` flow (the L3 hot path the coordinator actually runs).

use rsir::coordinator::flow::{run_hlps, FlowConfig};
use rsir::device::builtin;
use rsir::floorplan::cost::{BatchEvaluator, CostModel, CpuEvaluator, DenseCpuEvaluator};
use rsir::floorplan::problem::{Problem, Unit, UnitEdge};
use rsir::floorplan::sa::{anneal, SaConfig};
use rsir::ir::core::Resources;
use rsir::util::bench::bench;
use rsir::util::rng::Rng;

fn synth_problem(n: usize, seed: u64) -> Problem {
    let mut rng = Rng::new(seed);
    let units = (0..n)
        .map(|i| Unit {
            nodes: vec![i],
            resources: Resources::new(
                2_000.0 + rng.below(40_000) as f64,
                1_500.0 + rng.below(30_000) as f64,
                rng.below(40) as f64,
                rng.below(120) as f64,
                rng.below(8) as f64,
            ),
            fixed_slot: None,
            name: format!("u{i}"),
        })
        .collect();
    let mut edges = Vec::new();
    for i in 0..n {
        if i + 1 < n {
            edges.push(UnitEdge {
                a: i,
                b: i + 1,
                width: 64 + (rng.below(8) as u64) * 32,
            });
        }
        if i + 5 < n && rng.chance(0.4) {
            edges.push(UnitEdge {
                a: i,
                b: i + 5,
                width: 32,
            });
        }
    }
    Problem {
        units,
        edges,
        die_weight: 3.0,
    }
}

fn main() {
    let dev = builtin::by_name("u280").unwrap();
    let have_artifacts = rsir::runtime::artifacts_dir().join("manifest.json").exists();
    println!("== batched candidate scoring (B = 1024) ==");
    for n in [24usize, 60, 120] {
        let p = synth_problem(n, 7);
        let model = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut rng = Rng::new(11);
        let batch: Vec<Vec<usize>> = (0..1024)
            .map(|_| (0..n).map(|_| rng.below(dev.num_slots())).collect())
            .collect();

        let mut cpu = CpuEvaluator {
            model: model.clone(),
        };
        bench(&format!("cpu-sparse M={n} B=1024"), 1, 5, || {
            cpu.evaluate(&batch).iter().sum::<f32>()
        });
        let mut dense = DenseCpuEvaluator {
            model: model.clone(),
        };
        bench(&format!("cpu-dense  M={n} B=1024"), 1, 5, || {
            dense.evaluate(&batch).iter().sum::<f32>()
        });
        if have_artifacts {
            let man = rsir::runtime::Manifest::load(&rsir::runtime::artifacts_dir()).unwrap();
            match rsir::runtime::PjrtEvaluator::new(model.clone(), &man) {
                Ok(mut pjrt) => {
                    // sanity: same numbers
                    let a = pjrt.evaluate(&batch[..64].to_vec());
                    let b = cpu.evaluate(&batch[..64].to_vec());
                    for (x, y) in a.iter().zip(&b) {
                        assert!((x - y).abs() <= 1e-3 * y.abs().max(1.0));
                    }
                    bench(&format!("pjrt       M={n} B=1024"), 1, 5, || {
                        pjrt.evaluate(&batch).iter().sum::<f32>()
                    });
                }
                Err(e) => println!("pjrt unavailable for M={n}: {e}"),
            }
        }
    }

    println!("\n== SA explorer end-to-end (M=60, 120 steps) ==");
    let p = synth_problem(60, 13);
    let model = CostModel::build(&p, &dev, 0.7, 1e-4);
    let sa_cfg = SaConfig {
        steps: 120,
        ..Default::default()
    };
    {
        let mut cpu = CpuEvaluator {
            model: model.clone(),
        };
        bench("sa/cpu  M=60", 1, 3, || {
            anneal(&p, &dev, &mut cpu, None, &sa_cfg).best_cost
        });
    }
    if have_artifacts {
        let man = rsir::runtime::Manifest::load(&rsir::runtime::artifacts_dir()).unwrap();
        if let Ok(mut pjrt) = rsir::runtime::PjrtEvaluator::new(model, &man) {
            bench("sa/pjrt M=60", 1, 3, || {
                anneal(&p, &dev, &mut pjrt, None, &sa_cfg).best_cost
            });
        }
    }

    println!("\n== full HLPS flow (llama2 on u280) ==");
    bench("run_hlps llama2/u280 (no SA)", 0, 3, || {
        let g = rsir::designs::llama2::generate(&Default::default()).unwrap();
        let mut d = g.design;
        run_hlps(
            &mut d,
            &dev,
            &FlowConfig {
                sa_refine: false,
                ..Default::default()
            },
        )
        .unwrap()
        .optimized
        .fmax_mhz()
    });
    println!("\nperf_hotpath bench complete");
}

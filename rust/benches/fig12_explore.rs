//! Bench: regenerate **Figure 12** — ten floorplans of the LLM design on
//! the VHK158, reporting the trade-off between resource distribution
//! (most-congested-slot utilization), total wirelength, and frequency.
//!
//! Shape expectations: tighter utilization limits spread the design
//! (lower congestion, longer wires), looser limits pack it (shorter
//! wires, more congestion); frequency varies across the sweep (the paper
//! observes up to ~20 MHz between trade-off points).

use rsir::coordinator::explore;
use rsir::coordinator::flow::FlowConfig;
use rsir::device::builtin;
use rsir::util::bench::Table;
use rsir::util::pool::Pool;
use std::time::Instant;

fn main() {
    let dev = builtin::by_name("vhk158").unwrap();
    let g = rsir::designs::llama2::generate(&Default::default()).unwrap();
    let cfg = FlowConfig::default();
    let limits = explore::default_limits();
    let pool = Pool::from_env(None);
    println!("pool: {} workers over {} sweep points\n", pool.workers(), limits.len());

    let t0 = Instant::now();
    let rows = explore::explore(&g.design, &dev, &limits, &cfg, &pool).unwrap();
    let elapsed = t0.elapsed();

    let mut t = Table::new(&["util_limit", "max_slot_util", "wirelength", "Fmax (MHz)"]);
    for r in &rows {
        t.row(&[
            format!("{:.2}", r.util_limit),
            if r.max_slot_util.is_finite() {
                format!("{:.2}", r.max_slot_util)
            } else {
                "-".into()
            },
            if r.wirelength.is_finite() {
                format!("{:.0}", r.wirelength)
            } else {
                "-".into()
            },
            if r.routable {
                format!("{:.0}", r.fmax_mhz)
            } else {
                "-".into()
            },
        ]);
    }
    t.print();

    let routable: Vec<_> = rows.iter().filter(|r| r.routable).collect();
    let fmaxes: Vec<f64> = routable.iter().map(|r| r.fmax_mhz).collect();
    let spread = fmaxes.iter().cloned().fold(f64::MIN, f64::max)
        - fmaxes.iter().cloned().fold(f64::MAX, f64::min);
    let corr = explore::tradeoff_correlation(&rows);
    println!("\n{} of {} floorplans routable", routable.len(), rows.len());
    println!("frequency spread across trade-off points: {spread:.0} MHz (paper: up to ~20 MHz)");
    match corr {
        Some(c) => println!(
            "util_limit vs wirelength correlation: {c:.2} (negative = the Fig 12 trade-off)"
        ),
        None => println!("util_limit vs wirelength correlation: undefined (degenerate sweep)"),
    }
    println!("wall time: {elapsed:?} for {} flows", rows.len());
    let check = |cond: bool, msg: &str| {
        println!("[{}] {msg}", if cond { "ok" } else { "MISS" });
    };
    check(routable.len() >= 7, "most trade-off points routable");
    check(
        corr.is_some_and(|c| c < 0.0),
        "packing tighter shortens wires",
    );
}

//! Bench: the DSE warm-start machinery — identity first, then speed.
//!
//! Two gated measurements:
//!
//! 1. **SA resume microbench** — annealing the last 20% of a budget from
//!    an 80% checkpoint vs annealing the full budget cold. The resumed
//!    result is asserted bit-identical (best assignment, cost bits,
//!    candidate count, full trace) *before* any clock is read, so the
//!    speedup being gated is provably "same bytes, less work".
//! 2. **Sweep warm-vs-cold** — `run_dse` over one group with budgets
//!    ascending, SA warm-starting on vs off. Rows and front asserted
//!    bit-identical first; the wall-clock win comes from each point
//!    re-annealing only the budget delta instead of from step zero.
//!
//! Also asserts the worker-count determinism contract (1 vs 4 pool
//! workers produce byte-identical reports).
//!
//! `--smoke` shrinks sizes for CI; `--out FILE` writes the stats as JSON
//! (uploaded as the `BENCH_dse.json` CI artifact).

use rsir::coordinator::dse::{run_dse, DseConfig};
use rsir::coordinator::flow::{FlowConfig, PipelineStrategy};
use rsir::designs::cnn::{self, CnnConfig};
use rsir::device::builtin;
use rsir::floorplan::cost::{CostModel, CpuEvaluator};
use rsir::floorplan::problem::Problem;
use rsir::floorplan::sa::{anneal_resumable, SaConfig};
use rsir::util::bench::bench;
use rsir::util::json::{Json, JsonObj};
use rsir::util::pool::Pool;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let dev = builtin::by_name("u250").unwrap();
    let (design_id, cnn_cfg) = if smoke {
        ("cnn:4x3", CnnConfig { rows: 4, cols: 3 })
    } else {
        ("cnn:8x6", CnnConfig { rows: 8, cols: 6 })
    };
    let g = cnn::generate(&cnn_cfg).unwrap();
    let runs = 3;

    // ---- 1. SA resume microbench ---------------------------------------
    let nl = rsir::eda::vivado::elaborate(&g.design);
    let problem = Problem::from_netlist(&nl, &dev, 3.0);
    let model = CostModel::build(&problem, &dev, 0.7, 1e-4);
    let full_steps = if smoke { 400 } else { 1500 };
    let prefix_steps = full_steps * 4 / 5;
    let full_cfg = SaConfig {
        steps: full_steps,
        ..Default::default()
    };
    let prefix_cfg = SaConfig {
        steps: prefix_steps,
        ..full_cfg.clone()
    };
    let mut ev = CpuEvaluator { model };
    let (cold_res, _) = anneal_resumable(&problem, &dev, &mut ev, None, &full_cfg, None);
    let (_, ck) = anneal_resumable(&problem, &dev, &mut ev, None, &prefix_cfg, None);
    let ck = ck.expect("incremental lane yields a checkpoint");
    let (resumed, _) = anneal_resumable(&problem, &dev, &mut ev, None, &full_cfg, Some(&ck));

    // Identity before any timing: the resumed anneal is the cold one.
    assert_eq!(cold_res.best, resumed.best, "resume diverged from cold");
    assert_eq!(cold_res.best_cost.to_bits(), resumed.best_cost.to_bits());
    assert_eq!(cold_res.evaluated, resumed.evaluated);
    assert_eq!(cold_res.trace.len(), resumed.trace.len());
    for (a, b) in cold_res.trace.iter().zip(&resumed.trace) {
        assert_eq!(a.to_bits(), b.to_bits(), "trace drifted");
    }
    println!("== sa resume ({design_id}, {prefix_steps}/{full_steps} steps checkpointed) ==");
    let cold_stats = bench("sa cold (full budget)", 1, runs, || {
        anneal_resumable(&problem, &dev, &mut ev, None, &full_cfg, None).0
    });
    let resume_stats = bench("sa resumed (last 20%)", 1, runs, || {
        anneal_resumable(&problem, &dev, &mut ev, None, &full_cfg, Some(&ck)).0
    });
    let resume_speedup =
        cold_stats.median.as_secs_f64() / resume_stats.median.as_secs_f64().max(1e-12);
    println!("resume speedup: {resume_speedup:.2}x (identical bits)");

    // ---- 2. Sweep warm-vs-cold -----------------------------------------
    let budgets: Vec<usize> = if smoke {
        vec![100, 200, 300, 400]
    } else {
        vec![300, 600, 900, 1200]
    };
    let base = FlowConfig::default();
    let warm_cfg = DseConfig {
        utils: vec![0.7],
        grids: vec![1],
        sa_steps: budgets.clone(),
        strategies: vec![PipelineStrategy::Full],
        base: base.clone(),
        warm_sa: true,
    };
    let cold_cfg = DseConfig {
        warm_sa: false,
        ..warm_cfg.clone()
    };
    let pool = Pool::new(1);

    // Identity before timing: warm rows/front == cold rows/front, and
    // the report is byte-identical at a different worker count.
    let warm_report = run_dse(&g.design, &dev, &warm_cfg, &pool).unwrap();
    let cold_report = run_dse(&g.design, &dev, &cold_cfg, &pool).unwrap();
    assert_eq!(warm_report.rows.len(), cold_report.rows.len());
    for (a, b) in warm_report.rows.iter().zip(&cold_report.rows) {
        assert!(a.bits_eq(b), "warm row drifted from cold: {a:?} vs {b:?}");
    }
    assert_eq!(
        warm_report.to_json().pretty(),
        cold_report.to_json().pretty(),
        "warm report drifted from cold"
    );
    let wide_report = run_dse(&g.design, &dev, &warm_cfg, &Pool::new(4)).unwrap();
    assert_eq!(
        warm_report.to_json().pretty(),
        wide_report.to_json().pretty(),
        "report depends on worker count"
    );
    assert!(
        warm_report.rows.iter().any(|r| r.routable),
        "sweep produced no routable points: {:?}",
        warm_report.rows
    );

    println!("\n== dse sweep ({design_id}, budgets {budgets:?}) ==");
    let sweep_cold = bench("dse cold starts", 0, runs, || {
        run_dse(&g.design, &dev, &cold_cfg, &pool).unwrap()
    });
    let sweep_warm = bench("dse warm starts", 0, runs, || {
        run_dse(&g.design, &dev, &warm_cfg, &pool).unwrap()
    });
    let sweep_speedup =
        sweep_cold.median.as_secs_f64() / sweep_warm.median.as_secs_f64().max(1e-12);
    println!("sweep warm-start speedup: {sweep_speedup:.2}x (identical bits)");

    if let Some(path) = &out {
        let mut o = JsonObj::new();
        o.insert("bench", Json::str("dse"));
        o.insert("design", Json::str(design_id));
        o.insert("runs", Json::num(runs as f64));
        o.insert("smoke", Json::Bool(smoke));
        o.insert("points", Json::num(warm_report.rows.len() as f64));
        o.insert("front", Json::num(warm_report.front.len() as f64));
        o.insert("sa_cold_median_ns", Json::num(cold_stats.median.as_nanos() as f64));
        o.insert(
            "sa_resume_median_ns",
            Json::num(resume_stats.median.as_nanos() as f64),
        );
        o.insert("resume_speedup", Json::num(resume_speedup));
        o.insert(
            "sweep_cold_median_ns",
            Json::num(sweep_cold.median.as_nanos() as f64),
        );
        o.insert(
            "sweep_warm_median_ns",
            Json::num(sweep_warm.median.as_nanos() as f64),
        );
        o.insert("sweep_speedup", Json::num(sweep_speedup));
        o.insert("byte_identical", Json::Bool(true));
        std::fs::write(path, Json::Obj(o).pretty()).unwrap();
        println!("wrote {path}");
    }

    // Gates (identity was asserted above; these are pure wall-clock).
    let (resume_gate, sweep_gate) = if smoke { (1.5, 1.05) } else { (2.0, 1.25) };
    assert!(
        resume_speedup >= resume_gate,
        "resuming the last 20% must beat a cold full anneal >={resume_gate}x \
         (got {resume_speedup:.2}x)"
    );
    assert!(
        sweep_speedup >= sweep_gate,
        "warm-started sweep must beat cold starts >={sweep_gate}x (got {sweep_speedup:.2}x)"
    );
    println!("\ndse bench complete");
}

//! Bench: regenerate **Table 2** — frequency improvements for every
//! benchmark × device row, timing each full HLPS flow. Pass `--only
//! <substr>` via `cargo bench --bench table2_freq -- --only llama2-u280`,
//! and `--workers N` (or `RSIR_WORKERS`) to size the row-level pool.
//!
//! Shape expectations vs the paper (absolute MHz comes from the EDA
//! simulator, see DESIGN.md substitutions):
//! * every routable row improves; average gain in the tens of percent;
//! * CNN rows land in AutoBridge's class (~300-335 MHz optimized);
//! * CNN 13x10/13x12 and KNN are unroutable at baseline ("-");
//! * Minimap2 shows the smallest gain (pre-pipelined hierarchy).

use rsir::coordinator::flow::FlowConfig;
use rsir::coordinator::report;
use rsir::util::pool::Pool;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let arg_after = |flag: &str| {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
    };
    let only = arg_after("--only").map(|s| s.as_str());
    let workers = arg_after("--workers").and_then(|s| s.parse::<usize>().ok());
    let pool = Pool::from_env(workers);
    let cfg = FlowConfig::default();

    let t0 = Instant::now();
    let rows = report::table2(only, &cfg, &pool).expect("table2 failed");
    let elapsed = t0.elapsed();

    report::render_table2(&rows).print();
    println!("pool: {} workers", pool.workers());

    let imps: Vec<f64> = rows.iter().filter_map(|r| r.improvement()).collect();
    let unroutable = rows.iter().filter(|r| r.original_mhz.is_none()).count();
    if !imps.is_empty() {
        println!(
            "\naverage improvement: +{:.0}% over {} routable baselines (paper: ~+39%)",
            imps.iter().sum::<f64>() / imps.len() as f64,
            imps.len()
        );
    }
    println!("unroutable baselines: {unroutable} (paper: 3 of 14)");
    println!("total wall time: {elapsed:?} for {} flows", rows.len());

    // Shape assertions (soft: report, don't panic, so partial runs work).
    if only.is_none() {
        let check = |cond: bool, msg: &str| {
            println!("[{}] {msg}", if cond { "ok" } else { "MISS" });
        };
        check(
            rows.iter().all(|r| r.original_mhz.map(|o| r.rir_mhz > o).unwrap_or(true)),
            "RIR beats every routable baseline",
        );
        check(unroutable == 3, "exactly 3 unroutable baselines");
        let cnn_ok = rows
            .iter()
            .filter(|r| r.app.starts_with("CNN"))
            .all(|r| r.rir_mhz > 290.0);
        check(cnn_ok, "CNN optimized rows in the AutoBridge class (>290 MHz)");
        let mm = rows.iter().find(|r| r.app == "Minimap2");
        if let Some(mm) = mm {
            let small = mm.improvement().map(|i| i < 15.0).unwrap_or(false);
            check(small, "Minimap2 gain is the smallest (pre-pipelined design)");
        }
    }
}

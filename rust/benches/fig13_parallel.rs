//! Bench: regenerate **Figure 13** — synthesis wall time, monolithic vs
//! per-slot parallel, for CNN systolic arrays 13x4 … 13x12 on the U250.
//!
//! Two layers of numbers:
//! * the modeled vendor wall times (the Figure 13 bars; paper average
//!   speedup 2.49x, growing with array size);
//! * measured wall time of actually running our synthesis surrogate
//!   sequentially vs on threads (the plugin's parallelism is real).

use rsir::coordinator::flow::{run_hlps, FlowConfig};
use rsir::coordinator::parallel_synth;
use rsir::designs::cnn::{self, CnnConfig};
use rsir::device::builtin;
use rsir::eda::SynthTimeModel;
use rsir::util::bench::Table;
use std::time::Instant;

fn main() {
    let dev = builtin::by_name("u250").unwrap();
    let model = SynthTimeModel::default();
    let workers = 8;
    let mut t = Table::new(&[
        "CNN",
        "Groups",
        "Monolithic (s)",
        "Parallel (s)",
        "Speedup",
        "Measured seq",
        "Measured par",
    ]);
    let mut speedups = Vec::new();
    let t0 = Instant::now();
    for cols in [4usize, 6, 8, 10, 12] {
        let g = cnn::generate(&CnnConfig { rows: 13, cols }).unwrap();
        let mut d = g.design;
        run_hlps(
            &mut d,
            &dev,
            &FlowConfig {
                sa_refine: false,
                ..Default::default()
            },
        )
        .unwrap();
        let rep = parallel_synth::run(&d, &dev, workers, &model).unwrap();
        speedups.push(rep.modeled_speedup);
        t.row(&[
            format!("13x{cols}"),
            rep.groups.len().to_string(),
            format!("{:.0}", rep.modeled_monolithic_s),
            format!("{:.0}", rep.modeled_parallel_s),
            format!("{:.2}x", rep.modeled_speedup),
            format!("{:?}", rep.measured_sequential),
            format!("{:?}", rep.measured_parallel),
        ]);
    }
    t.print();
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("\naverage modeled speedup: {avg:.2}x (paper: 2.49x)");
    println!("wall time: {:?}", t0.elapsed());
    let check = |cond: bool, msg: &str| {
        println!("[{}] {msg}", if cond { "ok" } else { "MISS" });
    };
    check((1.5..4.0).contains(&avg), "average speedup in the paper's band");
    check(
        speedups.windows(2).all(|w| w[1] >= w[0] - 0.3),
        "speedup grows (roughly) with array size",
    );
}

//! Bench: regenerate **Table 1** — lines of adaptation code needed to
//! support Dynamatic, Catapult HLS and Intel HLS input, plus timed
//! import/transform/export sweeps over every benchmark of each frontend
//! (29 Dynamatic examples, the Catapult sparse-LA design, 12 CHStone
//! programs) proving the RQ1 claim end-to-end.

use rsir::coordinator::report;
use rsir::designs::{catapult, dynamatic, intel_hls};
use rsir::passes::manager::{Pass, PassContext};
use rsir::util::bench::bench;

fn main() {
    println!("== Table 1: code to support each HLS tool ==");
    report::table1().print();
    println!("(paper: Dynamatic 146, Catapult 158, Intel 204 lines)");
    println!();

    println!("== RQ1 sweep: import + transform + export every benchmark ==");
    bench("dynamatic: 29 examples import+rules", 1, 5, || {
        let mut ok = 0;
        for ex in dynamatic::EXAMPLES {
            let g = dynamatic::generate(ex).unwrap();
            assert!(g.design.module(ex).unwrap().uncovered_ports().is_empty());
            ok += 1;
        }
        ok
    });
    bench("intel-hls: 12 CHStone import+rules", 1, 5, || {
        let mut ok = 0;
        for b in intel_hls::CHSTONE {
            let g = intel_hls::generate(b).unwrap();
            assert!(g.design.module(b).unwrap().uncovered_ports().is_empty());
            ok += 1;
        }
        ok
    });
    bench("catapult: sparse-LA import+inference", 1, 5, || {
        let g = catapult::generate().unwrap();
        assert_eq!(
            g.design
                .module("spmv_core")
                .unwrap()
                .interface_of("row_dat")
                .map(|i| i.kind()),
            Some("handshake")
        );
        g.design.modules.len()
    });
    // Functionally-equivalent RTL export (the paper's closing claim of
    // §4.1): hierarchy transformed + pipeline inserted + exported.
    bench("dynamatic fir: full transform + export", 1, 5, || {
        let g = dynamatic::generate("fir").unwrap();
        let mut d = g.design;
        let mut ctx = PassContext::new();
        rsir::passes::rebuild::RebuildAll.run(&mut d, &mut ctx).unwrap();
        rsir::passes::iface_infer::InterfaceInference
            .run(&mut d, &mut ctx)
            .unwrap();
        let bundle = rsir::plugins::export(&d).unwrap();
        bundle.files.len()
    });
    println!("\ntable1_loc bench complete");
}

//! Floorplanning: the AutoBridge ILP formulation, the batched cost model
//! (CPU oracle of the Pallas kernel) with its incremental delta
//! evaluator ([`cost::ScoredState`]), and the simulated-annealing
//! explorer used for design-space exploration (Fig 12).

pub mod autobridge;
pub mod cost;
pub mod problem;
pub mod sa;

pub use autobridge::{solve, FloorplanResult, IlpFpConfig};
pub use cost::{
    BatchEvaluator, CostModel, CpuEvaluator, DenseCpuEvaluator, FullRescore, Proposal, ScoredState,
};
pub use problem::{Problem, Unit, UnitEdge};
pub use sa::{anneal, anneal_resumable, cmp_cost_f64, SaCheckpoint, SaConfig, SaResult};

use std::fmt;

/// Typed marker for *design infeasibility*: the floorplan ILP proved (or
/// budget-exhausted into) "this design does not fit this device at this
/// limit", or the placer could not fit the netlist at all. Sweeps
/// ([`crate::coordinator::explore`], [`crate::coordinator::dse`])
/// downcast to this to record an explicit unroutable data point, while
/// every *other* error — a genuine flow bug — propagates as `Err`.
///
/// The `Display` text is byte-identical to the untyped `anyhow!` strings
/// it replaced, so daemon error-message parity and log goldens are
/// unchanged.
#[derive(Debug, Clone)]
pub struct Infeasible {
    /// Human-readable reason, rendered verbatim.
    pub reason: String,
}

impl Infeasible {
    pub fn new(reason: impl Into<String>) -> Self {
        Infeasible {
            reason: reason.into(),
        }
    }
}

impl fmt::Display for Infeasible {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.reason)
    }
}

impl std::error::Error for Infeasible {}

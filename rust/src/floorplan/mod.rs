//! Floorplanning: the AutoBridge ILP formulation, the batched cost model
//! (CPU oracle of the Pallas kernel) with its incremental delta
//! evaluator ([`cost::ScoredState`]), and the simulated-annealing
//! explorer used for design-space exploration (Fig 12).

pub mod autobridge;
pub mod cost;
pub mod problem;
pub mod sa;

pub use autobridge::{solve, FloorplanResult, IlpFpConfig};
pub use cost::{
    BatchEvaluator, CostModel, CpuEvaluator, DenseCpuEvaluator, FullRescore, Proposal, ScoredState,
};
pub use problem::{Problem, Unit, UnitEdge};
pub use sa::{anneal, SaConfig, SaResult};

//! Simulated-annealing floorplan explorer with an incremental fast lane.
//!
//! Used by the Figure-12 design-space exploration and as a refinement /
//! fallback around the ILP. Chains are persistent [`ScoredState`]s
//! mutated in place; each proposal changes 1–2 unit assignments and is
//! scored in O(deg + K) through the delta path (`apply` → `cost` →
//! `revert`) instead of a full O(edges + units×kinds) re-score.
//!
//! Two scoring lanes, selected by [`BatchEvaluator::cost_model`]:
//!
//! * **Incremental** (CPU): every chain is an independent job — its own
//!   seeded RNG stream ([`Rng::stream`]), its own `ScoredState` — run
//!   start-to-finish on the `util::pool` work-stealing executor.
//!   Results are byte-identical for any `SaConfig::workers` value.
//! * **Batched** (dense oracle / PJRT): the historical contract — one
//!   `evaluate` launch scores `population × proposals` materialized
//!   candidates per step, which is what makes the accelerator offload
//!   worthwhile. Chains draw from the same per-chain RNG streams, so
//!   with a bit-exact evaluator both lanes produce identical results
//!   (asserted by `tests/floorplan_sa.rs`).

use crate::device::model::VirtualDevice;
use crate::floorplan::cost::{score_deltas_into, BatchEvaluator, CostModel, Proposal, ScoredState};
use crate::floorplan::problem::Problem;
use crate::util::pool::Pool;
use crate::util::rng::Rng;
use std::cmp::Ordering;

#[derive(Debug, Clone)]
pub struct SaConfig {
    pub seed: u64,
    /// Parallel annealing chains.
    pub population: usize,
    /// Proposals per chain per step (all scored per chain, best picked).
    pub proposals: usize,
    pub steps: usize,
    pub t0: f64,
    pub cooling: f64,
    /// Pool workers the incremental lane spreads chains across (clamped
    /// to ≥ 1). Purely a wall-clock knob: chains own independent RNG
    /// streams, so results are byte-identical for any value. Defaults to
    /// 1 because the coordinator already parallelizes across flows
    /// (Table 2 rows, Figure 12 sweep points).
    pub workers: usize,
}

impl Default for SaConfig {
    fn default() -> Self {
        SaConfig {
            seed: 0x5EED,
            population: 16,
            proposals: 8,
            steps: 300,
            t0: 2_000.0,
            cooling: 0.97,
            workers: 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SaResult {
    pub best: Vec<usize>,
    pub best_cost: f32,
    /// Candidates evaluated in total.
    pub evaluated: usize,
    /// Cost trace (best-so-far per step), for convergence plots.
    pub trace: Vec<f32>,
}

/// Everything one chain learned, merged deterministically afterwards.
#[derive(Debug, Clone)]
struct ChainOut {
    best: Vec<usize>,
    best_cost: f32,
    /// Step at which `best_cost` was first reached (0 = the initial
    /// assignment) — the merge tie-breaker that keeps the winner
    /// independent of execution order.
    best_step: usize,
    trace: Vec<f32>,
    evaluated: usize,
}

/// Full per-chain loop state at a step boundary — everything a chain
/// needs to resume *bit-exactly*: the RNG mid-stream, the incrementally
/// maintained [`ScoredState`] (stored, never rebuilt from the assignment
/// — a rebuild is only bit-stable on exact-friendly inputs), the
/// accepted cost, the cooled temperature (stored, not recomputed — a
/// `powi` shortcut need not bit-match the iterative `temp *= cooling`
/// product), and the running [`ChainOut`].
#[derive(Debug, Clone)]
struct ChainState {
    rng: Rng,
    state: ScoredState,
    cost: f32,
    temp: f64,
    steps_done: usize,
    out: ChainOut,
}

/// Resumable snapshot of an incremental-lane anneal at a step boundary.
///
/// Chains are pure functions of `(seed, model, initial)`, so a run with
/// fewer steps is a bit-exact *prefix* of a longer run — which makes
/// "warm-start from a neighboring sweep point" expressible without
/// breaking determinism: resuming a checkpoint taken at `T1` steps up to
/// `T2 > T1` produces byte-identical results to a cold `T2`-step run,
/// paying only for the `T2 − T1` remainder.
///
/// The embedded key covers everything the annealer itself can see
/// (problem, slot count, initial assignment, every [`SaConfig`] knob
/// except `steps` and `workers` — both pure wall-clock knobs); an
/// incompatible checkpoint is silently ignored (cold fallback). The one
/// thing the key *cannot* cover is the evaluator's cost model, which is
/// the caller's contract: resume only against the same model (same
/// design, device, utilization limit) — exactly what a steps-only sweep
/// axis guarantees.
#[derive(Debug, Clone)]
pub struct SaCheckpoint {
    key: u64,
    steps_done: usize,
    chains: Vec<ChainState>,
}

impl SaCheckpoint {
    /// Steps the checkpointed run had completed — resumable to any
    /// target ≥ this.
    pub fn steps_done(&self) -> usize {
        self.steps_done
    }
}

/// Shared read-only context of the incremental lanes.
struct ChainCtx<'a> {
    problem: &'a Problem,
    model: &'a CostModel,
    movable: &'a [usize],
    cfg: &'a SaConfig,
    ns: usize,
}

/// Run SA. `initial` seeds chain 0 (e.g. the ILP solution); remaining
/// chains start random. Pinned units never move. Deterministic for a
/// given `cfg.seed` regardless of `cfg.workers` or the evaluator lane
/// (given a bit-exact evaluator).
pub fn anneal(
    problem: &Problem,
    dev: &VirtualDevice,
    evaluator: &mut dyn BatchEvaluator,
    initial: Option<&[usize]>,
    cfg: &SaConfig,
) -> SaResult {
    anneal_resumable(problem, dev, evaluator, initial, cfg, None).0
}

/// [`anneal`] with checkpoint/resume along the *steps* axis.
///
/// When `resume` is a compatible [`SaCheckpoint`] (same problem, initial
/// assignment, and every config knob except `steps`/`workers`, taken at
/// `steps_done ≤ cfg.steps`), every chain picks up exactly where it
/// left off and runs only the remaining steps — byte-identical to a
/// cold `cfg.steps` run, by the prefix property of deterministic chains.
/// An incompatible or absent checkpoint runs cold from step 0.
///
/// Returns the result plus a checkpoint at `cfg.steps` for the next
/// resume. The batched lane (evaluators without [`BatchEvaluator::
/// cost_model`]) has no mid-run state hand-off: it ignores `resume` and
/// returns `None`.
pub fn anneal_resumable(
    problem: &Problem,
    dev: &VirtualDevice,
    evaluator: &mut dyn BatchEvaluator,
    initial: Option<&[usize]>,
    cfg: &SaConfig,
    resume: Option<&SaCheckpoint>,
) -> (SaResult, Option<SaCheckpoint>) {
    let ns = dev.num_slots();
    let movable: Vec<usize> = (0..problem.units.len())
        .filter(|&u| problem.units[u].fixed_slot.is_none())
        .collect();
    // Clone the sparse scoring view out of the evaluator so it stays
    // callable (the serial delta lane keeps scoring through
    // `evaluate_deltas` on it) — O(m + E), the dense matrix is skipped.
    let model = match evaluator.cost_model().map(CostModel::sparse_clone) {
        Some(m) => m,
        None => {
            let r = anneal_batched(problem, evaluator, &movable, initial, cfg, ns);
            return (r, None);
        }
    };
    debug_assert_eq!(model.m_real, problem.units.len(), "model/problem mismatch");
    let ctx = ChainCtx {
        problem,
        model: &model,
        movable: &movable,
        cfg,
        ns,
    };
    let population = cfg.population.max(1);
    let key = resume_key(problem, cfg, initial, ns);
    let seeds: Option<&[ChainState]> = resume
        .filter(|ck| {
            ck.key == key && ck.steps_done <= cfg.steps && ck.chains.len() == population
        })
        .map(|ck| ck.chains.as_slice());

    let finals: Vec<ChainState> = if cfg.workers.max(1) > 1 {
        // The parallel fast lane (`workers > 1`): chains are independent
        // pool jobs scored through the shared [`score_deltas_into`]
        // delta routine — per-evaluator `evaluate_deltas` overrides are
        // bypassed here, which is sound exactly because `cost_model()`
        // promises scoring is a pure function of the model (the 1-vs-N
        // determinism test pins it).
        let pool = Pool::new(cfg.workers.max(1));
        pool.par_map((0..population).collect::<Vec<usize>>(), |chain| {
            let mut cs = match seeds {
                Some(cks) => cks[chain].clone(),
                None => chain_start(&ctx, if chain == 0 { initial } else { None }, chain),
            };
            let mut score = |st: &mut ScoredState, props: &[Proposal], out: &mut Vec<f32>| {
                score_deltas_into(ctx.model, st, props, out);
            };
            chain_run_to(&ctx, &mut cs, cfg.steps, &mut score);
            cs
        })
    } else {
        // The serial fast lane (the default, `workers <= 1`): same
        // per-chain run, but every scoring round goes through the
        // evaluator's [`BatchEvaluator::evaluate_deltas`] — the trait's
        // incremental entry point — so evaluator overrides stay on the
        // hot path.
        (0..population)
            .map(|chain| {
                let mut cs = match seeds {
                    Some(cks) => cks[chain].clone(),
                    None => chain_start(&ctx, if chain == 0 { initial } else { None }, chain),
                };
                let mut score = |st: &mut ScoredState, props: &[Proposal], out: &mut Vec<f32>| {
                    evaluator.evaluate_deltas(st, props, out);
                };
                chain_run_to(&ctx, &mut cs, cfg.steps, &mut score);
                cs
            })
            .collect()
    };
    let checkpoint = SaCheckpoint {
        key,
        steps_done: cfg.steps,
        chains: finals.clone(),
    };
    let outs: Vec<ChainOut> = finals.into_iter().map(|cs| cs.out).collect();
    (merge(outs), Some(checkpoint))
}

/// Fingerprint of everything a chain's trajectory depends on that the
/// annealer can see — the [`SaCheckpoint`] validity key. `steps` and
/// `workers` are deliberately excluded (the resume axis and a pure
/// wall-clock knob respectively).
fn resume_key(problem: &Problem, cfg: &SaConfig, initial: Option<&[usize]>, ns: usize) -> u64 {
    let mut f = crate::ir::digest::Fnv::new();
    f.write_usize(ns);
    f.write_u64(cfg.seed)
        .write_usize(cfg.population)
        .write_usize(cfg.proposals)
        .write_f64(cfg.t0)
        .write_f64(cfg.cooling);
    f.write_f64(problem.die_weight);
    f.write_usize(problem.units.len());
    for u in &problem.units {
        f.write_f64(u.resources.lut)
            .write_f64(u.resources.ff)
            .write_f64(u.resources.bram)
            .write_f64(u.resources.dsp)
            .write_f64(u.resources.uram);
        match u.fixed_slot {
            Some(s) => {
                f.write_bool(true);
                f.write_usize(s);
            }
            None => {
                f.write_bool(false);
            }
        }
    }
    f.write_usize(problem.edges.len());
    for e in &problem.edges {
        f.write_usize(e.a).write_usize(e.b).write_u64(e.width);
    }
    match initial {
        Some(init) => {
            f.write_bool(true);
            f.write_usize(init.len());
            for &s in init {
                f.write_usize(s);
            }
        }
        None => {
            f.write_bool(false);
        }
    }
    f.finish()
}

/// Start one chain: seeded stream, initial assignment, scored state.
fn chain_start(ctx: &ChainCtx, initial: Option<&[usize]>, chain: usize) -> ChainState {
    let (cfg, model, ns) = (ctx.cfg, ctx.model, ctx.ns);
    let mut rng = Rng::stream(cfg.seed, chain as u64);
    let assign: Vec<usize> = match initial {
        Some(init) => init.to_vec(),
        None => (0..ctx.problem.units.len())
            .map(|u| ctx.problem.units[u].fixed_slot.unwrap_or_else(|| rng.below(ns)))
            .collect(),
    };
    let mut state = ScoredState::new(model, assign);
    let cost = state.cost(model);
    let out = ChainOut {
        best: state.assignment().to_vec(),
        best_cost: cost,
        best_step: 0,
        trace: Vec::with_capacity(cfg.steps),
        evaluated: 1,
    };
    ChainState {
        rng,
        state,
        cost,
        temp: cfg.t0,
        steps_done: 0,
        out,
    }
}

/// Advance one chain from `cs.steps_done` to `target`: persistent state,
/// proposal scoring through `score` (a delta-path scorer) with one
/// reusable flat scratch buffer. Cold runs and resumed runs share this
/// single loop body — the structural reason a resumed run is bit-exact.
fn chain_run_to(
    ctx: &ChainCtx,
    cs: &mut ChainState,
    target: usize,
    score: &mut dyn FnMut(&mut ScoredState, &[Proposal], &mut Vec<f32>),
) {
    let (cfg, model, ns) = (ctx.cfg, ctx.model, ctx.ns);
    if ctx.movable.is_empty() || cfg.proposals == 0 {
        cs.steps_done = target;
        return;
    }
    let mut scratch: Vec<Proposal> = Vec::with_capacity(cfg.proposals);
    let mut costs: Vec<f32> = Vec::with_capacity(cfg.proposals);
    for step in cs.steps_done..target {
        scratch.clear();
        for _ in 0..cfg.proposals {
            scratch.push(propose(&mut cs.rng, cs.state.assignment(), ctx.movable, ns));
        }
        score(&mut cs.state, &scratch, &mut costs);
        cs.out.evaluated += costs.len();
        let pick = pick_first_min(&costs, 0, costs.len());
        let delta = (costs[pick] - cs.cost) as f64;
        if delta <= 0.0 || cs.rng.f64() < (-delta / cs.temp).exp() {
            cs.state.apply(model, &scratch[pick]);
            cs.state.commit();
            cs.cost = costs[pick];
            if cs.cost < cs.out.best_cost {
                cs.out.best_cost = cs.cost;
                cs.out.best.copy_from_slice(cs.state.assignment());
                cs.out.best_step = step + 1;
            }
        }
        cs.temp *= cfg.cooling;
        cs.out.trace.push(cs.out.best_cost);
    }
    cs.steps_done = target;
}

/// The batched lane (dense oracle / PJRT): one `evaluate` launch per
/// step over all chains' materialized proposals — the exact historical
/// device contract. Same per-chain RNG streams as the fast lane.
fn anneal_batched(
    problem: &Problem,
    evaluator: &mut dyn BatchEvaluator,
    movable: &[usize],
    initial: Option<&[usize]>,
    cfg: &SaConfig,
    ns: usize,
) -> SaResult {
    let population = cfg.population.max(1);
    let mut rngs: Vec<Rng> = (0..population)
        .map(|c| Rng::stream(cfg.seed, c as u64))
        .collect();
    let chains: Vec<Vec<usize>> = rngs
        .iter_mut()
        .enumerate()
        .map(|(c, rng)| {
            if c == 0 {
                if let Some(init) = initial {
                    return init.to_vec();
                }
            }
            (0..problem.units.len())
                .map(|u| problem.units[u].fixed_slot.unwrap_or_else(|| rng.below(ns)))
                .collect()
        })
        .collect();
    let init_costs = evaluator.evaluate(&chains);
    let mut chains = chains;
    let mut cur_costs = init_costs.clone();
    let mut outs: Vec<ChainOut> = chains
        .iter()
        .zip(&init_costs)
        .map(|(c, &cost)| ChainOut {
            best: c.clone(),
            best_cost: cost,
            best_step: 0,
            trace: Vec::with_capacity(cfg.steps),
            evaluated: 1,
        })
        .collect();
    if movable.is_empty() || cfg.proposals == 0 {
        return merge(outs);
    }
    let mut temp = cfg.t0;
    let mut scratch: Vec<Proposal> = Vec::with_capacity(population * cfg.proposals);
    for step in 0..cfg.steps {
        scratch.clear();
        for (c, rng) in rngs.iter_mut().enumerate() {
            for _ in 0..cfg.proposals {
                scratch.push(propose(rng, &chains[c], movable, ns));
            }
        }
        let mut batch: Vec<Vec<usize>> = scratch
            .iter()
            .enumerate()
            .map(|(i, p)| p.materialize(&chains[i / cfg.proposals]))
            .collect();
        let costs = evaluator.evaluate(&batch);
        for c in 0..population {
            let base = c * cfg.proposals;
            let pick = pick_first_min(&costs, base, base + cfg.proposals);
            let delta = (costs[pick] - cur_costs[c]) as f64;
            if delta <= 0.0 || rngs[c].f64() < (-delta / temp).exp() {
                chains[c] = std::mem::take(&mut batch[pick]);
                cur_costs[c] = costs[pick];
                if cur_costs[c] < outs[c].best_cost {
                    outs[c].best_cost = cur_costs[c];
                    outs[c].best.copy_from_slice(&chains[c]);
                    outs[c].best_step = step + 1;
                }
            }
            outs[c].evaluated += cfg.proposals;
            outs[c].trace.push(outs[c].best_cost);
        }
        temp *= cfg.cooling;
    }
    merge(outs)
}

/// Draw one proposal: 1–2 mutations, each a random move or (30 % of the
/// time, given ≥ 2 movable units) a swap of two *distinct* movable
/// units — a self-swap would silently waste a mutation. Later mutations
/// see earlier ones through the proposal's overlay view.
fn propose(rng: &mut Rng, base: &[usize], movable: &[usize], ns: usize) -> Proposal {
    let mut p = Proposal::default();
    let moves = 1 + rng.below(2);
    for _ in 0..moves {
        if rng.chance(0.3) && movable.len() >= 2 {
            let (ai, bi) = distinct_pair(rng, movable.len());
            let (a, b) = (movable[ai], movable[bi]);
            let (sa, sb) = (p.slot_of(a, base), p.slot_of(b, base));
            p.push(a as u32, sb as u32);
            p.push(b as u32, sa as u32);
        } else {
            let u = *rng.pick(movable);
            p.push(u as u32, rng.below(ns) as u32);
        }
    }
    p
}

/// Two distinct indices in `[0, n)`, uniform over ordered pairs `a ≠ b`.
fn distinct_pair(rng: &mut Rng, n: usize) -> (usize, usize) {
    debug_assert!(n >= 2);
    let a = rng.below(n);
    let mut b = rng.below(n - 1);
    if b >= a {
        b += 1;
    }
    (a, b)
}

/// Total cost order: finite costs by value, every NaN after every
/// finite cost (and NaNs equal to each other), so a poisoned evaluator
/// row can neither panic the explorer nor win a comparison.
fn cmp_cost(a: f32, b: f32) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (false, false) => a.partial_cmp(&b).unwrap(),
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
    }
}

/// `f64` twin of the chain cost order, public because the sweep layers
/// reuse it: [`crate::coordinator::explore`]'s canonical row equality
/// and [`crate::coordinator::dse`]'s Pareto dominance both rank every
/// NaN sentinel after (worse than) every finite metric, with NaNs equal
/// to each other — the same total order the annealer applies to f32
/// costs.
pub fn cmp_cost_f64(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (false, false) => a.partial_cmp(&b).unwrap(),
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
    }
}

/// Index of the first strict minimum of `costs[lo..hi]` under
/// [`cmp_cost`] (first-wins on ties, matching the historical pick).
fn pick_first_min(costs: &[f32], lo: usize, hi: usize) -> usize {
    let mut pick = lo;
    for k in lo + 1..hi {
        if cmp_cost(costs[k], costs[pick]) == Ordering::Less {
            pick = k;
        }
    }
    pick
}

/// Deterministic cross-chain merge: the winner minimizes
/// (cost, step first reached, chain index) under the total cost order;
/// the global trace is the per-step minimum over chain traces. Both are
/// independent of execution order, which is what makes `workers` a pure
/// wall-clock knob.
fn merge(mut outs: Vec<ChainOut>) -> SaResult {
    let mut win = 0usize;
    for c in 1..outs.len() {
        let better = match cmp_cost(outs[c].best_cost, outs[win].best_cost) {
            Ordering::Less => true,
            Ordering::Equal => outs[c].best_step < outs[win].best_step,
            Ordering::Greater => false,
        };
        if better {
            win = c;
        }
    }
    let steps = outs[0].trace.len();
    let trace: Vec<f32> = (0..steps)
        .map(|t| {
            let mut m = outs[0].trace[t];
            for o in &outs[1..] {
                if cmp_cost(o.trace[t], m) == Ordering::Less {
                    m = o.trace[t];
                }
            }
            m
        })
        .collect();
    SaResult {
        best: std::mem::take(&mut outs[win].best),
        best_cost: outs[win].best_cost,
        evaluated: outs.iter().map(|o| o.evaluated).sum(),
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::floorplan::cost::{CostModel, CpuEvaluator};
    use crate::floorplan::problem::{Problem, Unit, UnitEdge};
    use crate::ir::core::Resources;

    fn chain_problem(n: usize) -> Problem {
        Problem {
            units: (0..n)
                .map(|i| Unit {
                    nodes: vec![i],
                    resources: Resources::new(2_000.0, 1_000.0, 0.0, 0.0, 0.0),
                    fixed_slot: None,
                    name: format!("u{i}"),
                })
                .collect(),
            edges: (0..n - 1)
                .map(|i| UnitEdge {
                    a: i,
                    b: i + 1,
                    width: 64,
                })
                .collect(),
            die_weight: 3.0,
        }
    }

    fn evaluator(p: &Problem, dev: &crate::device::model::VirtualDevice) -> CpuEvaluator {
        CpuEvaluator {
            model: CostModel::build(p, dev, 0.7, 1e-4),
        }
    }

    #[test]
    fn sa_finds_colocation_optimum() {
        let dev = builtin::by_name("u280").unwrap();
        let p = chain_problem(6);
        let mut ev = evaluator(&p, &dev);
        let r = anneal(&p, &dev, &mut ev, None, &SaConfig::default());
        // All small units fit one slot: optimal wirelength 0.
        assert_eq!(r.best_cost, 0.0, "best={:?}", r.best);
    }

    #[test]
    fn sa_improves_over_random_start() {
        let dev = builtin::by_name("u250").unwrap();
        let p = chain_problem(12);
        let mut ev = evaluator(&p, &dev);
        let bad: Vec<usize> = (0..12).map(|i| (i * 7) % dev.num_slots()).collect();
        let bad_cost = ev.model.cost_scalar(&bad);
        let r = anneal(&p, &dev, &mut ev, Some(&bad), &SaConfig::default());
        assert!(r.best_cost < bad_cost * 0.5, "{} vs {}", r.best_cost, bad_cost);
        // trace monotone non-increasing
        assert!(r.trace.windows(2).all(|w| w[1] <= w[0]));
        assert_eq!(r.trace.len(), SaConfig::default().steps);
    }

    #[test]
    fn pinned_units_stay_put() {
        let dev = builtin::by_name("u280").unwrap();
        let mut p = chain_problem(5);
        let pin = dev.slot_index(1, 2);
        p.units[2].fixed_slot = Some(pin);
        let mut ev = evaluator(&p, &dev);
        let r = anneal(&p, &dev, &mut ev, None, &SaConfig::default());
        assert_eq!(r.best[2], pin);
    }

    #[test]
    fn all_pinned_returns_initial_population_best() {
        let dev = builtin::by_name("u280").unwrap();
        let mut p = chain_problem(4);
        for (i, u) in p.units.iter_mut().enumerate() {
            u.fixed_slot = Some(i % 2);
        }
        let mut ev = evaluator(&p, &dev);
        let r = anneal(&p, &dev, &mut ev, None, &SaConfig::default());
        assert!(r.trace.is_empty());
        assert_eq!(r.evaluated, SaConfig::default().population);
        assert_eq!(r.best, vec![0, 1, 0, 1]);
    }

    #[test]
    fn deterministic_for_seed() {
        let dev = builtin::by_name("u280").unwrap();
        let p = chain_problem(8);
        let mut e1 = evaluator(&p, &dev);
        let mut e2 = evaluator(&p, &dev);
        let r1 = anneal(&p, &dev, &mut e1, None, &SaConfig::default());
        let r2 = anneal(&p, &dev, &mut e2, None, &SaConfig::default());
        assert_eq!(r1.best, r2.best);
        assert_eq!(r1.best_cost, r2.best_cost);
        assert_eq!(r1.trace, r2.trace);
    }

    #[test]
    fn distinct_pair_never_self_and_covers_all_pairs() {
        let mut rng = Rng::new(123);
        let n = 5;
        let mut seen = [[false; 5]; 5];
        for _ in 0..2000 {
            let (a, b) = distinct_pair(&mut rng, n);
            assert_ne!(a, b, "self-swap drawn");
            assert!(a < n && b < n);
            seen[a][b] = true;
        }
        for a in 0..n {
            for b in 0..n {
                assert_eq!(seen[a][b], a != b, "pair ({a},{b}) coverage");
            }
        }
    }

    /// A run resumed from a checkpoint at T1 steps must be bit-identical
    /// to a cold run at T2 > T1 — the prefix property that makes DSE
    /// warm-starts a pure wall-clock win.
    #[test]
    fn resume_matches_cold_bit_for_bit() {
        let dev = builtin::by_name("u250").unwrap();
        let p = chain_problem(12);
        let cold_cfg = SaConfig {
            steps: 200,
            ..Default::default()
        };
        let mut ev = evaluator(&p, &dev);
        let cold = anneal(&p, &dev, &mut ev, None, &cold_cfg);

        let short_cfg = SaConfig {
            steps: 80,
            ..Default::default()
        };
        let mut ev1 = evaluator(&p, &dev);
        let (short, ck) = anneal_resumable(&p, &dev, &mut ev1, None, &short_cfg, None);
        let ck = ck.expect("incremental lane must checkpoint");
        assert_eq!(ck.steps_done(), 80);
        assert_eq!(short.trace.len(), 80);
        // The short run is itself a bit-exact prefix of the cold run.
        assert_eq!(short.trace[..], cold.trace[..80]);

        let mut ev2 = evaluator(&p, &dev);
        let (resumed, ck2) = anneal_resumable(&p, &dev, &mut ev2, None, &cold_cfg, Some(&ck));
        assert_eq!(resumed.best, cold.best);
        assert_eq!(resumed.best_cost.to_bits(), cold.best_cost.to_bits());
        assert_eq!(resumed.evaluated, cold.evaluated);
        assert_eq!(
            resumed.trace.iter().map(|c| c.to_bits()).collect::<Vec<_>>(),
            cold.trace.iter().map(|c| c.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(ck2.unwrap().steps_done(), 200);
    }

    /// Worker count is a pure wall-clock knob across a resume boundary
    /// too: checkpoint serially, resume on 4 workers, equal bytes.
    #[test]
    fn resume_across_worker_counts_is_identical() {
        let dev = builtin::by_name("u250").unwrap();
        let p = chain_problem(10);
        let cold_cfg = SaConfig {
            steps: 150,
            ..Default::default()
        };
        let mut ev = evaluator(&p, &dev);
        let cold = anneal(&p, &dev, &mut ev, None, &cold_cfg);

        let mut ev1 = evaluator(&p, &dev);
        let (_, ck) = anneal_resumable(
            &p,
            &dev,
            &mut ev1,
            None,
            &SaConfig {
                steps: 60,
                ..Default::default()
            },
            None,
        );
        let mut ev2 = evaluator(&p, &dev);
        let (resumed, _) = anneal_resumable(
            &p,
            &dev,
            &mut ev2,
            None,
            &SaConfig {
                steps: 150,
                workers: 4,
                ..Default::default()
            },
            ck.as_ref(),
        );
        assert_eq!(resumed.best, cold.best);
        assert_eq!(resumed.best_cost.to_bits(), cold.best_cost.to_bits());
        assert_eq!(resumed.trace, cold.trace);
        assert_eq!(resumed.evaluated, cold.evaluated);
    }

    /// An incompatible checkpoint (different seed / knobs / initial) is
    /// ignored: the run falls back to a cold start.
    #[test]
    fn incompatible_checkpoint_falls_back_cold() {
        let dev = builtin::by_name("u250").unwrap();
        let p = chain_problem(8);
        let mut ev = evaluator(&p, &dev);
        let cfg = SaConfig {
            steps: 90,
            ..Default::default()
        };
        let cold = anneal(&p, &dev, &mut ev, None, &cfg);

        let other = SaConfig {
            steps: 40,
            seed: 0xBAD,
            ..Default::default()
        };
        let mut ev1 = evaluator(&p, &dev);
        let (_, foreign) = anneal_resumable(&p, &dev, &mut ev1, None, &other, None);
        let mut ev2 = evaluator(&p, &dev);
        let (r, _) = anneal_resumable(&p, &dev, &mut ev2, None, &cfg, foreign.as_ref());
        assert_eq!(r.best, cold.best);
        assert_eq!(r.best_cost.to_bits(), cold.best_cost.to_bits());
        assert_eq!(r.trace, cold.trace);

        // A checkpoint *ahead* of the target (steps_done > steps) is
        // also rejected; one exactly at the target resumes as a no-op.
        let mut ev3 = evaluator(&p, &dev);
        let (ahead, ck90) = anneal_resumable(&p, &dev, &mut ev3, None, &cfg, None);
        let mut ev4 = evaluator(&p, &dev);
        let (noop, _) = anneal_resumable(&p, &dev, &mut ev4, None, &cfg, ck90.as_ref());
        assert_eq!(noop.best, ahead.best);
        assert_eq!(noop.evaluated, ahead.evaluated);
        assert_eq!(noop.trace, ahead.trace);
        let short = SaConfig {
            steps: 40,
            ..Default::default()
        };
        let mut ev5 = evaluator(&p, &dev);
        let (back, _) = anneal_resumable(&p, &dev, &mut ev5, None, &short, ck90.as_ref());
        let mut ev6 = evaluator(&p, &dev);
        let cold40 = anneal(&p, &dev, &mut ev6, None, &short);
        assert_eq!(back.best, cold40.best, "rewind must run cold, not truncate");
        assert_eq!(back.trace, cold40.trace);
    }

    #[test]
    fn cmp_cost_f64_matches_f32_total_order() {
        assert_eq!(cmp_cost_f64(1.0, 2.0), Ordering::Less);
        assert_eq!(cmp_cost_f64(2.0, 1.0), Ordering::Greater);
        assert_eq!(cmp_cost_f64(1.0, 1.0), Ordering::Equal);
        assert_eq!(cmp_cost_f64(f64::NAN, 1.0), Ordering::Greater);
        assert_eq!(cmp_cost_f64(1.0, f64::NAN), Ordering::Less);
        assert_eq!(cmp_cost_f64(f64::NAN, f64::NAN), Ordering::Equal);
        assert_eq!(cmp_cost_f64(f64::NEG_INFINITY, f64::NAN), Ordering::Less);
    }

    #[test]
    fn cmp_cost_ranks_nan_last_and_is_total() {
        assert_eq!(cmp_cost(1.0, 2.0), Ordering::Less);
        assert_eq!(cmp_cost(2.0, 1.0), Ordering::Greater);
        assert_eq!(cmp_cost(1.0, 1.0), Ordering::Equal);
        assert_eq!(cmp_cost(f32::NAN, 1.0), Ordering::Greater);
        assert_eq!(cmp_cost(1.0, f32::NAN), Ordering::Less);
        assert_eq!(cmp_cost(f32::NAN, f32::NAN), Ordering::Equal);
        assert_eq!(cmp_cost(f32::NEG_INFINITY, f32::NAN), Ordering::Less);
        // pick_first_min never selects a NaN over a finite cost and is
        // first-wins on exact ties.
        assert_eq!(pick_first_min(&[f32::NAN, 3.0, 2.0, 2.0], 0, 4), 2);
        assert_eq!(pick_first_min(&[f32::NAN, f32::NAN], 0, 2), 0);
    }

    #[test]
    fn proposals_respect_movable_set() {
        let mut rng = Rng::new(7);
        let base = vec![0usize; 10];
        let movable = vec![1usize, 3, 5, 7];
        for _ in 0..500 {
            let p = propose(&mut rng, &base, &movable, 8);
            assert!(!p.is_empty());
            for &(u, s) in p.moves() {
                assert!(movable.contains(&(u as usize)), "pinned unit {u} moved");
                assert!((s as usize) < 8);
            }
        }
    }
}

//! Batched simulated-annealing floorplan explorer.
//!
//! Used by the Figure-12 design-space exploration and as a refinement /
//! fallback around the ILP: a population of candidate assignments is
//! mutated and re-scored *in batches* through a [`BatchEvaluator`] — the
//! CPU oracle or the AOT-compiled Pallas kernel via PJRT. Batching is
//! what makes the accelerator offload worthwhile: one `evaluate` call
//! scores `population × proposals` candidates in a single device launch.

use crate::device::model::VirtualDevice;
use crate::floorplan::cost::BatchEvaluator;
use crate::floorplan::problem::Problem;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SaConfig {
    pub seed: u64,
    /// Parallel annealing chains.
    pub population: usize,
    /// Proposals per chain per step (all scored in one batch).
    pub proposals: usize,
    pub steps: usize,
    pub t0: f64,
    pub cooling: f64,
}

impl Default for SaConfig {
    fn default() -> Self {
        SaConfig {
            seed: 0x5EED,
            population: 16,
            proposals: 8,
            steps: 300,
            t0: 2_000.0,
            cooling: 0.97,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SaResult {
    pub best: Vec<usize>,
    pub best_cost: f32,
    /// Candidates evaluated in total.
    pub evaluated: usize,
    /// Cost trace (best-so-far per step), for convergence plots.
    pub trace: Vec<f32>,
}

/// Run batched SA. `initial` seeds chain 0 (e.g. the ILP solution);
/// remaining chains start random. Pinned units never move.
pub fn anneal(
    problem: &Problem,
    dev: &VirtualDevice,
    evaluator: &mut dyn BatchEvaluator,
    initial: Option<&[usize]>,
    cfg: &SaConfig,
) -> SaResult {
    let nu = problem.units.len();
    let ns = dev.num_slots();
    let mut rng = Rng::new(cfg.seed);
    let movable: Vec<usize> = (0..nu)
        .filter(|&u| problem.units[u].fixed_slot.is_none())
        .collect();

    // Initial population.
    let mut chains: Vec<Vec<usize>> = (0..cfg.population)
        .map(|c| {
            if c == 0 {
                if let Some(init) = initial {
                    return init.to_vec();
                }
            }
            (0..nu)
                .map(|u| problem.units[u].fixed_slot.unwrap_or_else(|| rng.below(ns)))
                .collect()
        })
        .collect();
    let mut chain_costs = evaluator.evaluate(&chains);
    let mut evaluated = chains.len();

    let mut best_idx = argmin(&chain_costs);
    let mut best = chains[best_idx].clone();
    let mut best_cost = chain_costs[best_idx];

    let mut temp = cfg.t0;
    let mut trace = Vec::with_capacity(cfg.steps);
    if movable.is_empty() {
        return SaResult {
            best,
            best_cost,
            evaluated,
            trace,
        };
    }

    for _ in 0..cfg.steps {
        // Propose: population × proposals mutated candidates.
        let mut batch: Vec<Vec<usize>> = Vec::with_capacity(cfg.population * cfg.proposals);
        for chain in &chains {
            for _ in 0..cfg.proposals {
                let mut cand = chain.clone();
                // 1–2 random moves (or a swap).
                let moves = 1 + rng.below(2);
                for _ in 0..moves {
                    if rng.chance(0.3) && movable.len() >= 2 {
                        // swap two movable units
                        let a = *rng.pick(&movable);
                        let b = *rng.pick(&movable);
                        cand.swap(a, b);
                    } else {
                        let u = *rng.pick(&movable);
                        cand[u] = rng.below(ns);
                    }
                }
                batch.push(cand);
            }
        }
        let costs = evaluator.evaluate(&batch);
        evaluated += batch.len();

        // Per-chain: pick best proposal; Metropolis accept.
        for c in 0..cfg.population {
            let base = c * cfg.proposals;
            let mut pick = base;
            for k in base..base + cfg.proposals {
                if costs[k] < costs[pick] {
                    pick = k;
                }
            }
            let delta = (costs[pick] - chain_costs[c]) as f64;
            if delta <= 0.0 || rng.f64() < (-delta / temp).exp() {
                chains[c] = batch[pick].clone();
                chain_costs[c] = costs[pick];
                if chain_costs[c] < best_cost {
                    best_cost = chain_costs[c];
                    best = chains[c].clone();
                }
            }
        }
        temp *= cfg.cooling;
        trace.push(best_cost);
        let _ = best_idx;
        best_idx = argmin(&chain_costs);
    }

    SaResult {
        best,
        best_cost,
        evaluated,
        trace,
    }
}

fn argmin(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::floorplan::cost::{CostModel, CpuEvaluator};
    use crate::floorplan::problem::{Problem, Unit, UnitEdge};
    use crate::ir::core::Resources;

    fn chain_problem(n: usize) -> Problem {
        Problem {
            units: (0..n)
                .map(|i| Unit {
                    nodes: vec![i],
                    resources: Resources::new(2_000.0, 1_000.0, 0.0, 0.0, 0.0),
                    fixed_slot: None,
                    name: format!("u{i}"),
                })
                .collect(),
            edges: (0..n - 1)
                .map(|i| UnitEdge {
                    a: i,
                    b: i + 1,
                    width: 64,
                })
                .collect(),
            die_weight: 3.0,
        }
    }

    fn evaluator(p: &Problem, dev: &crate::device::model::VirtualDevice) -> CpuEvaluator {
        CpuEvaluator {
            model: CostModel::build(p, dev, 0.7, 1e-4),
        }
    }

    #[test]
    fn sa_finds_colocation_optimum() {
        let dev = builtin::by_name("u280").unwrap();
        let p = chain_problem(6);
        let mut ev = evaluator(&p, &dev);
        let r = anneal(&p, &dev, &mut ev, None, &SaConfig::default());
        // All small units fit one slot: optimal wirelength 0.
        assert_eq!(r.best_cost, 0.0, "best={:?}", r.best);
    }

    #[test]
    fn sa_improves_over_random_start() {
        let dev = builtin::by_name("u250").unwrap();
        let p = chain_problem(12);
        let mut ev = evaluator(&p, &dev);
        let bad: Vec<usize> = (0..12).map(|i| (i * 7) % dev.num_slots()).collect();
        let bad_cost = ev.model.cost_scalar(&bad);
        let r = anneal(&p, &dev, &mut ev, Some(&bad), &SaConfig::default());
        assert!(r.best_cost < bad_cost * 0.5, "{} vs {}", r.best_cost, bad_cost);
        // trace monotone non-increasing
        assert!(r.trace.windows(2).all(|w| w[1] <= w[0]));
    }

    #[test]
    fn pinned_units_stay_put() {
        let dev = builtin::by_name("u280").unwrap();
        let mut p = chain_problem(5);
        let pin = dev.slot_index(1, 2);
        p.units[2].fixed_slot = Some(pin);
        let mut ev = evaluator(&p, &dev);
        let r = anneal(&p, &dev, &mut ev, None, &SaConfig::default());
        assert_eq!(r.best[2], pin);
    }

    #[test]
    fn deterministic_for_seed() {
        let dev = builtin::by_name("u280").unwrap();
        let p = chain_problem(8);
        let mut e1 = evaluator(&p, &dev);
        let mut e2 = evaluator(&p, &dev);
        let r1 = anneal(&p, &dev, &mut e1, None, &SaConfig::default());
        let r2 = anneal(&p, &dev, &mut e2, None, &SaConfig::default());
        assert_eq!(r1.best, r2.best);
        assert_eq!(r1.best_cost, r2.best_cost);
    }
}

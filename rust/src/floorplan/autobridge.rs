//! AutoBridge-style ILP floorplanning (§3.4 stage 3).
//!
//! Binary variable `x[v][s]` assigns unit `v` to slot `s`. The wirelength
//! objective is linearized with per-edge |Δcol| / |Δrow| envelope
//! variables, where row coordinates are *die-weighted potentials*: row r
//! maps to `r + die_weight × (#boundaries below r)`, so |Δrow-potential|
//! is exactly `manhattan_rows + die_weight × die_crossings` — the same
//! metric the SA explorer and the Pallas kernel use. Constraints:
//!
//! * each unit in exactly one slot;
//! * per-slot resource capacity ≤ `util_limit` per kind (the knob Figure
//!   12 sweeps);
//! * pinned units respect their pin;
//! * an aggregate die-crossing budget approximates SLL capacity (exact
//!   per-column accounting is checked post-hoc by the router).

use crate::device::model::VirtualDevice;
use crate::floorplan::problem::Problem;
use crate::ilp::{self, BnbConfig, Cmp, IlpModel, Status};
use anyhow::{anyhow, Result};

#[derive(Debug, Clone)]
pub struct IlpFpConfig {
    /// Max per-slot utilization per resource kind (Fig 12's x-axis knob).
    pub util_limit: f64,
    /// Branch & bound node budget (the "400-second" analogue).
    pub max_nodes: usize,
    /// Max units the ILP accepts before coarsening kicks in.
    pub max_units: usize,
    /// Fraction of total SLL capacity the crossing budget allows.
    pub sll_budget_frac: f64,
}

impl Default for IlpFpConfig {
    fn default() -> Self {
        IlpFpConfig {
            util_limit: 0.70,
            max_nodes: 600,
            max_units: 12,
            sll_budget_frac: 0.9,
        }
    }
}

#[derive(Debug, Clone)]
pub struct FloorplanResult {
    /// Slot per problem unit.
    pub unit_slots: Vec<usize>,
    pub wirelength: f64,
    pub optimal: bool,
}

/// Solve the floorplan ILP, relaxing the utilization limit in +0.05 steps
/// (up to the router's give-up point) when the requested limit is
/// infeasible — mirroring how the Fig 12 exploration walks the knob.
pub fn solve(
    problem: &Problem,
    dev: &VirtualDevice,
    cfg: &IlpFpConfig,
) -> Result<FloorplanResult> {
    let mut limit = cfg.util_limit;
    loop {
        let mut attempt = cfg.clone();
        attempt.util_limit = limit;
        match solve_at(problem, dev, &attempt) {
            Ok(r) => return Ok(r),
            Err(_) if limit + 0.05 <= 0.90 + 1e-9 => {
                limit += 0.05;
            }
            // Still failing at the router's give-up point: surface the
            // last attempt's (typed-infeasible) error.
            Err(e) => return Err(e),
        }
    }
}

/// Single-shot ILP solve at exactly `cfg.util_limit`.
pub fn solve_at(
    problem: &Problem,
    dev: &VirtualDevice,
    cfg: &IlpFpConfig,
) -> Result<FloorplanResult> {
    let coarse = problem.coarsen(cfg.max_units);
    let ns = dev.num_slots();
    let nu = coarse.units.len();
    if nu == 0 {
        return Ok(FloorplanResult {
            unit_slots: Vec::new(),
            wirelength: 0.0,
            optimal: true,
        });
    }

    // Die-weighted row potential and plain column positions.
    let rowpot: Vec<f64> = (0..dev.rows)
        .map(|r| r as f64 + coarse.die_weight * dev.die_rows.iter().filter(|&&b| b < r).count() as f64)
        .collect();

    let mut m = IlpModel::new();
    // x[v][s]
    let mut x = vec![vec![0usize; ns]; nu];
    for (v, unit) in coarse.units.iter().enumerate() {
        for s in 0..ns {
            x[v][s] = m.binary(format!("x_{v}_{s}"));
        }
        // exactly one slot
        m.constraint(
            format!("assign_{v}"),
            (0..ns).map(|s| (x[v][s], 1.0)).collect(),
            Cmp::Eq,
            1.0,
        );
        // pinning
        if let Some(pin) = unit.fixed_slot {
            m.constraint(format!("pin_{v}"), vec![(x[v][pin], 1.0)], Cmp::Eq, 1.0);
        }
    }
    // per-slot resource limits
    for s in 0..ns {
        let cap = &dev.slots[s].capacity;
        for (k, kind) in crate::ir::core::Resources::kinds().iter().enumerate() {
            let capk = cap.get(kind);
            if capk <= 0.0 {
                continue;
            }
            let terms: Vec<(usize, f64)> = (0..nu)
                .map(|v| (x[v][s], coarse.units[v].resources.get(kind)))
                .filter(|(_, c)| *c > 0.0)
                .collect();
            if terms.is_empty() {
                continue;
            }
            m.constraint(
                format!("cap_{s}_{k}"),
                terms,
                Cmp::Le,
                cfg.util_limit * capk,
            );
        }
    }
    // per-edge |Δcol| and |Δrowpot| envelopes
    let col_of = |s: usize| dev.slots[s].x as f64;
    let row_of = |s: usize| rowpot[dev.slots[s].y];
    let max_pot = rowpot.last().copied().unwrap_or(0.0) + dev.cols as f64;
    let mut crossing_terms: Vec<(usize, f64)> = Vec::new();
    let mut env_vars: Vec<(usize, usize)> = Vec::with_capacity(coarse.edges.len());
    for (ei, e) in coarse.edges.iter().enumerate() {
        let dx = m.cont(format!("dx_{ei}"), 0.0, max_pot);
        let dy = m.cont(format!("dy_{ei}"), 0.0, max_pot);
        env_vars.push((dx, dy));
        // dx >= Xa - Xb and dx >= Xb - Xa, X = Σ col(s)·x[v][s]
        for sign in [1.0f64, -1.0] {
            let mut terms = vec![(dx, 1.0)];
            for s in 0..ns {
                terms.push((x[e.a][s], -sign * col_of(s)));
                terms.push((x[e.b][s], sign * col_of(s)));
            }
            m.constraint(format!("dxc_{ei}_{sign}"), terms, Cmp::Ge, 0.0);
            let mut terms = vec![(dy, 1.0)];
            for s in 0..ns {
                terms.push((x[e.a][s], -sign * row_of(s)));
                terms.push((x[e.b][s], sign * row_of(s)));
            }
            m.constraint(format!("dyc_{ei}_{sign}"), terms, Cmp::Ge, 0.0);
        }
        m.obj(dx, e.width as f64);
        m.obj(dy, e.width as f64);
        crossing_terms.push((dy, e.width as f64));
    }
    // aggregate SLL budget (die_weight scales each crossing's contribution
    // to dy, so divide it back out).
    if !dev.die_rows.is_empty() && coarse.die_weight > 0.0 {
        let budget = cfg.sll_budget_frac
            * (dev.sll_per_column * dev.cols as u64 * dev.die_rows.len() as u64) as f64
            * coarse.die_weight;
        m.constraint("sll_budget", crossing_terms, Cmp::Le, budget);
    }

    // Warm start: greedy feasible placement (B&B prunes against it from
    // node zero; budget exhaustion then still returns a decent plan).
    let initial = greedy_initial(&coarse, dev, cfg.util_limit).map(|slots| {
        let mut x0 = vec![0.0f64; m.num_vars()];
        for (v, &s) in slots.iter().enumerate() {
            x0[x[v][s]] = 1.0;
        }
        for (ei, e) in coarse.edges.iter().enumerate() {
            let (dxv, dyv) = env_vars[ei];
            x0[dxv] = (col_of(slots[e.a]) - col_of(slots[e.b])).abs();
            x0[dyv] = (row_of(slots[e.a]) - row_of(slots[e.b])).abs();
        }
        x0
    });
    let sol = ilp::solve(
        &m,
        &BnbConfig {
            max_nodes: cfg.max_nodes,
            rel_gap: 1e-6,
            initial,
        },
    );
    match sol.status {
        Status::Optimal | Status::Limit if sol.objective.is_finite() => {}
        Status::Unbounded => return Err(anyhow!("floorplan ILP unbounded (bug)")),
        _ => {
            // Typed so sweeps can classify "design does not fit at this
            // limit" (a data point) apart from internal flow errors. The
            // message bytes are the historical ones.
            return Err(anyhow::Error::new(super::Infeasible::new(format!(
                "floorplan ILP infeasible (or budget exhausted with no incumbent) at util_limit {}",
                cfg.util_limit
            ))))
        }
    }
    let mut coarse_slots = vec![0usize; nu];
    for v in 0..nu {
        coarse_slots[v] = (0..ns)
            .max_by(|&a, &b| sol.x[x[v][a]].partial_cmp(&sol.x[x[v][b]]).unwrap())
            .unwrap();
    }
    // Expand coarse assignment to the original problem's units.
    let node_slots = coarse.expand(
        &coarse_slots,
        problem.units.iter().flat_map(|u| u.nodes.iter()).count(),
    );
    // original problem units are 1:1 with nodes (pre-coarsening), so map
    // via each unit's first node.
    let unit_slots: Vec<usize> = problem
        .units
        .iter()
        .map(|u| node_slots[u.nodes[0]])
        .collect();
    let wirelength = problem.wirelength(&unit_slots, dev);
    Ok(FloorplanResult {
        unit_slots,
        wirelength,
        optimal: sol.status == Status::Optimal,
    })
}

/// Greedy feasible placement: heaviest-connected units first, each into
/// the capacity-feasible slot minimizing incremental wirelength to its
/// already-placed neighbours (utilization as tie-break).
fn greedy_initial(
    problem: &Problem,
    dev: &VirtualDevice,
    util_limit: f64,
) -> Option<Vec<usize>> {
    use crate::ir::core::Resources;
    let nu = problem.units.len();
    let ns = dev.num_slots();
    let mut adj: Vec<Vec<(usize, u64)>> = vec![Vec::new(); nu];
    for e in &problem.edges {
        adj[e.a].push((e.b, e.width));
        adj[e.b].push((e.a, e.width));
    }
    let mut order: Vec<usize> = (0..nu).collect();
    order.sort_by_key(|&v| {
        std::cmp::Reverse(adj[v].iter().map(|(_, w)| *w).sum::<u64>())
    });
    let mut slot_of = vec![usize::MAX; nu];
    let mut used = vec![Resources::ZERO; ns];
    for &v in &order {
        if let Some(pin) = problem.units[v].fixed_slot {
            slot_of[v] = pin;
            used[pin] = used[pin].add(&problem.units[v].resources);
            if used[pin].max_util(&dev.slots[pin].capacity) > util_limit + 1e-9 {
                return None; // pinned unit cannot fit
            }
        }
    }
    for &v in &order {
        if slot_of[v] != usize::MAX {
            continue;
        }
        let mut best = usize::MAX;
        let mut best_cost = f64::INFINITY;
        for s in 0..ns {
            let u = used[s]
                .add(&problem.units[v].resources)
                .max_util(&dev.slots[s].capacity);
            if u > util_limit {
                continue;
            }
            let mut wl = 0.0;
            for &(nb, w) in &adj[v] {
                if slot_of[nb] != usize::MAX {
                    let (man, dies) = dev.slot_dist(s, slot_of[nb]);
                    wl += w as f64 * (man as f64 + problem.die_weight * dies as f64);
                }
            }
            let cost = wl + 0.1 * u;
            if cost < best_cost {
                best_cost = cost;
                best = s;
            }
        }
        if best == usize::MAX {
            return None;
        }
        slot_of[v] = best;
        used[best] = used[best].add(&problem.units[v].resources);
    }
    Some(slot_of)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::floorplan::problem::{Problem, Unit, UnitEdge};
    use crate::ir::core::Resources;

    fn unit(name: &str, lut: f64) -> Unit {
        Unit {
            nodes: vec![],
            resources: Resources::new(lut, lut, 0.0, 0.0, 0.0),
            fixed_slot: None,
            name: name.into(),
        }
    }

    fn chain(n: usize, lut: f64, width: u64) -> Problem {
        let mut units: Vec<Unit> = (0..n).map(|i| unit(&format!("u{i}"), lut)).collect();
        for (i, u) in units.iter_mut().enumerate() {
            u.nodes = vec![i];
        }
        Problem {
            units,
            edges: (0..n - 1)
                .map(|i| UnitEdge {
                    a: i,
                    b: i + 1,
                    width,
                })
                .collect(),
            die_weight: 3.0,
        }
    }

    #[test]
    fn small_chain_colocates_when_it_fits() {
        let dev = builtin::by_name("u280").unwrap();
        let p = chain(4, 5_000.0, 64);
        let r = solve(&p, &dev, &IlpFpConfig::default()).unwrap();
        assert_eq!(r.wirelength, 0.0, "{:?}", r.unit_slots);
    }

    #[test]
    fn oversized_units_spread_across_slots() {
        let dev = builtin::by_name("u280").unwrap();
        // Each unit ~60% of a slot at util_limit 0.7: one per slot.
        let cap = dev.slots[5].capacity.lut;
        let p = chain(4, cap * 0.6, 32);
        let r = solve(&p, &dev, &IlpFpConfig::default()).unwrap();
        let mut slots = r.unit_slots.clone();
        slots.sort();
        slots.dedup();
        assert_eq!(slots.len(), 4, "each unit its own slot: {:?}", r.unit_slots);
        // Chain should occupy adjacent slots (wirelength small).
        assert!(r.wirelength <= 32.0 * (3.0 + 3.0 * 2.0) + 1.0, "{}", r.wirelength);
    }

    #[test]
    fn pinned_unit_respected() {
        let dev = builtin::by_name("u250").unwrap();
        let mut p = chain(3, 1000.0, 16);
        let pin = dev.slot_index(1, 3);
        p.units[0].fixed_slot = Some(pin);
        let r = solve(&p, &dev, &IlpFpConfig::default()).unwrap();
        assert_eq!(r.unit_slots[0], pin);
        // Others follow to minimize wirelength.
        assert_eq!(r.unit_slots[1], pin);
    }

    #[test]
    fn util_limit_infeasible_when_too_tight() {
        let dev = builtin::by_name("u280").unwrap();
        let cap = dev.slots[5].capacity.lut;
        // 7 units of 60% on 6 slots at limit 0.7: pigeonhole infeasible.
        let p = chain(7, cap * 0.6, 8);
        let cfg = IlpFpConfig {
            util_limit: 0.70,
            max_nodes: 2_000,
            ..Default::default()
        };
        let err = solve_at(&p, &dev, &cfg).unwrap_err();
        // Typed as design infeasibility (the legacy message bytes), so
        // sweeps can classify it as an unroutable data point.
        assert!(
            err.downcast_ref::<crate::floorplan::Infeasible>().is_some(),
            "{err:#}"
        );
        assert!(
            format!("{err}").starts_with("floorplan ILP infeasible"),
            "{err}"
        );
    }

    #[test]
    fn coarsening_path_used_for_many_units() {
        let dev = builtin::by_name("u250").unwrap();
        let p = chain(60, 2_000.0, 16);
        let cfg = IlpFpConfig {
            max_units: 12,
            max_nodes: 5_000,
            ..Default::default()
        };
        let r = solve(&p, &dev, &cfg).unwrap();
        assert_eq!(r.unit_slots.len(), 60);
        // Feasible: per-slot LUT within limit.
        let mut per_slot = vec![0.0f64; dev.num_slots()];
        for (u, &s) in p.units.iter().zip(&r.unit_slots) {
            per_slot[s] += u.resources.lut;
        }
        for (s, &used) in per_slot.iter().enumerate() {
            assert!(
                used <= 0.7 * dev.slots[s].capacity.lut + 1e-6,
                "slot {s} over: {used}"
            );
        }
    }
}

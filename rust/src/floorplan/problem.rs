//! Floorplanning problem extraction: from a flat netlist to the
//! unit/edge abstraction the ILP and SA engines consume, including the
//! coarsening step that merges small units into clusters (AutoBridge
//! floorplans coarse-grained *partitions*, not individual cells).

use crate::device::model::VirtualDevice;
use crate::ir::core::Resources;
use crate::timing::netlist::FlatNetlist;
use std::collections::BTreeMap;

/// A floorplannable unit (one or more netlist nodes).
#[derive(Debug, Clone)]
pub struct Unit {
    /// Netlist node indices merged into this unit.
    pub nodes: Vec<usize>,
    pub resources: Resources,
    /// Slot index this unit is pinned to, if any.
    pub fixed_slot: Option<usize>,
    pub name: String,
}

/// An undirected edge between units with total bit width.
#[derive(Debug, Clone, PartialEq)]
pub struct UnitEdge {
    pub a: usize,
    pub b: usize,
    pub width: u64,
}

/// The floorplanning instance.
#[derive(Debug, Clone)]
pub struct Problem {
    pub units: Vec<Unit>,
    pub edges: Vec<UnitEdge>,
    /// Per-slot distance = manhattan + die_weight × crossings.
    pub die_weight: f64,
}

impl Problem {
    /// One unit per netlist node. Pblock names resolve to slot indices
    /// through a prebuilt map (first occurrence wins, matching the
    /// historical linear scan) instead of rescanning `dev.slots` per node.
    pub fn from_netlist(nl: &FlatNetlist, dev: &VirtualDevice, die_weight: f64) -> Problem {
        let mut slot_by_pblock: BTreeMap<&str, usize> = BTreeMap::new();
        for (i, s) in dev.slots.iter().enumerate() {
            slot_by_pblock.entry(&s.pblock).or_insert(i);
        }
        let units = nl
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| Unit {
                nodes: vec![i],
                resources: n.resources,
                fixed_slot: n
                    .fixed_slot
                    .as_deref()
                    .and_then(|pb| slot_by_pblock.get(pb).copied()),
                name: n.path.clone(),
            })
            .collect();
        let mut agg: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        for e in &nl.edges {
            let (a, b) = if e.src < e.dst {
                (e.src, e.dst)
            } else {
                (e.dst, e.src)
            };
            if a != b {
                *agg.entry((a, b)).or_default() += e.width;
            }
        }
        Problem {
            units,
            edges: agg
                .into_iter()
                .map(|((a, b), width)| UnitEdge { a, b, width })
                .collect(),
            die_weight,
        }
    }

    /// Greedy coarsening: repeatedly merge the lightest unit into its
    /// most-connected neighbour until at most `max_units` remain. Units
    /// pinned to different slots are never merged; a merged cluster keeps
    /// a pin if any member had one.
    pub fn coarsen(&self, max_units: usize) -> Problem {
        let n = self.units.len();
        if n <= max_units {
            return self.clone();
        }
        // cluster id per original unit
        let mut cluster: Vec<usize> = (0..n).collect();
        let mut cl_res: Vec<Resources> = self.units.iter().map(|u| u.resources).collect();
        let mut cl_fixed: Vec<Option<usize>> = self.units.iter().map(|u| u.fixed_slot).collect();
        let mut cl_alive: Vec<bool> = vec![true; n];
        let mut alive_count = n;
        // adjacency: (neighbor cluster, width)
        let mut adj: Vec<BTreeMap<usize, u64>> = vec![BTreeMap::new(); n];
        for e in &self.edges {
            *adj[e.a].entry(e.b).or_default() += e.width;
            *adj[e.b].entry(e.a).or_default() += e.width;
        }
        let key = |r: &Resources| r.lut + r.ff * 0.5 + r.dsp * 80.0 + r.bram * 100.0 + r.uram * 300.0;
        while alive_count > max_units {
            // lightest alive cluster with at least one neighbour
            let Some(light) = (0..n)
                .filter(|&c| cl_alive[c] && !adj[c].is_empty())
                .min_by(|&a, &b| key(&cl_res[a]).partial_cmp(&key(&cl_res[b])).unwrap())
            else {
                break;
            };
            // strongest neighbour compatible by pinning
            let Some((&nb, _)) = adj[light]
                .iter()
                .filter(|(&nb, _)| {
                    cl_alive[nb]
                        && match (cl_fixed[light], cl_fixed[nb]) {
                            (Some(a), Some(b)) => a == b,
                            _ => true,
                        }
                })
                .max_by_key(|(_, &w)| w)
            else {
                // cannot merge this one; detach it from consideration
                adj[light].clear();
                continue;
            };
            // merge light into nb
            cl_res[nb] = cl_res[nb].add(&cl_res[light]);
            if cl_fixed[nb].is_none() {
                cl_fixed[nb] = cl_fixed[light];
            }
            cl_alive[light] = false;
            alive_count -= 1;
            let light_adj = std::mem::take(&mut adj[light]);
            for (other, w) in light_adj {
                if other == nb || !cl_alive[other] {
                    adj[other].remove(&light);
                    continue;
                }
                *adj[nb].entry(other).or_default() += w;
                let ow = adj[other].remove(&light).unwrap_or(w);
                *adj[other].entry(nb).or_default() += ow;
            }
            adj[nb].remove(&light);
            for c in cluster.iter_mut() {
                if *c == light {
                    *c = nb;
                }
            }
        }
        // compact clusters
        let mut remap: BTreeMap<usize, usize> = BTreeMap::new();
        let mut units = Vec::new();
        for (i, &c) in cluster.iter().enumerate() {
            let id = *remap.entry(c).or_insert_with(|| {
                units.push(Unit {
                    nodes: Vec::new(),
                    resources: cl_res[c],
                    fixed_slot: cl_fixed[c],
                    name: self.units[c].name.clone(),
                });
                units.len() - 1
            });
            units[id].nodes.extend(self.units[i].nodes.iter().copied());
        }
        let mut agg: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        for e in &self.edges {
            let (a, b) = (remap[&cluster[e.a]], remap[&cluster[e.b]]);
            if a != b {
                let k = if a < b { (a, b) } else { (b, a) };
                *agg.entry(k).or_default() += e.width;
            }
        }
        Problem {
            units,
            edges: agg
                .into_iter()
                .map(|((a, b), width)| UnitEdge { a, b, width })
                .collect(),
            die_weight: self.die_weight,
        }
    }

    /// Expand a per-unit slot assignment back to per-netlist-node slots.
    pub fn expand(&self, unit_slots: &[usize], num_nodes: usize) -> Vec<usize> {
        let mut out = vec![0usize; num_nodes];
        for (u, &s) in self.units.iter().zip(unit_slots) {
            for &node in &u.nodes {
                out[node] = s;
            }
        }
        out
    }

    /// Wirelength of an assignment under the device's distance metric.
    pub fn wirelength(&self, slots: &[usize], dev: &VirtualDevice) -> f64 {
        self.edges
            .iter()
            .map(|e| {
                let (man, dies) = dev.slot_dist(slots[e.a], slots[e.b]);
                e.width as f64 * (man as f64 + self.die_weight * dies as f64)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::timing::netlist::{FlatEdge, FlatNode};

    fn netlist(n: usize) -> FlatNetlist {
        FlatNetlist {
            nodes: (0..n)
                .map(|i| FlatNode {
                    path: format!("n{i}"),
                    module: "M".into(),
                    resources: Resources::new(1000.0 * (i as f64 + 1.0), 0.0, 0.0, 0.0, 0.0),
                    internal_ns: 2.0,
                    is_pipeline: false,
                    fixed_slot: None,
                })
                .collect(),
            edges: (0..n - 1)
                .map(|i| FlatEdge {
                    src: i,
                    dst: i + 1,
                    width: 32,
                    pipelinable: true,
                })
                .collect(),
        }
    }

    #[test]
    fn from_netlist_builds_units() {
        let dev = builtin::by_name("u250").unwrap();
        let p = Problem::from_netlist(&netlist(5), &dev, 3.0);
        assert_eq!(p.units.len(), 5);
        assert_eq!(p.edges.len(), 4);
    }

    #[test]
    fn coarsen_reduces_units_and_conserves_resources() {
        let dev = builtin::by_name("u250").unwrap();
        let p = Problem::from_netlist(&netlist(20), &dev, 3.0);
        let total_before: f64 = p.units.iter().map(|u| u.resources.lut).sum();
        let c = p.coarsen(6);
        assert!(c.units.len() <= 6);
        let total_after: f64 = c.units.iter().map(|u| u.resources.lut).sum();
        assert!((total_before - total_after).abs() < 1e-6);
        // Every original node represented exactly once.
        let mut all: Vec<usize> = c.units.iter().flat_map(|u| u.nodes.clone()).collect();
        all.sort();
        assert_eq!(all, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn expand_maps_back() {
        let dev = builtin::by_name("u250").unwrap();
        let p = Problem::from_netlist(&netlist(10), &dev, 3.0);
        let c = p.coarsen(3);
        let slots: Vec<usize> = (0..c.units.len()).map(|i| i % 4).collect();
        let full = c.expand(&slots, 10);
        assert_eq!(full.len(), 10);
        for (u, &s) in c.units.iter().zip(&slots) {
            for &nidx in &u.nodes {
                assert_eq!(full[nidx], s);
            }
        }
    }

    #[test]
    fn wirelength_counts_die_crossings() {
        let dev = builtin::by_name("u280").unwrap();
        let p = Problem::from_netlist(&netlist(2), &dev, 3.0);
        let a = dev.slot_index(0, 0);
        let b = dev.slot_index(0, 1);
        let wl = p.wirelength(&[a, b], &dev);
        // 1 crossing: width 32 × (1 + 3×1)
        assert_eq!(wl, 128.0);
    }

    #[test]
    fn coarsen_respects_conflicting_pins() {
        let dev = builtin::by_name("u250").unwrap();
        let mut nl = netlist(4);
        nl.nodes[0].fixed_slot = Some("SLOT_X0Y0".into());
        nl.nodes[3].fixed_slot = Some("SLOT_X1Y3".into());
        let p = Problem::from_netlist(&nl, &dev, 3.0);
        let c = p.coarsen(2);
        // The two pinned nodes must be in different clusters.
        let find = |n: usize| c.units.iter().position(|u| u.nodes.contains(&n)).unwrap();
        assert_ne!(find(0), find(3));
    }
}

//! Batched floorplan-candidate cost model — CPU oracle of the L1 Pallas
//! kernel (`python/compile/kernels/floorplan_cost.py`).
//!
//! Contract (all f32, shared verbatim with the kernel and ref.py):
//!
//! ```text
//! inputs  C    [M, M]  symmetric connectivity (bit widths), zero diag
//!         D    [S, S]  slot distance (manhattan + die_w × crossings)
//!         R    [M, K]  unit resources, K = 5 (LUT FF BRAM DSP URAM)
//!         caps [S, K]  slot capacity × util_limit
//!         A    [B, M, S] one-hot assignment batch
//! output  cost [B] = 0.5 · Σ (C@A ⊙ A@D)  +  λ · Σ relu(AᵀR − caps)²
//! ```
//!
//! The wirelength term uses the identity
//! `Σᵢⱼ C[i,j]·(A D Aᵀ)[i,j] = Σ (C@A) ⊙ (A@D)` — two MXU matmuls per
//! candidate instead of a gather.

use crate::device::model::VirtualDevice;
use crate::floorplan::problem::Problem;

pub const NUM_KINDS: usize = 5;

/// Dense, padded instance of the cost model.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Padded unit count (multiple of 8 for MXU friendliness).
    pub m: usize,
    /// Real unit count (≤ m).
    pub m_real: usize,
    /// Slot count (not padded; S is small).
    pub s: usize,
    pub conn: Vec<f32>,
    pub dist: Vec<f32>,
    pub res: Vec<f32>,
    pub caps: Vec<f32>,
    /// Penalty weight λ.
    pub lambda: f32,
    /// Sparse (i, j, weight) upper-triangle edges — the CPU fast path.
    pub edges_sparse: Vec<(u32, u32, f32)>,
}

impl CostModel {
    pub fn build(
        problem: &Problem,
        dev: &VirtualDevice,
        util_limit: f64,
        lambda: f32,
    ) -> CostModel {
        let m_real = problem.units.len();
        let m = m_real.div_ceil(8) * 8;
        let s = dev.num_slots();
        let mut conn = vec![0f32; m * m];
        for e in &problem.edges {
            conn[e.a * m + e.b] += e.width as f32;
            conn[e.b * m + e.a] += e.width as f32;
        }
        let dist = {
            let d = dev.distance_matrix(problem.die_weight as f32);
            debug_assert_eq!(d.len(), s * s);
            d
        };
        let mut res = vec![0f32; m * NUM_KINDS];
        for (i, u) in problem.units.iter().enumerate() {
            res[i * NUM_KINDS] = u.resources.lut as f32;
            res[i * NUM_KINDS + 1] = u.resources.ff as f32;
            res[i * NUM_KINDS + 2] = u.resources.bram as f32;
            res[i * NUM_KINDS + 3] = u.resources.dsp as f32;
            res[i * NUM_KINDS + 4] = u.resources.uram as f32;
        }
        let mut caps = vec![0f32; s * NUM_KINDS];
        for (si, slot) in dev.slots.iter().enumerate() {
            caps[si * NUM_KINDS] = (slot.capacity.lut * util_limit) as f32;
            caps[si * NUM_KINDS + 1] = (slot.capacity.ff * util_limit) as f32;
            caps[si * NUM_KINDS + 2] = (slot.capacity.bram * util_limit) as f32;
            caps[si * NUM_KINDS + 3] = (slot.capacity.dsp * util_limit) as f32;
            caps[si * NUM_KINDS + 4] = (slot.capacity.uram * util_limit) as f32;
        }
        // Upper-triangle nonzeros of the (already aggregated) matrix —
        // built from `conn` so duplicate edge entries cannot double-count.
        let mut edges_sparse = Vec::new();
        for a in 0..m_real {
            for b in (a + 1)..m_real {
                let c = conn[a * m + b];
                if c != 0.0 {
                    edges_sparse.push((a as u32, b as u32, c));
                }
            }
        }
        CostModel {
            m,
            m_real,
            s,
            conn,
            dist,
            res,
            caps,
            lambda,
            edges_sparse,
        }
    }

    /// One-hot encode a batch of assignments (slot id per real unit;
    /// padded units pinned to slot 0 with zero resources/connectivity, so
    /// they never affect the cost).
    pub fn onehot(&self, batch: &[Vec<usize>]) -> Vec<f32> {
        let (m, s) = (self.m, self.s);
        let mut a = vec![0f32; batch.len() * m * s];
        for (b, cand) in batch.iter().enumerate() {
            assert_eq!(cand.len(), self.m_real);
            for i in 0..m {
                let slot = if i < self.m_real { cand[i] } else { 0 };
                a[b * m * s + i * s + slot] = 1.0;
            }
        }
        a
    }

    /// Scalar cost of one candidate — sparse edge iteration (the CPU fast
    /// path; identical math to the dense/batched form).
    pub fn cost_scalar(&self, cand: &[usize]) -> f32 {
        let mut wl = 0f32;
        for &(i, j, c) in &self.edges_sparse {
            wl += c * self.dist[cand[i as usize] * self.s + cand[j as usize]];
        }
        let mut usage = vec![0f32; self.s * NUM_KINDS];
        for (i, &slot) in cand.iter().enumerate() {
            for k in 0..NUM_KINDS {
                usage[slot * NUM_KINDS + k] += self.res[i * NUM_KINDS + k];
            }
        }
        let mut pen = 0f32;
        for (u, c) in usage.iter().zip(&self.caps) {
            let over = (u - c).max(0.0);
            pen += over * over;
        }
        wl + self.lambda * pen
    }

    /// Batched cost via the matmul identity — numerically the same
    /// computation the Pallas kernel performs.
    pub fn cost_batch(&self, a_onehot: &[f32], batch: usize) -> Vec<f32> {
        let (m, s) = (self.m, self.s);
        assert_eq!(a_onehot.len(), batch * m * s);
        let mut out = Vec::with_capacity(batch);
        // scratch
        let mut ca = vec![0f32; m * s];
        let mut ad = vec![0f32; m * s];
        let mut usage = vec![0f32; s * NUM_KINDS];
        for b in 0..batch {
            let a = &a_onehot[b * m * s..(b + 1) * m * s];
            // CA = C (M×M) @ A (M×S)
            matmul(&self.conn, a, &mut ca, m, m, s);
            // AD = A (M×S) @ D (S×S)
            matmul(a, &self.dist, &mut ad, m, s, s);
            let wl: f32 = ca.iter().zip(&ad).map(|(x, y)| x * y).sum();
            // usage = Aᵀ (S×M) @ R (M×K)
            usage.iter_mut().for_each(|u| *u = 0.0);
            for i in 0..m {
                for sl in 0..s {
                    let av = a[i * s + sl];
                    if av != 0.0 {
                        for k in 0..NUM_KINDS {
                            usage[sl * NUM_KINDS + k] += av * self.res[i * NUM_KINDS + k];
                        }
                    }
                }
            }
            let pen: f32 = usage
                .iter()
                .zip(&self.caps)
                .map(|(u, c)| {
                    let over = (u - c).max(0.0);
                    over * over
                })
                .sum();
            out.push(0.5 * wl + self.lambda * pen);
        }
        out
    }
}

fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    c.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av != 0.0 {
                let brow = &b[kk * n..kk * n + n];
                let crow = &mut c[i * n..i * n + n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }
}

/// Batch evaluator abstraction: CPU oracle or the PJRT executable.
pub trait BatchEvaluator {
    /// Evaluate a batch of candidates (slot id per real unit each).
    fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32>;
    fn name(&self) -> &'static str;
}

/// CPU implementation of [`BatchEvaluator`].
///
/// §Perf note: on a CPU the *sparse* scalar formula (iterate edges, not
/// the dense M×M matrix) beats the matmul identity by ~3-5x — the dense
/// form exists because it is what maps onto the MXU. `evaluate` therefore
/// uses the scalar path; `CostModel::cost_batch` remains the bit-level
/// oracle of the Pallas kernel (and is what the PJRT comparison tests
/// check against — scalar, dense and kernel agree within f32 tolerance).
pub struct CpuEvaluator {
    pub model: CostModel,
}

impl BatchEvaluator for CpuEvaluator {
    fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32> {
        batch.iter().map(|c| self.model.cost_scalar(c)).collect()
    }
    fn name(&self) -> &'static str {
        "cpu"
    }
}

/// Dense-matmul evaluator — the exact computation the Pallas kernel runs,
/// on the CPU. Used by tests and by the perf bench as the kernel oracle.
pub struct DenseCpuEvaluator {
    pub model: CostModel,
}

impl BatchEvaluator for DenseCpuEvaluator {
    fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32> {
        let a = self.model.onehot(batch);
        self.model.cost_batch(&a, batch.len())
    }
    fn name(&self) -> &'static str {
        "cpu-dense"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::floorplan::problem::{Problem, Unit, UnitEdge};
    use crate::ir::core::Resources;
    use crate::util::rng::Rng;

    fn problem(n: usize) -> Problem {
        let mut units: Vec<Unit> = (0..n)
            .map(|i| Unit {
                nodes: vec![i],
                resources: Resources::new(
                    1000.0 + 137.0 * i as f64,
                    500.0,
                    2.0,
                    8.0,
                    0.0,
                ),
                fixed_slot: None,
                name: format!("u{i}"),
            })
            .collect();
        units[0].resources.lut = 50_000.0;
        Problem {
            units,
            edges: (0..n - 1)
                .map(|i| UnitEdge {
                    a: i,
                    b: i + 1,
                    width: 32 + (i as u64 % 5) * 16,
                })
                .collect(),
            die_weight: 3.0,
        }
    }

    #[test]
    fn batch_matches_scalar() {
        let dev = builtin::by_name("u280").unwrap();
        let p = problem(13);
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut rng = Rng::new(5);
        let batch: Vec<Vec<usize>> = (0..16)
            .map(|_| (0..13).map(|_| rng.below(cm.s)).collect())
            .collect();
        let scalar: Vec<f32> = batch.iter().map(|c| cm.cost_scalar(c)).collect();
        let a = cm.onehot(&batch);
        let batched = cm.cost_batch(&a, 16);
        for (s, b) in scalar.iter().zip(&batched) {
            assert!(
                (s - b).abs() <= 1e-3 * s.abs().max(1.0),
                "scalar {s} vs batch {b}"
            );
        }
    }

    #[test]
    fn colocations_cheaper_than_spread_when_no_overflow() {
        let dev = builtin::by_name("u280").unwrap();
        let p = problem(4);
        let cm = CostModel::build(&p, &dev, 0.9, 1e-4);
        let together = cm.cost_scalar(&[0, 0, 0, 0]);
        let apart = cm.cost_scalar(&[0, 5, 0, 5]);
        assert!(together < apart);
    }

    #[test]
    fn overflow_penalized() {
        let dev = builtin::by_name("u280").unwrap();
        let mut p = problem(4);
        // make every unit huge
        for u in &mut p.units {
            u.resources.lut = dev.slots[0].capacity.lut * 0.5;
        }
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let stacked = cm.cost_scalar(&[0, 0, 0, 0]);
        let spread = cm.cost_scalar(&[0, 1, 2, 3]);
        assert!(stacked > spread, "stacked {stacked} spread {spread}");
    }

    #[test]
    fn padding_is_neutral() {
        let dev = builtin::by_name("u250").unwrap();
        let p = problem(5); // padded to m=8
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        assert_eq!(cm.m, 8);
        let cand = vec![1, 2, 3, 4, 5];
        let a = cm.onehot(&[cand.clone()]);
        let batched = cm.cost_batch(&a, 1)[0];
        let scalar = cm.cost_scalar(&cand);
        assert!((batched - scalar).abs() <= 1e-3 * scalar.max(1.0));
    }

    #[test]
    fn cpu_evaluator_wraps_model() {
        let dev = builtin::by_name("u250").unwrap();
        let p = problem(6);
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut ev = CpuEvaluator { model: cm };
        let costs = ev.evaluate(&[vec![0; 6], vec![7; 6]]);
        assert_eq!(costs.len(), 2);
        assert!(costs.iter().all(|c| c.is_finite()));
    }
}

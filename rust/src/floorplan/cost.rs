//! Batched floorplan-candidate cost model — CPU oracle of the L1 Pallas
//! kernel (`python/compile/kernels/floorplan_cost.py`).
//!
//! Contract (all f32, shared verbatim with the kernel and ref.py):
//!
//! ```text
//! inputs  C    [M, M]  symmetric connectivity (bit widths), zero diag
//!         D    [S, S]  slot distance (manhattan + die_w × crossings)
//!         R    [M, K]  unit resources, K = 5 (LUT FF BRAM DSP URAM)
//!         caps [S, K]  slot capacity × util_limit
//!         A    [B, M, S] one-hot assignment batch
//! output  cost [B] = 0.5 · Σ (C@A ⊙ A@D)  +  λ · Σ relu(AᵀR − caps)²
//! ```
//!
//! The wirelength term uses the identity
//! `Σᵢⱼ C[i,j]·(A D Aᵀ)[i,j] = Σ (C@A) ⊙ (A@D)` — two MXU matmuls per
//! candidate instead of a gather.
//!
//! Besides the dense/batched form (the Pallas kernel's math) and the
//! sparse scalar form (`cost_scalar`), the model carries a per-unit CSR
//! adjacency that powers [`ScoredState`]: a candidate plus its cached
//! wirelength, per-slot resource usage and per-slot penalty terms, on
//! which a move/swap costs O(deg(u) + S·K) instead of a full re-score.
//! This is the SA explorer's fast lane; see the module docs on
//! [`ScoredState`] for the exactness contract.

use crate::device::model::VirtualDevice;
use crate::floorplan::problem::Problem;

pub const NUM_KINDS: usize = 5;

/// Dense, padded instance of the cost model.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Padded unit count (multiple of 8 for MXU friendliness).
    pub m: usize,
    /// Real unit count (≤ m).
    pub m_real: usize,
    /// Slot count (not padded; S is small).
    pub s: usize,
    pub conn: Vec<f32>,
    pub dist: Vec<f32>,
    pub res: Vec<f32>,
    pub caps: Vec<f32>,
    /// Penalty weight λ.
    pub lambda: f32,
    /// Sparse (i, j, weight) upper-triangle edges — the CPU fast path.
    pub edges_sparse: Vec<(u32, u32, f32)>,
    /// CSR row offsets of the per-unit adjacency (`m_real + 1` entries).
    pub adj_off: Vec<u32>,
    /// CSR neighbor unit per adjacency entry (each undirected edge
    /// appears in both endpoints' rows).
    pub adj_unit: Vec<u32>,
    /// CSR edge weight per adjacency entry (same order as `adj_unit`).
    pub adj_w: Vec<f32>,
}

impl CostModel {
    pub fn build(
        problem: &Problem,
        dev: &VirtualDevice,
        util_limit: f64,
        lambda: f32,
    ) -> CostModel {
        let m_real = problem.units.len();
        let m = m_real.div_ceil(8) * 8;
        let s = dev.num_slots();
        let mut conn = vec![0f32; m * m];
        for e in &problem.edges {
            conn[e.a * m + e.b] += e.width as f32;
            conn[e.b * m + e.a] += e.width as f32;
        }
        let dist = {
            let d = dev.distance_matrix(problem.die_weight as f32);
            debug_assert_eq!(d.len(), s * s);
            d
        };
        let mut res = vec![0f32; m * NUM_KINDS];
        for (i, u) in problem.units.iter().enumerate() {
            res[i * NUM_KINDS] = u.resources.lut as f32;
            res[i * NUM_KINDS + 1] = u.resources.ff as f32;
            res[i * NUM_KINDS + 2] = u.resources.bram as f32;
            res[i * NUM_KINDS + 3] = u.resources.dsp as f32;
            res[i * NUM_KINDS + 4] = u.resources.uram as f32;
        }
        let mut caps = vec![0f32; s * NUM_KINDS];
        for (si, slot) in dev.slots.iter().enumerate() {
            caps[si * NUM_KINDS] = (slot.capacity.lut * util_limit) as f32;
            caps[si * NUM_KINDS + 1] = (slot.capacity.ff * util_limit) as f32;
            caps[si * NUM_KINDS + 2] = (slot.capacity.bram * util_limit) as f32;
            caps[si * NUM_KINDS + 3] = (slot.capacity.dsp * util_limit) as f32;
            caps[si * NUM_KINDS + 4] = (slot.capacity.uram * util_limit) as f32;
        }
        // Upper-triangle nonzeros of the (already aggregated) matrix —
        // built from `conn` so duplicate edge entries cannot double-count.
        let mut edges_sparse = Vec::new();
        for a in 0..m_real {
            for b in (a + 1)..m_real {
                let c = conn[a * m + b];
                if c != 0.0 {
                    edges_sparse.push((a as u32, b as u32, c));
                }
            }
        }
        // CSR adjacency over the same aggregated edges: the delta
        // evaluator walks one unit's row per move.
        let mut deg = vec![0u32; m_real];
        for &(a, b, _) in &edges_sparse {
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let mut adj_off = Vec::with_capacity(m_real + 1);
        let mut acc = 0u32;
        adj_off.push(0);
        for d in &deg {
            acc += d;
            adj_off.push(acc);
        }
        let mut cursor: Vec<u32> = adj_off[..m_real].to_vec();
        let mut adj_unit = vec![0u32; acc as usize];
        let mut adj_w = vec![0f32; acc as usize];
        for &(a, b, c) in &edges_sparse {
            let (ai, bi) = (a as usize, b as usize);
            adj_unit[cursor[ai] as usize] = b;
            adj_w[cursor[ai] as usize] = c;
            cursor[ai] += 1;
            adj_unit[cursor[bi] as usize] = a;
            adj_w[cursor[bi] as usize] = c;
            cursor[bi] += 1;
        }
        CostModel {
            m,
            m_real,
            s,
            conn,
            dist,
            res,
            caps,
            lambda,
            edges_sparse,
            adj_off,
            adj_unit,
            adj_w,
        }
    }

    /// One-hot encode a batch of assignments (slot id per real unit;
    /// padded units pinned to slot 0 with zero resources/connectivity, so
    /// they never affect the cost).
    pub fn onehot(&self, batch: &[Vec<usize>]) -> Vec<f32> {
        let (m, s) = (self.m, self.s);
        let mut a = vec![0f32; batch.len() * m * s];
        for (b, cand) in batch.iter().enumerate() {
            assert_eq!(cand.len(), self.m_real);
            for i in 0..m {
                let slot = if i < self.m_real { cand[i] } else { 0 };
                a[b * m * s + i * s + slot] = 1.0;
            }
        }
        a
    }

    /// Scalar cost of one candidate — sparse edge iteration (the CPU fast
    /// path; identical math to the dense/batched form).
    pub fn cost_scalar(&self, cand: &[usize]) -> f32 {
        let mut wl = 0f32;
        for &(i, j, c) in &self.edges_sparse {
            wl += c * self.dist[cand[i as usize] * self.s + cand[j as usize]];
        }
        let mut usage = vec![0f32; self.s * NUM_KINDS];
        for (i, &slot) in cand.iter().enumerate() {
            for k in 0..NUM_KINDS {
                usage[slot * NUM_KINDS + k] += self.res[i * NUM_KINDS + k];
            }
        }
        let mut pen = 0f32;
        for (u, c) in usage.iter().zip(&self.caps) {
            let over = (u - c).max(0.0);
            pen += over * over;
        }
        wl + self.lambda * pen
    }

    /// Clone everything the sparse/delta scoring paths read, leaving the
    /// dense `conn` matrix empty: `cost_scalar` and [`ScoredState`]
    /// never touch it, so the SA lanes avoid an O(m²) copy per anneal.
    /// Not suitable for `onehot`/`cost_batch` (the dense oracle).
    pub(crate) fn sparse_clone(&self) -> CostModel {
        CostModel {
            m: self.m,
            m_real: self.m_real,
            s: self.s,
            conn: Vec::new(),
            dist: self.dist.clone(),
            res: self.res.clone(),
            caps: self.caps.clone(),
            lambda: self.lambda,
            edges_sparse: self.edges_sparse.clone(),
            adj_off: self.adj_off.clone(),
            adj_unit: self.adj_unit.clone(),
            adj_w: self.adj_w.clone(),
        }
    }

    /// Batched cost via the matmul identity — numerically the same
    /// computation the Pallas kernel performs.
    pub fn cost_batch(&self, a_onehot: &[f32], batch: usize) -> Vec<f32> {
        let (m, s) = (self.m, self.s);
        assert_eq!(a_onehot.len(), batch * m * s);
        let mut out = Vec::with_capacity(batch);
        // scratch
        let mut ca = vec![0f32; m * s];
        let mut ad = vec![0f32; m * s];
        let mut usage = vec![0f32; s * NUM_KINDS];
        for b in 0..batch {
            let a = &a_onehot[b * m * s..(b + 1) * m * s];
            // CA = C (M×M) @ A (M×S)
            matmul(&self.conn, a, &mut ca, m, m, s);
            // AD = A (M×S) @ D (S×S)
            matmul(a, &self.dist, &mut ad, m, s, s);
            let wl: f32 = ca.iter().zip(&ad).map(|(x, y)| x * y).sum();
            // usage = Aᵀ (S×M) @ R (M×K)
            usage.iter_mut().for_each(|u| *u = 0.0);
            for i in 0..m {
                for sl in 0..s {
                    let av = a[i * s + sl];
                    if av != 0.0 {
                        for k in 0..NUM_KINDS {
                            usage[sl * NUM_KINDS + k] += av * self.res[i * NUM_KINDS + k];
                        }
                    }
                }
            }
            let pen: f32 = usage
                .iter()
                .zip(&self.caps)
                .map(|(u, c)| {
                    let over = (u - c).max(0.0);
                    over * over
                })
                .sum();
            out.push(0.5 * wl + self.lambda * pen);
        }
        out
    }
}

fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    c.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av != 0.0 {
                let brow = &b[kk * n..kk * n + n];
                let crow = &mut c[i * n..i * n + n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    }
}

/// Max (unit, slot) writes one SA proposal can carry: two mutation
/// rounds, each at worst a swap (two writes).
pub const PROPOSAL_MAX_MOVES: usize = 4;

/// One SA proposal relative to some base assignment: a short ordered
/// list of `(unit, new_slot)` writes. Later writes to the same unit win,
/// exactly as if they were applied to a mutable candidate in sequence.
///
/// `Copy` and fixed-size on purpose: a step's proposals live in one flat
/// scratch buffer that is reused across steps — no per-proposal `Vec`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Proposal {
    moves: [(u32, u32); PROPOSAL_MAX_MOVES],
    len: u8,
}

impl Proposal {
    /// Append a `(unit, new_slot)` write.
    pub fn push(&mut self, unit: u32, slot: u32) {
        assert!(
            (self.len as usize) < PROPOSAL_MAX_MOVES,
            "proposal overflow"
        );
        self.moves[self.len as usize] = (unit, slot);
        self.len += 1;
    }

    /// The writes recorded so far, in application order.
    pub fn moves(&self) -> &[(u32, u32)] {
        &self.moves[..self.len as usize]
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Effective slot of `unit` once this proposal is applied over `base`
    /// (the view mutation generators use to stack moves).
    pub fn slot_of(&self, unit: usize, base: &[usize]) -> usize {
        self.moves()
            .iter()
            .rev()
            .find(|(u, _)| *u as usize == unit)
            .map(|(_, s)| *s as usize)
            .unwrap_or(base[unit])
    }

    /// Expand to a full candidate over `base` (the slow-lane form fed to
    /// batch evaluators).
    pub fn materialize(&self, base: &[usize]) -> Vec<usize> {
        let mut cand = base.to_vec();
        for &(u, s) in self.moves() {
            cand[u as usize] = s as usize;
        }
        cand
    }
}

/// A candidate assignment plus the cached terms of its cost: wirelength,
/// per-slot resource usage `[S×K]` and per-slot relu² penalty terms.
/// `apply_move`/`apply_swap` update the caches in O(deg(u) + S·K) — the
/// CSR row of the moved unit, the two affected slots' K resource kinds
/// and penalty terms, and one flat re-fold of the S·K penalty terms —
/// instead of the O(edges + units·K) full re-score.
///
/// §Exactness contract. `ScoredState::new(model, a).cost(model)` is
/// **bit-identical** to `model.cost_scalar(&a)` for any assignment: the
/// wirelength fold iterates `edges_sparse` in the same order and the
/// penalty folds the S·K term array flat, associating exactly like
/// `cost_scalar`'s loop. After incremental updates the costs stay
/// bit-identical whenever the inputs are "exact-friendly" — integral
/// resource values, widths and die weights whose intermediate sums stay
/// below 2²⁴ (every in-tree problem and generator qualifies), because
/// then every f32 add/subtract is exact and order-independent. For
/// arbitrary real-valued inputs the cached cost can drift by f32
/// rounding; the property tests pin it within relative 1e-3 of
/// `cost_scalar` under arbitrary move/swap/revert sequences.
///
/// Uncommitted changes are journaled: `revert` undoes everything since
/// the last `commit` (or construction), which is how the SA fast lane
/// scores a proposal and puts the chain back, in O(moves · deg).
#[derive(Debug, Clone)]
pub struct ScoredState {
    assign: Vec<usize>,
    wl: f32,
    /// Per-slot resource usage, row-major `[S×K]`.
    usage: Vec<f32>,
    /// Per-slot-per-kind relu² penalty terms, flat `[S×K]` — kept as
    /// terms (not a per-slot scalar) so the total re-folds in the exact
    /// order `cost_scalar` uses.
    pen_terms: Vec<f32>,
    pen_sum: f32,
    /// (unit, previous slot) undo log since the last commit.
    journal: Vec<(u32, u32)>,
}

impl ScoredState {
    /// Full O(edges + units·K) scoring of `assign` — done once per chain;
    /// everything after is incremental.
    pub fn new(model: &CostModel, assign: Vec<usize>) -> ScoredState {
        assert_eq!(assign.len(), model.m_real, "assignment arity");
        let mut wl = 0f32;
        for &(i, j, c) in &model.edges_sparse {
            wl += c * model.dist[assign[i as usize] * model.s + assign[j as usize]];
        }
        let mut usage = vec![0f32; model.s * NUM_KINDS];
        for (i, &slot) in assign.iter().enumerate() {
            for k in 0..NUM_KINDS {
                usage[slot * NUM_KINDS + k] += model.res[i * NUM_KINDS + k];
            }
        }
        let mut pen_terms = vec![0f32; model.s * NUM_KINDS];
        for ((t, u), c) in pen_terms.iter_mut().zip(&usage).zip(&model.caps) {
            let over = (u - c).max(0.0);
            *t = over * over;
        }
        let pen_sum = pen_terms.iter().sum();
        ScoredState {
            assign,
            wl,
            usage,
            pen_terms,
            pen_sum,
            journal: Vec::new(),
        }
    }

    /// The candidate this state scores.
    pub fn assignment(&self) -> &[usize] {
        &self.assign
    }

    /// Cached cost — the same `wl + λ·pen` expression as `cost_scalar`.
    pub fn cost(&self, model: &CostModel) -> f32 {
        self.wl + model.lambda * self.pen_sum
    }

    /// Move `unit` to `new_slot`, journaling the old slot for `revert`.
    pub fn apply_move(&mut self, model: &CostModel, unit: usize, new_slot: usize) {
        let old = self.assign[unit];
        self.journal.push((unit as u32, old as u32));
        if old != new_slot {
            self.shift(model, unit, old, new_slot);
        }
    }

    /// Swap the slots of `a` and `b` (two journaled moves).
    pub fn apply_swap(&mut self, model: &CostModel, a: usize, b: usize) {
        let (sa, sb) = (self.assign[a], self.assign[b]);
        self.apply_move(model, a, sb);
        self.apply_move(model, b, sa);
    }

    /// Apply every write of `proposal` in order.
    pub fn apply(&mut self, model: &CostModel, proposal: &Proposal) {
        for &(u, s) in proposal.moves() {
            self.apply_move(model, u as usize, s as usize);
        }
    }

    /// Keep the applied changes: clears the undo journal.
    pub fn commit(&mut self) {
        self.journal.clear();
    }

    /// Undo everything since the last `commit` (inverse moves, newest
    /// first), restoring assignment and cached terms.
    pub fn revert(&mut self, model: &CostModel) {
        while let Some((u, old)) = self.journal.pop() {
            let (u, old) = (u as usize, old as usize);
            let cur = self.assign[u];
            if cur != old {
                self.shift(model, u, cur, old);
            }
        }
    }

    /// The O(deg + S·K) cache update for one unit changing slot.
    fn shift(&mut self, model: &CostModel, unit: usize, from: usize, to: usize) {
        let s = model.s;
        // Wirelength: only edges incident to `unit` change; each term is
        // removed at the old distance and re-added at the new one.
        for e in model.adj_off[unit] as usize..model.adj_off[unit + 1] as usize {
            let v = model.adj_unit[e] as usize;
            let w = model.adj_w[e];
            let sv = self.assign[v];
            self.wl -= w * model.dist[from * s + sv];
            self.wl += w * model.dist[to * s + sv];
        }
        self.assign[unit] = to;
        // Usage and penalty terms: only the two affected slots.
        for k in 0..NUM_KINDS {
            self.usage[from * NUM_KINDS + k] -= model.res[unit * NUM_KINDS + k];
            self.usage[to * NUM_KINDS + k] += model.res[unit * NUM_KINDS + k];
        }
        for slot in [from, to] {
            for k in 0..NUM_KINDS {
                let i = slot * NUM_KINDS + k;
                let over = (self.usage[i] - model.caps[i]).max(0.0);
                self.pen_terms[i] = over * over;
            }
        }
        // Re-fold flat so the sum associates exactly like cost_scalar's
        // sequential loop (bit-parity; see the exactness contract above).
        self.pen_sum = self.pen_terms.iter().sum();
    }
}

/// Score each proposal against `state` via the delta path — apply, read,
/// revert — leaving `state` (which must have no uncommitted changes)
/// as it was. Shared by `CpuEvaluator`'s `evaluate_deltas` override and
/// the parallel annealing lane; `out` is a reusable scratch buffer.
pub fn score_deltas_into(
    model: &CostModel,
    state: &mut ScoredState,
    proposals: &[Proposal],
    out: &mut Vec<f32>,
) {
    out.clear();
    for p in proposals {
        state.apply(model, p);
        out.push(state.cost(model));
        state.revert(model);
    }
}

/// Batch evaluator abstraction: CPU oracle or the PJRT executable.
pub trait BatchEvaluator {
    /// Evaluate a batch of candidates (slot id per real unit each).
    fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32>;

    /// Score `proposals`, each a small move-set on top of `state`'s
    /// current assignment, into the reusable `out` buffer, without
    /// committing any of them. The default materializes full candidates
    /// and defers to [`evaluate`] in one batched call; CPU
    /// implementations override this with the O(deg + K) delta path.
    ///
    /// This is the annealer's scoring entry point whenever
    /// [`cost_model`] returns `Some` and `SaConfig::workers <= 1` (the
    /// default). With `workers > 1` chains are scored across the pool
    /// through the shared [`score_deltas_into`] routine instead —
    /// overrides are bypassed there, so an override must agree with the
    /// delta path over the exposed model (within f32 tolerance).
    ///
    /// [`evaluate`]: BatchEvaluator::evaluate
    /// [`cost_model`]: BatchEvaluator::cost_model
    fn evaluate_deltas(
        &mut self,
        state: &mut ScoredState,
        proposals: &[Proposal],
        out: &mut Vec<f32>,
    ) {
        let batch: Vec<Vec<usize>> = proposals
            .iter()
            .map(|p| p.materialize(state.assignment()))
            .collect();
        *out = self.evaluate(&batch);
    }

    /// The CPU-resident cost model, when scoring is a pure function of
    /// it. `Some` opts the SA explorer into the incremental lane:
    /// persistent per-chain [`ScoredState`]s, scored through
    /// `evaluate_deltas` serially or across the pool when
    /// `SaConfig::workers > 1`. `None` (the default, and the dense/PJRT
    /// answer) keeps the batched lane — one `evaluate` launch per
    /// step — untouched.
    fn cost_model(&self) -> Option<&CostModel> {
        None
    }

    fn name(&self) -> &'static str;
}

/// CPU implementation of [`BatchEvaluator`].
///
/// §Perf note: on a CPU the *sparse* scalar formula (iterate edges, not
/// the dense M×M matrix) beats the matmul identity by ~3-5x — the dense
/// form exists because it is what maps onto the MXU. `evaluate` therefore
/// uses the scalar path; `CostModel::cost_batch` remains the bit-level
/// oracle of the Pallas kernel (and is what the PJRT comparison tests
/// check against — scalar, dense and kernel agree within f32 tolerance).
pub struct CpuEvaluator {
    pub model: CostModel,
}

impl BatchEvaluator for CpuEvaluator {
    fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32> {
        batch.iter().map(|c| self.model.cost_scalar(c)).collect()
    }

    /// The fast lane: O(deg + K) per proposal instead of a full
    /// re-score. `state` must have been built against `self.model` (or
    /// a value-identical clone of it).
    fn evaluate_deltas(
        &mut self,
        state: &mut ScoredState,
        proposals: &[Proposal],
        out: &mut Vec<f32>,
    ) {
        score_deltas_into(&self.model, state, proposals, out);
    }

    fn cost_model(&self) -> Option<&CostModel> {
        Some(&self.model)
    }

    fn name(&self) -> &'static str {
        "cpu"
    }
}

/// Forces any evaluator through the batched full-rescore lane by hiding
/// its cost model and delta path: every proposal is materialized and
/// scored from scratch. This is the differential baseline the
/// incremental path is asserted bit-identical against (tests and the
/// `perf_hotpath` SA bench), never the flow's default.
pub struct FullRescore<E: BatchEvaluator>(pub E);

impl<E: BatchEvaluator> BatchEvaluator for FullRescore<E> {
    fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32> {
        self.0.evaluate(batch)
    }
    fn name(&self) -> &'static str {
        "full-rescore"
    }
}

/// Dense-matmul evaluator — the exact computation the Pallas kernel runs,
/// on the CPU. Used by tests and by the perf bench as the kernel oracle.
pub struct DenseCpuEvaluator {
    pub model: CostModel,
}

impl BatchEvaluator for DenseCpuEvaluator {
    fn evaluate(&mut self, batch: &[Vec<usize>]) -> Vec<f32> {
        let a = self.model.onehot(batch);
        self.model.cost_batch(&a, batch.len())
    }
    fn name(&self) -> &'static str {
        "cpu-dense"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::floorplan::problem::{Problem, Unit, UnitEdge};
    use crate::ir::core::Resources;
    use crate::util::rng::Rng;

    fn problem(n: usize) -> Problem {
        let mut units: Vec<Unit> = (0..n)
            .map(|i| Unit {
                nodes: vec![i],
                resources: Resources::new(
                    1000.0 + 137.0 * i as f64,
                    500.0,
                    2.0,
                    8.0,
                    0.0,
                ),
                fixed_slot: None,
                name: format!("u{i}"),
            })
            .collect();
        units[0].resources.lut = 50_000.0;
        Problem {
            units,
            edges: (0..n - 1)
                .map(|i| UnitEdge {
                    a: i,
                    b: i + 1,
                    width: 32 + (i as u64 % 5) * 16,
                })
                .collect(),
            die_weight: 3.0,
        }
    }

    #[test]
    fn batch_matches_scalar() {
        let dev = builtin::by_name("u280").unwrap();
        let p = problem(13);
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut rng = Rng::new(5);
        let batch: Vec<Vec<usize>> = (0..16)
            .map(|_| (0..13).map(|_| rng.below(cm.s)).collect())
            .collect();
        let scalar: Vec<f32> = batch.iter().map(|c| cm.cost_scalar(c)).collect();
        let a = cm.onehot(&batch);
        let batched = cm.cost_batch(&a, 16);
        for (s, b) in scalar.iter().zip(&batched) {
            assert!(
                (s - b).abs() <= 1e-3 * s.abs().max(1.0),
                "scalar {s} vs batch {b}"
            );
        }
    }

    #[test]
    fn colocations_cheaper_than_spread_when_no_overflow() {
        let dev = builtin::by_name("u280").unwrap();
        let p = problem(4);
        let cm = CostModel::build(&p, &dev, 0.9, 1e-4);
        let together = cm.cost_scalar(&[0, 0, 0, 0]);
        let apart = cm.cost_scalar(&[0, 5, 0, 5]);
        assert!(together < apart);
    }

    #[test]
    fn overflow_penalized() {
        let dev = builtin::by_name("u280").unwrap();
        let mut p = problem(4);
        // make every unit huge
        for u in &mut p.units {
            u.resources.lut = dev.slots[0].capacity.lut * 0.5;
        }
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let stacked = cm.cost_scalar(&[0, 0, 0, 0]);
        let spread = cm.cost_scalar(&[0, 1, 2, 3]);
        assert!(stacked > spread, "stacked {stacked} spread {spread}");
    }

    #[test]
    fn padding_is_neutral() {
        let dev = builtin::by_name("u250").unwrap();
        let p = problem(5); // padded to m=8
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        assert_eq!(cm.m, 8);
        let cand = vec![1, 2, 3, 4, 5];
        let a = cm.onehot(&[cand.clone()]);
        let batched = cm.cost_batch(&a, 1)[0];
        let scalar = cm.cost_scalar(&cand);
        assert!((batched - scalar).abs() <= 1e-3 * scalar.max(1.0));
    }

    #[test]
    fn csr_adjacency_mirrors_sparse_edges() {
        let dev = builtin::by_name("u280").unwrap();
        let p = problem(13);
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        assert_eq!(cm.adj_off.len(), cm.m_real + 1);
        assert_eq!(*cm.adj_off.last().unwrap() as usize, 2 * cm.edges_sparse.len());
        // Every undirected edge appears in both endpoints' rows with the
        // same weight.
        for &(a, b, c) in &cm.edges_sparse {
            for (u, v) in [(a, b), (b, a)] {
                let row = cm.adj_off[u as usize] as usize..cm.adj_off[u as usize + 1] as usize;
                let hit = row
                    .clone()
                    .any(|e| cm.adj_unit[e] == v && cm.adj_w[e] == c);
                assert!(hit, "edge ({a},{b},{c}) missing from row of {u}");
            }
        }
    }

    #[test]
    fn scored_state_initial_cost_is_bitwise_cost_scalar() {
        let dev = builtin::by_name("u280").unwrap();
        let p = problem(13);
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut rng = Rng::new(21);
        for _ in 0..32 {
            let cand: Vec<usize> = (0..13).map(|_| rng.below(cm.s)).collect();
            let st = ScoredState::new(&cm, cand.clone());
            assert_eq!(st.cost(&cm).to_bits(), cm.cost_scalar(&cand).to_bits());
        }
    }

    #[test]
    fn scored_state_tracks_moves_swaps_and_reverts() {
        let dev = builtin::by_name("u280").unwrap();
        let p = problem(16);
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut rng = Rng::new(33);
        let mut st = ScoredState::new(&cm, vec![0; 16]);
        let mut committed: Vec<usize> = st.assignment().to_vec();
        for round in 0..300 {
            match rng.below(4) {
                0 => {
                    let u = rng.below(16);
                    st.apply_move(&cm, u, rng.below(cm.s));
                }
                1 => {
                    let a = rng.below(16);
                    let b = (a + 1 + rng.below(15)) % 16;
                    st.apply_swap(&cm, a, b);
                }
                2 => {
                    st.commit();
                    committed = st.assignment().to_vec();
                }
                _ => {
                    st.revert(&cm);
                    assert_eq!(st.assignment(), &committed[..], "revert at {round}");
                }
            }
            let want = cm.cost_scalar(st.assignment());
            let got = st.cost(&cm);
            assert!(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                "round {round}: cached {got} vs rescored {want}"
            );
        }
    }

    #[test]
    fn evaluate_deltas_override_matches_default_full_rescore() {
        let dev = builtin::by_name("u250").unwrap();
        let p = problem(12);
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut rng = Rng::new(9);
        let base: Vec<usize> = (0..12).map(|_| rng.below(cm.s)).collect();
        let mut proposals = Vec::new();
        for _ in 0..64 {
            let mut pr = Proposal::default();
            for _ in 0..1 + rng.below(2) {
                pr.push(rng.below(12) as u32, rng.below(cm.s) as u32);
            }
            proposals.push(pr);
        }
        let mut fast = CpuEvaluator { model: cm.clone() };
        let mut slow = FullRescore(CpuEvaluator { model: cm.clone() });
        let mut st_fast = ScoredState::new(&cm, base.clone());
        let mut st_slow = ScoredState::new(&cm, base.clone());
        let (mut a, mut b) = (Vec::new(), Vec::new());
        fast.evaluate_deltas(&mut st_fast, &proposals, &mut a);
        slow.evaluate_deltas(&mut st_slow, &proposals, &mut b);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() <= 1e-3 * y.abs().max(1.0),
                "delta {x} vs full {y}"
            );
        }
        // Scoring must leave the states untouched.
        assert_eq!(st_fast.assignment(), &base[..]);
        assert_eq!(st_fast.cost(&cm).to_bits(), cm.cost_scalar(&base).to_bits());
    }

    #[test]
    fn sparse_clone_scores_identically_without_dense_matrix() {
        let dev = builtin::by_name("u280").unwrap();
        let p = problem(13);
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let sc = cm.sparse_clone();
        assert!(sc.conn.is_empty());
        let mut rng = Rng::new(2);
        for _ in 0..16 {
            let cand: Vec<usize> = (0..13).map(|_| rng.below(cm.s)).collect();
            let want = cm.cost_scalar(&cand);
            assert_eq!(sc.cost_scalar(&cand).to_bits(), want.to_bits());
            let st = ScoredState::new(&sc, cand);
            assert_eq!(st.cost(&sc).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn proposal_view_and_materialize_agree() {
        let base = vec![3usize, 1, 4, 1, 5];
        let mut p = Proposal::default();
        assert!(p.is_empty());
        p.push(0, 7);
        p.push(2, 2);
        p.push(0, 6); // later write to unit 0 wins
        assert_eq!(p.slot_of(0, &base), 6);
        assert_eq!(p.slot_of(2, &base), 2);
        assert_eq!(p.slot_of(4, &base), 5);
        assert_eq!(p.materialize(&base), vec![6, 1, 2, 1, 5]);
    }

    #[test]
    fn cpu_evaluator_wraps_model() {
        let dev = builtin::by_name("u250").unwrap();
        let p = problem(6);
        let cm = CostModel::build(&p, &dev, 0.7, 1e-4);
        let mut ev = CpuEvaluator { model: cm };
        let costs = ev.evaluate(&[vec![0; 6], vec![7; 6]]);
        assert_eq!(costs.len(), 2);
        assert!(costs.iter().all(|c| c.is_finite()));
    }
}

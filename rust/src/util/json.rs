//! Minimal self-contained JSON library.
//!
//! The RapidStream IR is defined as a subset of the JSON schema (§3.1 of the
//! paper): dictionaries, lists, strings, and numbers. Since no serde facade
//! is available offline, this module implements the storage/exchange format
//! from scratch: a [`Json`] value type, a recursive-descent parser, and
//! compact + pretty serializers. Object key order is preserved (insertion
//! order) so IR dumps are stable and diffable.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as f64 (the IR never needs more than
/// 2^53 integer precision: port widths, resource counts, coordinates).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// An insertion-ordered JSON object.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Json) {
        let key = key.into();
        if !self.map.contains_key(&key) {
            self.keys.push(key.clone());
        }
        self.map.insert(key, value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn get_mut(&mut self, key: &str) -> Option<&mut Json> {
        self.map.get_mut(key)
    }

    pub fn remove(&mut self, key: &str) -> Option<Json> {
        self.keys.retain(|k| k != key);
        self.map.remove(key)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.keys.iter()
    }
}

impl FromIterator<(String, Json)> for JsonObj {
    fn from_iter<T: IntoIterator<Item = (String, Json)>>(iter: T) -> Self {
        let mut obj = JsonObj::new();
        for (k, v) in iter {
            obj.insert(k, v);
        }
        obj
    }
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(JsonObj::new())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn as_obj_mut(&mut self) -> Option<&mut JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Path lookup: `j.at("module_ports")` on objects.
    pub fn at(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Compact single-line serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out, None, 0);
        out
    }

    /// Pretty, 2-space indented serialization.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        write_json(self, &mut out, Some(2), 0);
        out.push('\n');
        out
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

/// Parse error with byte offset and line/column info.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        JsonError {
            msg: msg.into(),
            line,
            col,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn keyword(&mut self, kw: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(val)
        } else {
            Err(self.err(format!("expected '{kw}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
        Ok(Json::Obj(obj))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => break,
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
        Ok(Json::Arr(arr))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle UTF-16 surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                            char::from_u32(combined).ok_or_else(|| self.err("bad surrogate"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape sequence")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control character in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let extra = match b {
                            0xC0..=0xDF => 1,
                            0xE0..=0xEF => 2,
                            0xF0..=0xF7 => 3,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        let start = self.pos - 1;
                        for _ in 0..extra {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>, level: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => write_number(*n, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_json(item, out, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push(']');
        }
        Json::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(val, out, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..(n * level) {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.at("c").unwrap().as_str(), Some("x"));
        let arr = j.at("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].at("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\"A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\"A"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str(), Some("😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo wörld"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"FIFO","ports":[{"name":"I","width":64},{"name":"I_vld","width":1}],"meta":{"resource":{"FF":10,"LUT":39}}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
        // Key order is preserved.
        assert!(j.dump().find("\"name\"").unwrap() < j.dump().find("\"ports\"").unwrap());
    }

    #[test]
    fn object_insert_overwrites() {
        let mut o = JsonObj::new();
        o.insert("k", Json::num(1.0));
        o.insert("k", Json::num(2.0));
        assert_eq!(o.len(), 1);
        assert_eq!(o.get("k").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn error_position() {
        let e = Json::parse("{\n  \"a\": xyz\n}").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::num(64.0).dump(), "64");
        assert_eq!(Json::num(0.5).dump(), "0.5");
    }
}

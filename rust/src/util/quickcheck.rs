//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! and, on failure, greedily shrinks the input via the generator's `shrink`
//! before panicking with the minimal counterexample's debug repr.

use crate::util::rng::Rng;
use std::fmt::Debug;

/// A generator of random test inputs plus a shrinking strategy.
pub trait Gen {
    type Item: Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Item;
    /// Candidate smaller versions of `item`; empty when fully shrunk.
    fn shrink(&self, _item: &Self::Item) -> Vec<Self::Item> {
        Vec::new()
    }
}

/// Run the property over `cases` random inputs, shrinking on failure.
pub fn forall<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Item) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let minimal = minimize(gen, input, &prop);
            panic!(
                "property failed (seed={seed}, case={case});\nminimal counterexample: {minimal:#?}"
            );
        }
    }
}

/// Greedily shrink a failing input: accept the first shrunken candidate
/// that still fails the property, until no candidate fails (or a bounded
/// number of descent steps is exhausted, which guarantees termination
/// even for shrinkers that never converge). Shared by [`forall`] and the
/// `rsir fuzz` counterexample minimizer.
pub fn minimize<G: Gen>(gen: &G, mut failing: G::Item, prop: &impl Fn(&G::Item) -> bool) -> G::Item {
    'outer: for _ in 0..1000 {
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

/// Generator for usize in [lo, hi], shrinking toward lo.
pub struct UsizeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeGen {
    type Item = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.lo, self.hi)
    }
    fn shrink(&self, item: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *item > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*item - self.lo) / 2);
            out.push(*item - 1);
        }
        out.dedup();
        out
    }
}

/// Generator for Vec<T>, shrinking by halving length then shrinking elements.
pub struct VecGen<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Item = Vec<G::Item>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Item> {
        let len = rng.range(self.min_len, self.max_len);
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, item: &Vec<G::Item>) -> Vec<Vec<G::Item>> {
        let mut out = Vec::new();
        if item.len() > self.min_len {
            // Drop the back half / one element.
            let keep = (item.len() / 2).max(self.min_len);
            out.push(item[..keep].to_vec());
            out.push(item[..item.len() - 1].to_vec());
            out.push(item[1..].to_vec());
        }
        // Shrink one element at a time (first position with candidates).
        for (i, el) in item.iter().enumerate() {
            let cands = self.inner.shrink(el);
            if !cands.is_empty() {
                for c in cands.into_iter().take(2) {
                    let mut v = item.clone();
                    v[i] = c;
                    out.push(v);
                }
                break;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(1, 200, &UsizeGen { lo: 0, hi: 100 }, |&x| x <= 100);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics() {
        forall(2, 200, &UsizeGen { lo: 0, hi: 100 }, |&x| x < 90);
    }

    #[test]
    fn shrinks_to_minimal() {
        // Capture the panic message and check the counterexample is minimal (90).
        let result = std::panic::catch_unwind(|| {
            forall(3, 500, &UsizeGen { lo: 0, hi: 1000 }, |&x| x < 90);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("90"), "expected shrink to 90, got: {msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecGen {
            inner: UsizeGen { lo: 0, hi: 9 },
            min_len: 2,
            max_len: 5,
        };
        forall(4, 100, &g, |v| (2..=5).contains(&v.len()));
    }

    #[test]
    fn minimize_is_greedy_to_exact_boundary() {
        // From any failing start, the greedy descent must land exactly on
        // the smallest failing value (90), not a mid-chain stop.
        let g = UsizeGen { lo: 0, hi: 1000 };
        let prop = |x: &usize| *x < 90;
        for start in [90usize, 91, 250, 999] {
            assert_eq!(minimize(&g, start, &prop), 90, "from {start}");
        }
    }

    /// A shrinker that always proposes the unchanged item: the descent
    /// must still terminate (bounded steps), returning the original.
    struct Stubborn;
    impl Gen for Stubborn {
        type Item = usize;
        fn generate(&self, rng: &mut Rng) -> usize {
            rng.below(100)
        }
        fn shrink(&self, item: &usize) -> Vec<usize> {
            vec![*item]
        }
    }

    #[test]
    fn minimize_terminates_on_non_converging_shrinker() {
        assert_eq!(minimize(&Stubborn, 42, &|_| false), 42);
    }

    #[test]
    fn generation_is_reproducible_from_seed() {
        let g = VecGen {
            inner: UsizeGen { lo: 0, hi: 999 },
            min_len: 0,
            max_len: 8,
        };
        let sample = |seed: u64| -> Vec<Vec<usize>> {
            let mut rng = Rng::new(seed);
            (0..20).map(|_| g.generate(&mut rng)).collect()
        };
        assert_eq!(sample(5), sample(5));
        assert_ne!(sample(5), sample(6));
    }

    #[test]
    fn vec_gen_shrink_candidates_respect_min_len() {
        let g = VecGen {
            inner: UsizeGen { lo: 0, hi: 9 },
            min_len: 2,
            max_len: 6,
        };
        let mut rng = Rng::new(8);
        for _ in 0..50 {
            let v = g.generate(&mut rng);
            for cand in g.shrink(&v) {
                assert!(cand.len() >= 2, "candidate {cand:?} below min_len");
            }
        }
    }

    #[test]
    fn forall_runs_are_deterministic() {
        use std::cell::RefCell;
        let record = |seed: u64| {
            let seen = RefCell::new(Vec::new());
            forall(seed, 50, &UsizeGen { lo: 0, hi: 500 }, |x| {
                seen.borrow_mut().push(*x);
                true
            });
            seen.into_inner()
        };
        assert_eq!(record(12), record(12));
        assert_ne!(record(12), record(13));
    }
}

//! YAML *emitter* for human-readable IR dumps (§3.1: "The choice of storage
//! and exchange format for the IR, such as YAML, JSON, or XML, can
//! optionally vary"). We emit a YAML-compatible rendering of [`Json`]
//! values; JSON remains the canonical parse format.

use crate::util::json::Json;

pub fn to_yaml(v: &Json) -> String {
    let mut out = String::new();
    emit(v, &mut out, 0, false);
    if !out.ends_with('\n') {
        out.push('\n');
    }
    out
}

fn emit(v: &Json, out: &mut String, indent: usize, inline_ctx: bool) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => emit_str(s, out),
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            if inline_ctx {
                out.push('\n');
            }
            for (i, item) in a.iter().enumerate() {
                if i > 0 || inline_ctx {
                    pad(out, indent);
                }
                out.push_str("- ");
                emit(item, out, indent + 1, true);
                if !out.ends_with('\n') {
                    out.push('\n');
                }
            }
        }
        Json::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            if inline_ctx {
                // Nested object: first key on same line after "- ", or newline after "key:".
                let mut first = true;
                for (k, val) in o.iter() {
                    if first {
                        first = false;
                        // For `- key: val` style, key follows directly.
                        if !out.ends_with("- ") {
                            out.push('\n');
                            pad(out, indent);
                        }
                    } else {
                        pad(out, indent);
                    }
                    emit_key(k, out);
                    emit_value_after_key(val, out, indent);
                }
            } else {
                for (k, val) in o.iter() {
                    pad(out, indent);
                    emit_key(k, out);
                    emit_value_after_key(val, out, indent);
                }
            }
        }
    }
}

fn emit_value_after_key(val: &Json, out: &mut String, indent: usize) {
    match val {
        Json::Obj(o) if !o.is_empty() => {
            out.push('\n');
            emit(val, out, indent + 1, false);
        }
        Json::Arr(a) if !a.is_empty() => {
            out.push('\n');
            emit(val, out, indent + 1, false);
        }
        _ => {
            out.push(' ');
            emit(val, out, indent, true);
            out.push('\n');
        }
    }
}

fn emit_key(k: &str, out: &mut String) {
    if needs_quoting(k) {
        emit_str(k, out);
    } else {
        out.push_str(k);
    }
    out.push(':');
}

fn emit_str(s: &str, out: &mut String) {
    if needs_quoting(s) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c => out.push(c),
            }
        }
        out.push('"');
    } else {
        out.push_str(s);
    }
}

fn needs_quoting(s: &str) -> bool {
    s.is_empty()
        || s.chars().any(|c| {
            !(c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.' || c == '/')
        })
        || matches!(s, "true" | "false" | "null" | "yes" | "no")
        || s.chars().next().map(|c| c.is_ascii_digit() || c == '-').unwrap_or(false)
}

fn pad(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn scalar_map() {
        let j = Json::parse(r#"{"name":"FIFO","width":64}"#).unwrap();
        let y = to_yaml(&j);
        assert!(y.contains("name: FIFO\n"));
        assert!(y.contains("width: 64\n"));
    }

    #[test]
    fn nested_list_of_objects() {
        let j = Json::parse(r#"{"ports":[{"name":"I","width":64},{"name":"clk","width":1}]}"#)
            .unwrap();
        let y = to_yaml(&j);
        assert!(y.contains("ports:\n"), "{y}");
        assert!(y.contains("- name: I\n"), "{y}");
        assert!(y.contains("    width: 1\n"), "{y}");
    }

    #[test]
    fn quoting_special_strings() {
        let j = Json::parse(r#"{"v":"module FIFO (I);","k":"true"}"#).unwrap();
        let y = to_yaml(&j);
        assert!(y.contains(r#"v: "module FIFO (I);""#), "{y}");
        assert!(y.contains(r#"k: "true""#), "{y}");
    }

    #[test]
    fn empty_collections() {
        let j = Json::parse(r#"{"a":[],"b":{}}"#).unwrap();
        let y = to_yaml(&j);
        assert!(y.contains("a: []"));
        assert!(y.contains("b: {}"));
    }
}

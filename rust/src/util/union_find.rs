//! Union-find (disjoint-set) with path halving and union by rank.
//!
//! The partitioning pass (§3.3 of the paper) analyzes port connectivity of
//! an aux module's netlist with union-find — "It converts modules in
//! arbitrary formats to netlists ... and applies union-find [15] ... to
//! analyze port connectivity" — splitting disjoint components into separate
//! floorplannable units.

#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<usize>,
    rank: Vec<u8>,
    components: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind {
            parent: (0..n).collect(),
            rank: vec![0; n],
            components: n,
        }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Number of disjoint components.
    pub fn components(&self) -> usize {
        self.components
    }

    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]]; // path halving
            x = self.parent[x];
        }
        x
    }

    /// Returns true if the union merged two distinct components.
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        self.components -= 1;
        match self.rank[ra].cmp(&self.rank[rb]) {
            std::cmp::Ordering::Less => self.parent[ra] = rb,
            std::cmp::Ordering::Greater => self.parent[rb] = ra,
            std::cmp::Ordering::Equal => {
                self.parent[rb] = ra;
                self.rank[ra] += 1;
            }
        }
        true
    }

    pub fn same(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }

    /// Group element indices by component; groups ordered by smallest member.
    pub fn groups(&mut self) -> Vec<Vec<usize>> {
        let n = self.len();
        let mut by_root: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
        for i in 0..n {
            let r = self.find(i);
            by_root.entry(r).or_default().push(i);
        }
        let mut groups: Vec<Vec<usize>> = by_root.into_values().collect();
        groups.sort_by_key(|g| g[0]);
        groups
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_singletons() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.components(), 5);
        assert!(!uf.same(0, 1));
    }

    #[test]
    fn union_merges() {
        let mut uf = UnionFind::new(5);
        assert!(uf.union(0, 1));
        assert!(!uf.union(1, 0)); // already merged
        assert!(uf.same(0, 1));
        assert_eq!(uf.components(), 4);
    }

    #[test]
    fn transitive() {
        let mut uf = UnionFind::new(6);
        uf.union(0, 1);
        uf.union(1, 2);
        uf.union(4, 5);
        assert!(uf.same(0, 2));
        assert!(!uf.same(2, 4));
        assert_eq!(uf.components(), 3);
    }

    #[test]
    fn groups_partition() {
        let mut uf = UnionFind::new(6);
        uf.union(0, 3);
        uf.union(1, 4);
        let groups = uf.groups();
        assert_eq!(groups.len(), 4);
        assert!(groups.contains(&vec![0, 3]));
        assert!(groups.contains(&vec![1, 4]));
        assert!(groups.contains(&vec![2]));
        assert!(groups.contains(&vec![5]));
        // All elements appear exactly once.
        let total: usize = groups.iter().map(|g| g.len()).sum();
        assert_eq!(total, 6);
    }

    #[test]
    fn chain_large() {
        let mut uf = UnionFind::new(1000);
        for i in 0..999 {
            uf.union(i, i + 1);
        }
        assert_eq!(uf.components(), 1);
        assert!(uf.same(0, 999));
    }
}

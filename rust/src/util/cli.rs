//! Tiny command-line argument parser (no clap available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the program name). `option_keys` lists the
    /// long options that consume a following value when given as
    /// `--key value`; everything else starting with `--` is a flag.
    pub fn parse(argv: &[String], option_keys: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.options
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if option_keys.contains(&body) && i + 1 < argv.len() {
                    out.options.insert(body.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_positional_and_flags() {
        let a = Args::parse(&sv(&["table2", "--verbose", "x.json"]), &[]);
        assert_eq!(a.positional, vec!["table2", "x.json"]);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn parses_options_both_styles() {
        let a = Args::parse(&sv(&["--device=u280", "--seed", "42"]), &["seed"]);
        assert_eq!(a.get("device"), Some("u280"));
        assert_eq!(a.get_usize("seed", 0), 42);
    }

    #[test]
    fn unknown_dashdash_is_flag() {
        let a = Args::parse(&sv(&["--fast", "value"]), &[]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.positional, vec!["value"]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&[], &[]);
        assert_eq!(a.get_or("device", "u250"), "u250");
        assert_eq!(a.get_f64("temp", 1.5), 1.5);
    }
}

//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with median/mean/min reporting in a
//! fixed-width table, used by every `benches/*.rs` target (declared with
//! `harness = false` in Cargo.toml).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub runs: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn report(&self) {
        println!(
            "{:<44} runs={:<3} min={:>10} median={:>10} mean={:>10} max={:>10}",
            self.name,
            self.runs,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.max)
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Time `f` with `warmup` unmeasured runs then `runs` measured runs.
/// A `black_box`-style sink prevents the optimizer from deleting the work:
/// callers should return a value from `f` that depends on the computation.
pub fn bench<T>(name: &str, warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        sink(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        sink(f());
        times.push(t0.elapsed());
    }
    times.sort();
    let total: Duration = times.iter().sum();
    let stats = Stats {
        name: name.to_string(),
        runs,
        min: times[0],
        median: times[times.len() / 2],
        mean: total / runs as u32,
        max: *times.last().unwrap(),
    };
    stats.report();
    stats
}

/// Opaque sink: prevents dead-code elimination of benchmark results.
#[inline]
pub fn sink<T>(value: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(value)
}

/// Simple fixed-width table printer used by the table/figure benches so the
/// output rows match the paper's presentation.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// Render to a string (used to write bench outputs into EXPERIMENTS.md).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let push_line = |cells: &[String], out: &mut String| {
            out.push('|');
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            out.push('\n');
        };
        push_line(&self.headers, &mut out);
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            push_line(row, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_plausible_stats() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.runs, 5);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["App", "Freq"]);
        t.row(&["CNN".into(), "335".into()]);
        let s = t.to_string();
        assert!(s.contains("| App | Freq |"));
        assert!(s.contains("| CNN | 335  |"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".into()]);
    }
}

//! Work-stealing thread-pool executor shared by every batch surface of
//! the evaluation matrix (Table 2 rows, the Figure 12 utilization sweep,
//! Figure 13 per-slot synthesis).
//!
//! Design notes:
//!
//! * **Scoped** — jobs may borrow from the caller's stack (designs,
//!   devices, configs) because execution happens inside
//!   [`std::thread::scope`]; no `Arc`/`'static` plumbing at call sites.
//! * **Work-stealing** — jobs are pre-distributed round-robin onto one
//!   deque per worker; a worker pops from the front of its own deque and,
//!   when empty, steals from the back of a victim's. Uneven job durations
//!   (a 13x12 CNN flow next to a KNN flow) therefore cannot leave
//!   workers idle while one queue is backed up.
//! * **Order-preserving** — [`Pool::par_map`] returns results in input
//!   order regardless of completion order, so paper tables render
//!   identically for any worker count.
//! * **Panic-transparent** — a panicking job does not wedge the pool;
//!   the payload is re-raised on the calling thread after all workers
//!   drain.
//!
//! Worker count resolution (CLI `--workers` > `RSIR_WORKERS` env >
//! available parallelism) lives in [`resolve_workers`].

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::Mutex;

/// Environment variable consulted by [`resolve_workers`] when no explicit
/// worker count is given.
pub const WORKERS_ENV: &str = "RSIR_WORKERS";

/// A fixed-width work-stealing executor.
///
/// The pool is a lightweight handle: threads are spawned per call (scoped
/// to it), so a `Pool` can be created once in `main` and passed by
/// reference through the coordinator without lifetime ceremony.
///
/// ```
/// use rsir::util::pool::Pool;
///
/// let pool = Pool::new(4);
/// let squares = pool.par_map((0..8).collect::<Vec<u64>>(), |x| x * x);
/// assert_eq!(squares, vec![0, 1, 4, 9, 16, 25, 36, 49]);
/// ```
#[derive(Debug, Clone)]
pub struct Pool {
    workers: usize,
}

impl Pool {
    /// Create a pool with a fixed worker count (clamped to at least 1).
    ///
    /// ```
    /// use rsir::util::pool::Pool;
    /// assert_eq!(Pool::new(0).workers(), 1); // never zero workers
    /// assert_eq!(Pool::new(6).workers(), 6);
    /// ```
    pub fn new(workers: usize) -> Pool {
        Pool {
            workers: workers.max(1),
        }
    }

    /// Create a pool from the standard resolution chain: an explicit CLI
    /// value (`--workers`), else the `RSIR_WORKERS` environment variable,
    /// else the machine's available parallelism.
    ///
    /// ```
    /// use rsir::util::pool::Pool;
    /// assert_eq!(Pool::from_env(Some(2)).workers(), 2);
    /// assert!(Pool::from_env(None).workers() >= 1);
    /// ```
    pub fn from_env(cli: Option<usize>) -> Pool {
        Pool::new(resolve_workers(cli))
    }

    /// Number of worker threads this pool schedules onto.
    ///
    /// ```
    /// assert_eq!(rsir::util::pool::Pool::new(3).workers(), 3);
    /// ```
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Map `f` over `items` on the pool, returning results **in input
    /// order**. With one worker (or one item) this degenerates to the
    /// plain serial `map`, so `--workers 1` is bit-for-bit equivalent to
    /// no pool at all.
    ///
    /// If any job panics, the panic is re-raised on the caller's thread
    /// after the remaining jobs finish.
    ///
    /// ```
    /// use rsir::util::pool::Pool;
    /// let out = Pool::new(3).par_map(vec!["a", "bb", "ccc"], |s| s.len());
    /// assert_eq!(out, vec![1, 2, 3]);
    /// ```
    ///
    /// Panic propagation:
    ///
    /// ```should_panic
    /// use rsir::util::pool::Pool;
    /// Pool::new(2).par_map(vec![1, 2], |x| { assert_ne!(x, 2); x });
    /// ```
    pub fn par_map<I, T, F>(&self, items: Vec<I>, f: F) -> Vec<T>
    where
        I: Send,
        T: Send,
        F: Fn(I) -> T + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let nw = self.workers.min(n);
        if nw == 1 {
            // Serial fast path: identical semantics, no thread overhead.
            return items
                .into_iter()
                .map(|item| {
                    stall_worker();
                    f(item)
                })
                .collect();
        }

        // One slot per job for the input (taken exactly once) and the
        // output (written exactly once); per-worker index deques seeded
        // round-robin.
        let inputs: Vec<Mutex<Option<I>>> =
            items.into_iter().map(|i| Mutex::new(Some(i))).collect();
        let outputs: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let queues: Vec<Mutex<VecDeque<usize>>> = (0..nw)
            .map(|w| Mutex::new((0..n).filter(|i| i % nw == w).collect()))
            .collect();
        let panics: Mutex<Vec<Box<dyn Any + Send>>> = Mutex::new(Vec::new());

        // Work is fully pre-distributed and never re-enqueued, so a queue
        // observed empty stays empty: a worker that finds no job anywhere
        // can simply exit (the scope joins stragglers) instead of
        // busy-spinning until the slowest job completes.
        std::thread::scope(|s| {
            for w in 0..nw {
                let (inputs, outputs, queues) = (&inputs, &outputs, &queues);
                let (panics, f) = (&panics, &f);
                s.spawn(move || {
                    while let Some(i) = pop_or_steal(queues, w) {
                        stall_worker();
                        let item = inputs[i]
                            .lock()
                            .unwrap()
                            .take()
                            .expect("pool job claimed twice");
                        match catch_unwind(AssertUnwindSafe(|| f(item))) {
                            Ok(v) => *outputs[i].lock().unwrap() = Some(v),
                            Err(payload) => panics.lock().unwrap().push(payload),
                        }
                    }
                });
            }
        });

        if let Some(payload) = panics.into_inner().unwrap().pop() {
            resume_unwind(payload);
        }
        outputs
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("pool job produced no result"))
            .collect()
    }

    /// Run a batch of independent closures to completion (scoped spawn:
    /// the closures may borrow from the caller's stack).
    ///
    /// ```
    /// use std::sync::atomic::{AtomicUsize, Ordering};
    /// use rsir::util::pool::Pool;
    ///
    /// let hits = AtomicUsize::new(0);
    /// let jobs: Vec<_> = (0..8)
    ///     .map(|_| || { hits.fetch_add(1, Ordering::SeqCst); })
    ///     .collect();
    /// Pool::new(4).run(jobs);
    /// assert_eq!(hits.load(Ordering::SeqCst), 8);
    /// ```
    pub fn run<F>(&self, jobs: Vec<F>)
    where
        F: FnOnce() + Send,
    {
        self.par_map(jobs, |job| job());
    }
}

/// Fault site `pool.worker`: every action degrades to a delay here. The
/// pool is panic-transparent by contract — an injected panic in its own
/// plumbing would resume on the *caller's* thread (for the daemon, the
/// server thread itself), which is precisely the process death the fault
/// plane exists to rule out. Panic injection into job *bodies* instead
/// happens at the `pool.job` site inside `server::ops::execute`, where
/// the per-job barrier catches it.
fn stall_worker() {
    if crate::testing::faults::point("pool.worker").is_some() {
        crate::testing::faults::injected_sleep();
    }
}

/// Pop from `w`'s own deque front, else steal one job from the back of
/// the first non-empty victim deque (scanning neighbors cyclically).
fn pop_or_steal(queues: &[Mutex<VecDeque<usize>>], w: usize) -> Option<usize> {
    if let Some(i) = queues[w].lock().unwrap().pop_front() {
        return Some(i);
    }
    let nw = queues.len();
    for k in 1..nw {
        let victim = (w + k) % nw;
        if let Some(i) = queues[victim].lock().unwrap().pop_back() {
            return Some(i);
        }
    }
    None
}

/// Resolve the effective worker count: an explicit (nonzero) CLI value
/// wins, then a nonzero `RSIR_WORKERS` environment variable, then the
/// machine's available parallelism (falling back to 4 when unknown).
///
/// ```
/// use rsir::util::pool::resolve_workers;
/// assert_eq!(resolve_workers(Some(5)), 5);
/// assert!(resolve_workers(None) >= 1);
/// ```
pub fn resolve_workers(cli: Option<usize>) -> usize {
    resolve_workers_or(
        cli,
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4),
    )
}

/// Like [`resolve_workers`], but falling back to an explicit `default`
/// instead of the machine's parallelism. Zero (CLI or env) means
/// "unset". Used by `fig13`, where the worker count is a modeling
/// parameter defaulting to the paper's 8 jobs.
///
/// ```
/// use rsir::util::pool::resolve_workers_or;
/// assert_eq!(resolve_workers_or(Some(3), 8), 3);
/// assert_eq!(resolve_workers_or(Some(0), 8), 8); // 0 = unset
/// ```
pub fn resolve_workers_or(cli: Option<usize>, default: usize) -> usize {
    if let Some(w) = cli {
        if w > 0 {
            return w;
        }
    }
    if let Ok(v) = std::env::var(WORKERS_ENV) {
        if let Ok(w) = v.trim().parse::<usize>() {
            if w > 0 {
                return w;
            }
        }
    }
    default.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn par_map_preserves_order_under_shuffled_durations() {
        // Durations deliberately anti-correlated with index so completion
        // order differs from input order.
        let pool = Pool::new(4);
        let out = pool.par_map((0..32usize).collect(), |i| {
            std::thread::sleep(Duration::from_millis(((i * 7) % 5) as u64));
            i * i
        });
        assert_eq!(out, (0..32usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_matches_serial_map() {
        let items: Vec<i64> = (0..100).collect();
        let serial: Vec<i64> = items.iter().map(|x| x * 3 + 1).collect();
        assert_eq!(Pool::new(1).par_map(items.clone(), |x| x * 3 + 1), serial);
        assert_eq!(Pool::new(7).par_map(items, |x| x * 3 + 1), serial);
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(Pool::new(16).par_map(vec![1, 2, 3], |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input_yields_empty_output() {
        let out: Vec<i32> = Pool::new(4).par_map(Vec::new(), |x: i32| x);
        assert!(out.is_empty());
    }

    #[test]
    fn panic_propagates_and_pool_drains() {
        let pool = Pool::new(4);
        let result = std::panic::catch_unwind(|| {
            pool.par_map((0..16usize).collect(), |x| {
                if x == 5 {
                    panic!("job 5 exploded");
                }
                x
            })
        });
        assert!(result.is_err());
    }

    #[test]
    fn run_executes_every_job_exactly_once() {
        let hits = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..40)
            .map(|_| || {
                hits.fetch_add(1, Ordering::SeqCst);
            })
            .collect();
        Pool::new(5).run(jobs);
        assert_eq!(hits.load(Ordering::SeqCst), 40);
    }

    #[test]
    fn stealing_drains_a_backed_up_queue() {
        // Job 0 (worker 0's queue) is slow; workers must steal the rest of
        // worker 0's round-robin share or this takes ~8x longer than the
        // asserted budget.
        let pool = Pool::new(2);
        let t0 = std::time::Instant::now();
        let out = pool.par_map((0..16usize).collect(), |i| {
            if i == 0 {
                std::thread::sleep(Duration::from_millis(120));
            }
            i
        });
        assert_eq!(out.len(), 16);
        // Generous bound: serial-behind-the-slow-job would be fine too;
        // what must never happen is a deadlock/livelock.
        assert!(t0.elapsed() < Duration::from_secs(10));
    }

    #[test]
    fn resolve_workers_precedence() {
        assert_eq!(resolve_workers(Some(5)), 5);
        std::env::set_var(WORKERS_ENV, "3");
        assert_eq!(resolve_workers(None), 3);
        assert_eq!(resolve_workers(Some(2)), 2, "CLI beats env");
        std::env::set_var(WORKERS_ENV, "not-a-number");
        assert!(resolve_workers(None) >= 1);
        std::env::remove_var(WORKERS_ENV);
        assert!(resolve_workers(None) >= 1);
        assert_eq!(resolve_workers(Some(0)), resolve_workers(None), "0 = unset");
        assert_eq!(resolve_workers_or(None, 8), 8);
        assert_eq!(resolve_workers_or(Some(0), 0), 1, "clamped to >= 1");
    }
}

//! Self-contained substrates: JSON, YAML emission, RNG, union-find,
//! CLI parsing, property testing, the benchmark harness, and the
//! work-stealing thread pool driving the evaluation matrix.

pub mod bench;
pub mod cli;
pub mod json;
pub mod lru;
pub mod pool;
pub mod quickcheck;
pub mod rng;
pub mod union_find;
pub mod yamlish;

//! Self-contained substrates: JSON, YAML emission, RNG, union-find,
//! CLI parsing, property testing, and the benchmark harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod union_find;
pub mod yamlish;

//! Deterministic pseudo-random number generation (xoshiro256**).
//!
//! Used by the simulated-annealing floorplan explorer, the benchmark design
//! generators, and the mini property-testing harness. Fully deterministic
//! from a seed so every experiment in EXPERIMENTS.md is reproducible.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Derive the `stream`-th independent generator from a base seed.
    ///
    /// Parallel consumers (e.g. the SA chains, one stream per chain) each
    /// take their own stream so their draw sequences are decorrelated and
    /// — crucially — insensitive to how many values the *other* streams
    /// consume. `stream(seed, 0)` is identical to `Rng::new(seed)`.
    pub fn stream(seed: u64, stream: u64) -> Self {
        // A distinct odd-constant multiply per stream index; the SplitMix64
        // expansion in `new` then decorrelates the similar inputs.
        Rng::new(seed.wrapping_add(stream.wrapping_mul(0xD1B54A32D192ED03)))
    }

    /// Seed via SplitMix64 so that similar seeds diverge immediately.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n). Uses Lemire's multiply-shift rejection method.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn stream_zero_matches_new_and_streams_diverge() {
        let mut base = Rng::new(77);
        let mut s0 = Rng::stream(77, 0);
        for _ in 0..32 {
            assert_eq!(base.next_u64(), s0.next_u64());
        }
        let firsts: Vec<u64> = (0..16).map(|c| Rng::stream(77, c).next_u64()).collect();
        let mut uniq = firsts.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), firsts.len(), "streams collide: {firsts:?}");
        // Same (seed, stream) pair reproduces the same sequence.
        let a: Vec<u64> = {
            let mut r = Rng::stream(5, 3);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::stream(5, 3);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..500 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        // Mean of 10k uniforms should be near 0.5.
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}

//! A small deterministic LRU map and its counter snapshot. Grown in the
//! daemon PR inside `server::cache`; promoted here when the incremental
//! re-flow engine (`coordinator::memo`, `timing::netlist`, `eda::synth`)
//! needed the same substrate below the server layer.

use crate::util::json::{Json, JsonObj};
use std::collections::BTreeMap;

/// A small deterministic LRU map: recency is a monotone tick, eviction
/// removes the smallest tick (an O(n) scan — caps are small and the scan
/// order over a `BTreeMap` is deterministic). `cap == 0` disables the
/// cache entirely (every `get` misses, `put` is a no-op) — that is what
/// the one-shot lane runs with.
#[derive(Debug)]
pub struct Lru<K: Ord + Clone, V> {
    cap: usize,
    map: BTreeMap<K, (u64, V)>,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl<K: Ord + Clone, V: Clone> Lru<K, V> {
    pub fn new(cap: usize) -> Self {
        Lru {
            cap,
            map: BTreeMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn get(&mut self, key: &K) -> Option<V> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some((t, v)) => {
                *t = self.tick;
                self.hits += 1;
                Some(v.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn put(&mut self, key: K, value: V) {
        if self.cap == 0 {
            return;
        }
        self.tick += 1;
        self.map.insert(key, (self.tick, value));
        if self.map.len() > self.cap {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone());
            if let Some(k) = oldest {
                self.map.remove(&k);
            }
        }
    }

    /// Drop an entry (used by integrity verification to evict a
    /// corrupted value). Does not touch the hit/miss counters.
    pub fn remove(&mut self, key: &K) -> Option<V> {
        self.map.remove(key).map(|(_, v)| v)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            len: self.map.len(),
            cap: self.cap,
        }
    }
}

/// FNV-1a over raw bytes: the integrity digest for [`VerifiedLru`]
/// payloads (cheap, deterministic, and plenty to detect bit flips —
/// this is corruption *detection*, not an adversarial MAC).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// An [`Lru`] whose entries carry a content digest, verified on every
/// hit: a corrupted entry (bit-flipped payload or digest — injected by
/// the fault plane or a real memory fault) is evicted and reported as a
/// miss, so corruption degrades to a cold recompute plus a diagnostic
/// counter instead of a wrong answer. Without corruption the hit/miss
/// accounting is byte-identical to a plain [`Lru`].
#[derive(Debug)]
pub struct VerifiedLru<K: Ord + Clone, V: Clone> {
    inner: Lru<K, (u64, V)>,
    digest: fn(&V) -> u64,
    corrupt_dropped: u64,
}

impl<K: Ord + Clone, V: Clone> VerifiedLru<K, V> {
    pub fn new(cap: usize, digest: fn(&V) -> u64) -> Self {
        VerifiedLru {
            inner: Lru::new(cap),
            digest,
            corrupt_dropped: 0,
        }
    }

    /// Lookup with verification. `inject_corrupt` is the fault plane's
    /// hook: it simulates reading back a flipped payload (always `false`
    /// in production paths).
    pub fn get(&mut self, key: &K, inject_corrupt: bool) -> Option<V> {
        let (mut stored, v) = self.inner.get(key)?;
        if inject_corrupt {
            stored ^= 1;
        }
        if (self.digest)(&v) != stored {
            self.inner.remove(key);
            self.corrupt_dropped += 1;
            eprintln!("rsir: dropped corrupted cache entry (digest mismatch); recomputing cold");
            return None;
        }
        Some(v)
    }

    /// Insert with a freshly computed digest; `inject_corrupt` stores a
    /// flipped digest so the *next* hit fails verification.
    pub fn put(&mut self, key: K, value: V, inject_corrupt: bool) {
        let mut d = (self.digest)(&value);
        if inject_corrupt {
            d ^= 1;
        }
        self.inner.put(key, (d, value));
    }

    /// How many entries verification has evicted (the corruption
    /// diagnostic surfaced in daemon `stats`).
    pub fn corrupt_dropped(&self) -> u64 {
        self.corrupt_dropped
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.stats()
    }
}

/// Snapshot of one cache's counters, rendered by the `stats` request.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub len: usize,
    pub cap: usize,
}

impl CacheStats {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("hits", Json::num(self.hits as f64));
        o.insert("misses", Json::num(self.misses as f64));
        o.insert("len", Json::num(self.len as f64));
        o.insert("cap", Json::num(self.cap as f64));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut lru: Lru<u32, u32> = Lru::new(2);
        lru.put(1, 10);
        lru.put(2, 20);
        assert_eq!(lru.get(&1), Some(10)); // 1 is now most recent
        lru.put(3, 30); // evicts 2
        assert_eq!(lru.get(&2), None);
        assert_eq!(lru.get(&1), Some(10));
        assert_eq!(lru.get(&3), Some(30));
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn lru_counts_hits_and_misses() {
        let mut lru: Lru<u32, u32> = Lru::new(4);
        lru.put(1, 1);
        lru.get(&1);
        lru.get(&9);
        let s = lru.stats();
        assert_eq!((s.hits, s.misses, s.len, s.cap), (1, 1, 1, 4));
    }

    #[test]
    fn zero_cap_disables() {
        let mut lru: Lru<u32, u32> = Lru::new(0);
        lru.put(1, 1);
        assert_eq!(lru.get(&1), None);
        assert!(lru.is_empty());
    }

    #[test]
    fn remove_drops_entry_without_counting() {
        let mut lru: Lru<u32, u32> = Lru::new(4);
        lru.put(1, 10);
        assert_eq!(lru.remove(&1), Some(10));
        assert_eq!(lru.remove(&1), None);
        let s = lru.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
    }

    fn digest_u32(v: &u32) -> u64 {
        fnv1a64(&v.to_le_bytes())
    }

    #[test]
    fn verified_lru_matches_plain_lru_without_corruption() {
        let mut v: VerifiedLru<u32, u32> = VerifiedLru::new(2, digest_u32);
        v.put(1, 10, false);
        assert_eq!(v.get(&1, false), Some(10));
        assert_eq!(v.get(&9, false), None);
        let s = v.stats();
        assert_eq!((s.hits, s.misses, s.len), (1, 1, 1));
        assert_eq!(v.corrupt_dropped(), 0);
    }

    #[test]
    fn verified_lru_evicts_corrupted_entries_as_misses() {
        let mut v: VerifiedLru<u32, u32> = VerifiedLru::new(4, digest_u32);
        // Corrupted at insert: the next get detects and evicts.
        v.put(1, 10, true);
        assert_eq!(v.get(&1, false), None);
        assert_eq!(v.corrupt_dropped(), 1);
        // Entry is gone — a clean re-insert works again.
        v.put(1, 10, false);
        assert_eq!(v.get(&1, false), Some(10));
        // Corrupted read-back of a clean entry: also evicted.
        assert_eq!(v.get(&1, true), None);
        assert_eq!(v.corrupt_dropped(), 2);
        assert_eq!(v.get(&1, false), None, "corrupt entry must not linger");
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
    }
}

//! A small deterministic LRU map and its counter snapshot. Grown in the
//! daemon PR inside `server::cache`; promoted here when the incremental
//! re-flow engine (`coordinator::memo`, `timing::netlist`, `eda::synth`)
//! needed the same substrate below the server layer.

use crate::util::json::{Json, JsonObj};
use std::collections::BTreeMap;

/// A small deterministic LRU map: recency is a monotone tick, eviction
/// removes the smallest tick (an O(n) scan — caps are small and the scan
/// order over a `BTreeMap` is deterministic). `cap == 0` disables the
/// cache entirely (every `get` misses, `put` is a no-op) — that is what
/// the one-shot lane runs with.
#[derive(Debug)]
pub struct Lru<K: Ord + Clone, V> {
    cap: usize,
    map: BTreeMap<K, (u64, V)>,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl<K: Ord + Clone, V: Clone> Lru<K, V> {
    pub fn new(cap: usize) -> Self {
        Lru {
            cap,
            map: BTreeMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn get(&mut self, key: &K) -> Option<V> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some((t, v)) => {
                *t = self.tick;
                self.hits += 1;
                Some(v.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn put(&mut self, key: K, value: V) {
        if self.cap == 0 {
            return;
        }
        self.tick += 1;
        self.map.insert(key, (self.tick, value));
        if self.map.len() > self.cap {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone());
            if let Some(k) = oldest {
                self.map.remove(&k);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            len: self.map.len(),
            cap: self.cap,
        }
    }
}

/// Snapshot of one cache's counters, rendered by the `stats` request.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub len: usize,
    pub cap: usize,
}

impl CacheStats {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("hits", Json::num(self.hits as f64));
        o.insert("misses", Json::num(self.misses as f64));
        o.insert("len", Json::num(self.len as f64));
        o.insert("cap", Json::num(self.cap as f64));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut lru: Lru<u32, u32> = Lru::new(2);
        lru.put(1, 10);
        lru.put(2, 20);
        assert_eq!(lru.get(&1), Some(10)); // 1 is now most recent
        lru.put(3, 30); // evicts 2
        assert_eq!(lru.get(&2), None);
        assert_eq!(lru.get(&1), Some(10));
        assert_eq!(lru.get(&3), Some(30));
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn lru_counts_hits_and_misses() {
        let mut lru: Lru<u32, u32> = Lru::new(4);
        lru.put(1, 1);
        lru.get(&1);
        lru.get(&9);
        let s = lru.stats();
        assert_eq!((s.hits, s.misses, s.len, s.cap), (1, 1, 1, 4));
    }

    #[test]
    fn zero_cap_disables() {
        let mut lru: Lru<u32, u32> = Lru::new(0);
        lru.put(1, 1);
        assert_eq!(lru.get(&1), None);
        assert!(lru.is_empty());
    }
}

//! CHIP-KNN k-nearest-neighbours accelerator (§4.4 item 4 [29]): HLS
//! distance kernels behind a large custom RTL interconnect, packed as a
//! Vitis XO container — RIR "directly ingests the Vitis-packed Xilinx
//! Object (XO) files … acting as a transparent plugin to the Vitis
//! framework". The monolithic interconnect is what sinks the vendor
//! baseline (unroutable, "-" in Table 2).

use crate::designs::common::*;
use crate::ir::core::*;
use crate::util::json::Json;
use anyhow::Result;

pub struct KnnConfig {
    pub kernels: usize,
}

impl Default for KnnConfig {
    fn default() -> Self {
        KnnConfig { kernels: 4 }
    }
}

/// Build the XO manifest text (the artifact a user would hand RIR).
pub fn xo_manifest(cfg: &KnnConfig) -> String {
    let n = cfg.kernels;
    let mut sources: Vec<String> = Vec::new();
    // HLS distance kernels.
    sources.push(hls_kernel_verilog(
        "DistCore",
        &[("q", Dir::In, 512), ("d", Dir::Out, 512)],
    ));
    // Custom RTL interconnect: wide crossbar + top-K merger in one
    // monolithic module (the real CHIP-KNN interconnect is handwritten).
    let mut xbar = String::from(
        "// Custom RTL interconnect: query broadcast + top-K merge tree.\nmodule KnnXbar (\n  input wire ap_clk,\n  input wire ap_rst_n,\n  input wire [511:0] query, input wire query_vld, output wire query_rdy,\n  output wire [511:0] hits, output wire hits_vld, input wire hits_rdy",
    );
    for k in 0..n {
        xbar.push_str(&format!(
            ",\n  output wire [511:0] q{k}, output wire q{k}_vld, input wire q{k}_rdy"
        ));
        xbar.push_str(&format!(
            ",\n  input wire [511:0] d{k}, input wire d{k}_vld, output wire d{k}_rdy"
        ));
    }
    xbar.push_str("\n);\n// pragma clock port=ap_clk\n// pragma reset port=ap_rst_n active=low\n// pragma handshake pattern={bundle}{role} role.valid=_vld role.ready=_rdy role.data=.*\n// pragma handshake pattern=query{role} role.valid=_vld role.ready=_rdy role.data=.*\n// pragma handshake pattern=hits{role} role.valid=_vld role.ready=_rdy role.data=.*\n  reg [511:0] merge_acc;\n  always @(posedge ap_clk) if (query_vld) merge_acc <= query;\n");
    for k in 0..n {
        xbar.push_str(&format!("  assign q{k} = merge_acc;\n  assign q{k}_vld = query_vld;\n  assign d{k}_rdy = hits_rdy;\n"));
    }
    xbar.push_str("  assign query_rdy = 1'b1;\n  assign hits = merge_acc;\n  assign hits_vld = query_vld;\nendmodule\n");
    sources.push(xbar);

    // Kernel top wiring the crossbar to the dist cores.
    let mut top = String::from(
        "module krnl_knn (\n  input wire ap_clk,\n  input wire ap_rst_n,\n  input wire [511:0] query, input wire query_vld, output wire query_rdy,\n  output wire [511:0] hits, output wire hits_vld, input wire hits_rdy\n);\n// pragma clock port=ap_clk\n// pragma reset port=ap_rst_n active=low\n// pragma handshake pattern=query{role} role.valid=_vld role.ready=_rdy role.data=.*\n// pragma handshake pattern=hits{role} role.valid=_vld role.ready=_rdy role.data=.*\n",
    );
    for k in 0..n {
        top.push_str(&hs_wires(&format!("q{k}"), 512));
        top.push_str(&hs_wires(&format!("d{k}"), 512));
    }
    top.push_str("  KnnXbar xbar (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),\n    .query(query), .query_vld(query_vld), .query_rdy(query_rdy),\n    .hits(hits), .hits_vld(hits_vld), .hits_rdy(hits_rdy)");
    for k in 0..n {
        top.push_str(&format!(
            ",\n    .q{k}(q{k}), .q{k}_vld(q{k}_vld), .q{k}_rdy(q{k}_rdy),\n    .d{k}(d{k}), .d{k}_vld(d{k}_vld), .d{k}_rdy(d{k}_rdy)"
        ));
    }
    top.push_str(");\n");
    for k in 0..n {
        top.push_str(&format!(
            "  DistCore dc{k} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\n",
            hs_conn("q", &format!("q{k}")),
            hs_conn("d", &format!("d{k}")),
        ));
    }
    top.push_str("endmodule\n");
    sources.push(top);

    let mut o = crate::util::json::JsonObj::new();
    o.insert("kernel", Json::str("krnl_knn"));
    o.insert("top", Json::str("krnl_knn"));
    o.insert(
        "sources",
        Json::Arr(sources.iter().map(|s| Json::str(s)).collect()),
    );
    Json::Obj(o).pretty()
}

pub fn generate(cfg: &KnnConfig) -> Result<Generated> {
    let manifest = xo_manifest(cfg);
    let mods = crate::plugins::xo::import_xo(&manifest)?;
    let mut design = Design::new("krnl_knn");
    for m in mods {
        design.add(m);
    }
    // Characterization: big monolithic RTL interconnect + DSP-heavy cores.
    crate::ir::builder::set_module_resources(
        design.module_mut("KnnXbar").unwrap(),
        Resources::new(150_000.0, 190_000.0, 90.0, 0.0, 0.0),
    );
    {
        let x = design.module_mut("KnnXbar").unwrap();
        let mut t = crate::util::json::JsonObj::new();
        t.insert("internal_ns", Json::num(3.3));
        x.metadata.insert("timing", Json::Obj(t));
    }
    crate::ir::builder::set_module_resources(
        design.module_mut("DistCore").unwrap(),
        Resources::new(140_000.0, 120_000.0, 28.0, 900.0, 0.0),
    );
    {
        let c = design.module_mut("DistCore").unwrap();
        let mut t = crate::util::json::JsonObj::new();
        t.insert("internal_ns", Json::num(3.25));
        c.metadata.insert("timing", Json::Obj(t));
    }
    Ok(Generated {
        name: "knn".to_string(),
        design,
        sources: vec![manifest],
        hls_report: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::manager::{Pass, PassContext};

    #[test]
    fn imports_from_xo() {
        let g = generate(&KnnConfig::default()).unwrap();
        assert!(g.design.module("krnl_knn").unwrap().metadata.contains_key("xo_kernel"));
        let xbar = g.design.module("KnnXbar").unwrap();
        assert_eq!(xbar.interface_of("q0").unwrap().kind(), "handshake");
    }

    #[test]
    fn rebuilds_and_exports_back_to_xo() {
        let g = generate(&KnnConfig::default()).unwrap();
        let mut d = g.design;
        crate::passes::rebuild::RebuildAll
            .run(&mut d, &mut PassContext::new())
            .unwrap();
        crate::ir::validate::assert_clean(&d);
        // Transparent-plugin path: export back into an XO manifest.
        let out = crate::plugins::xo::export_xo(&d, "krnl_knn").unwrap();
        assert!(out.contains("\"kernel\": \"krnl_knn\""));
    }
}

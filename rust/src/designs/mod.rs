//! Benchmark design generators (§4.1/§4.4): real Verilog/VHDL/manifest
//! artifacts imported through the standard plugins, reproducing the
//! structure of the paper's evaluation designs.

pub mod catapult;
pub mod cnn;
pub mod common;
pub mod dynamatic;
pub mod intel_hls;
pub mod knn;
pub mod llama2;
pub mod minimap2;

pub use common::Generated;

//! Benchmark design generators (§4.1/§4.4): real Verilog/VHDL/manifest
//! artifacts imported through the standard plugins, reproducing the
//! structure of the paper's evaluation designs — plus [`synthetic`], the
//! seeded generator of arbitrary valid designs that feeds the
//! differential fuzzing harness (`testing::oracle`).

pub mod catapult;
pub mod cnn;
pub mod common;
pub mod dynamatic;
pub mod intel_hls;
pub mod knn;
pub mod llama2;
pub mod minimap2;
pub mod synthetic;

pub use common::Generated;

//! LLaMA2 hybrid-source accelerator (§4.4 item 2, the paper's motivating
//! example [8]): HLS transformer kernels + handwritten RTL loaders +
//! Xilinx IPs, composed through a four-level Verilog hierarchy
//! (top → stack → block → attention/FFN kernels), with control logic in
//! the top body. AutoBridge cannot ingest this shape; RIR rebuilds it.
//!
//! `opt: true` generates the "LLaMA2 (opt)" variant of Table 2: the HLS
//! functions decomposed into smaller pipelinable halves (qkv/softmax·v,
//! ffn up/down), which both shrinks each floorplan unit and shortens the
//! kernels' internal critical paths.

use crate::designs::common::*;
use crate::ir::core::*;
use crate::util::json::Json;
use anyhow::Result;

pub struct Llama2Config {
    pub blocks: usize,
    pub opt: bool,
}

impl Default for Llama2Config {
    fn default() -> Self {
        Llama2Config {
            blocks: 4,
            opt: false,
        }
    }
}

pub fn generate(cfg: &Llama2Config) -> Result<Generated> {
    let name = if cfg.opt { "llama2_opt" } else { "llama2" }.to_string();
    let n = cfg.blocks;
    let scale = if cfg.opt { 0.72 } else { 1.0 };

    // ---- Handwritten RTL: loaders with AXI pragmas ---------------------
    let input_loader = r#"// Handwritten RTL memory input loader (cf. Fig 9).
module InputLoader (
  input  wire ap_clk,
  input  wire ap_rst_n,
  output wire m_axi_ARVALID, input wire m_axi_ARREADY,
  output wire [63:0] m_axi_ARADDR,
  input  wire m_axi_RVALID, output wire m_axi_RREADY,
  input  wire [511:0] m_axi_RDATA,
  output wire [511:0] tok, output wire tok_vld, input wire tok_rdy
);
// pragma clock port=ap_clk
// pragma reset port=ap_rst_n active=low
// pragma handshake pattern=m_axi_{bundle}{role} \
//        role.valid=VALID role.ready=READY role.data=.*
// pragma handshake pattern=tok{role} role.valid=_vld role.ready=_rdy role.data=.*
  reg [15:0] burst_cnt;
  always @(posedge ap_clk) begin
    if (!ap_rst_n) burst_cnt <= 16'd0;
    else if (m_axi_RVALID & m_axi_RREADY) burst_cnt <= burst_cnt + 1;
  end
  assign m_axi_ARVALID = tok_rdy & ~burst_cnt[15];
  assign m_axi_ARADDR = {48'd0, burst_cnt};
  assign m_axi_RREADY = tok_rdy;
  assign tok = m_axi_RDATA;
  assign tok_vld = m_axi_RVALID;
endmodule
"#
    .to_string();

    let out_fifo = r#"// Handwritten output FIFO RTL.
module OutFIFO (
  input  wire ap_clk,
  input  wire ap_rst_n,
  input  wire [511:0] I, input wire I_vld, output reg I_rdy,
  output reg [511:0] O, output reg O_vld, input wire O_rdy
);
// pragma clock port=ap_clk
// pragma reset port=ap_rst_n active=low
// pragma handshake pattern={bundle}{role} role.valid=_vld role.ready=_rdy role.data=.*
  reg [511:0] buf0;
  reg full;
  always @(posedge ap_clk) begin
    if (!ap_rst_n) begin full <= 1'b0; O_vld <= 1'b0; I_rdy <= 1'b0; end
    else begin
      I_rdy <= ~full;
      if (I_vld & I_rdy) begin buf0 <= I; full <= 1'b1; end
      if (full & (~O_vld | O_rdy)) begin O <= buf0; O_vld <= 1'b1; full <= 1'b0; end
      else if (O_rdy) O_vld <= 1'b0;
    end
  end
endmodule
"#
    .to_string();

    // ---- Xilinx IP: HBM AXI bridge (XCI manifest surrogate) ------------
    let hbm_manifest = crate::plugins::xci::manifest_for(
        "hbm_axi_bridge",
        "xilinx.com:ip:hbm_axi_bridge:1.0",
        &[
            ("aclk".to_string(), Dir::In, 1),
            ("ARVALID".to_string(), Dir::In, 1),
            ("ARREADY".to_string(), Dir::Out, 1),
            ("ARADDR".to_string(), Dir::In, 64),
            ("RVALID".to_string(), Dir::Out, 1),
            ("RREADY".to_string(), Dir::In, 1),
            ("RDATA".to_string(), Dir::Out, 512),
        ],
        &Resources::new(11_000.0, 16_000.0, 12.0, 0.0, 0.0),
    );

    // ---- HLS kernels ----------------------------------------------------
    let mut sources = vec![input_loader, out_fifo];
    let mut entries: Vec<(String, Json)> = Vec::new();
    let hs_io: [(&str, Dir, u32); 2] = [("i", Dir::In, 512), ("o", Dir::Out, 512)];
    let rep_io: [(&str, &str, u32); 2] = [("i", "in", 512), ("o", "out", 512)];
    let kernel_names: Vec<&str> = if cfg.opt {
        vec!["AttnQKV", "AttnSV", "FfnUp", "FfnDown"]
    } else {
        vec!["Attention", "Ffn"]
    };
    for k in &kernel_names {
        sources.push(hls_kernel_verilog(k, &hs_io));
        let (lut, ff, bram, dsp, uram, t) = match (*k, cfg.opt) {
            ("Attention", _) => (55_000.0, 75_000.0, 60.0, 180.0, 30.0, 3.85),
            ("Ffn", _) => (70_000.0, 82_000.0, 58.0, 220.0, 30.0, 3.85),
            ("AttnQKV", _) => (28_000.0, 38_000.0, 30.0, 95.0, 15.0, 3.0),
            ("AttnSV", _) => (26_000.0, 36_000.0, 28.0, 85.0, 15.0, 3.0),
            ("FfnUp", _) => (36_000.0, 42_000.0, 30.0, 115.0, 15.0, 3.05),
            ("FfnDown", _) => (34_000.0, 40_000.0, 28.0, 105.0, 15.0, 3.05),
            _ => unreachable!(),
        };
        entries.push((
            k.to_string(),
            report_entry(&Resources::new(lut, ff, bram, dsp, uram), t, &rep_io),
        ));
    }
    // Embed + head kernels.
    sources.push(hls_kernel_verilog("Embed", &hs_io));
    sources.push(hls_kernel_verilog("Head", &hs_io));
    entries.push((
        "Embed".into(),
        report_entry(
            &Resources::new(22_000.0 * scale, 30_000.0 * scale, 40.0, 60.0, 20.0),
            3.6,
            &rep_io,
        ),
    ));
    entries.push((
        "Head".into(),
        report_entry(
            &Resources::new(30_000.0 * scale, 36_000.0 * scale, 30.0, 140.0, 10.0),
            3.7,
            &rep_io,
        ),
    ));

    // ---- Block level (Verilog, rebuildable) -----------------------------
    let block_body = if cfg.opt {
        format!(
            "module Block (\n  input wire ap_clk,\n  input wire ap_rst_n,\n  input  wire [511:0] i, input wire i_vld, output wire i_rdy,\n  output wire [511:0] o, output wire o_vld, input wire o_rdy\n);\n{}{}{}\n  AttnQKV qkv (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\n  AttnSV sv (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\n  FfnUp up (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\n  FfnDown down (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\nendmodule\n",
            hs_wires("x0", 512),
            hs_wires("x1", 512),
            hs_wires("x2", 512),
            hs_conn("i", "i"),
            hs_conn("o", "x0"),
            hs_conn("i", "x0"),
            hs_conn("o", "x1"),
            hs_conn("i", "x1"),
            hs_conn("o", "x2"),
            hs_conn("i", "x2"),
            hs_conn("o", "o"),
        )
    } else {
        format!(
            "module Block (\n  input wire ap_clk,\n  input wire ap_rst_n,\n  input  wire [511:0] i, input wire i_vld, output wire i_rdy,\n  output wire [511:0] o, output wire o_vld, input wire o_rdy\n);\n{}\n  Attention attn (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\n  Ffn ffn (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\nendmodule\n",
            hs_wires("x0", 512),
            hs_conn("i", "i"),
            hs_conn("o", "x0"),
            hs_conn("i", "x0"),
            hs_conn("o", "o"),
        )
    };
    sources.push(block_body);

    // ---- Stack level -----------------------------------------------------
    let mut stack = String::from(
        "module Stack (\n  input wire ap_clk,\n  input wire ap_rst_n,\n  input  wire [511:0] i, input wire i_vld, output wire i_rdy,\n  output wire [511:0] o, output wire o_vld, input wire o_rdy\n);\n",
    );
    for b in 0..n.saturating_sub(1) {
        stack.push_str(&hs_wires(&format!("s{b}"), 512));
    }
    for b in 0..n {
        let iw = if b == 0 {
            "i".to_string()
        } else {
            format!("s{}", b - 1)
        };
        let ow = if b + 1 == n {
            "o".to_string()
        } else {
            format!("s{b}")
        };
        stack.push_str(&format!(
            "  Block blk{b} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\n",
            hs_conn("i", &iw),
            hs_conn("o", &ow),
        ));
    }
    stack.push_str("endmodule\n");
    sources.push(stack);

    // ---- Top level with control logic -----------------------------------
    let top = format!(
        r#"// LLaMA2 accelerator top: RTL + IP + HLS, control logic inline.
module {name} (
  input  wire ap_clk,
  input  wire ap_rst_n,
  output wire [511:0] result, output wire result_vld, input wire result_rdy
);
{w_tok}{w_emb}{w_stk}{w_head}{w_axi}
  reg [7:0] seq_state;
  wire advance = tok_vld & tok_rdy;
  always @(posedge ap_clk) begin
    if (!ap_rst_n) seq_state <= 8'd0;
    else if (advance) seq_state <= seq_state + 8'd1;
  end

  hbm_axi_bridge hbm0 (.aclk(ap_clk),
    .ARVALID(ar_v), .ARREADY(ar_r), .ARADDR(ar_a),
    .RVALID(r_v), .RREADY(r_r), .RDATA(r_d));
  InputLoader il (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
    .m_axi_ARVALID(ar_v), .m_axi_ARREADY(ar_r), .m_axi_ARADDR(ar_a),
    .m_axi_RVALID(r_v), .m_axi_RREADY(r_r), .m_axi_RDATA(r_d),
    .tok(tok), .tok_vld(tok_vld), .tok_rdy(tok_rdy));
  Embed emb (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {emb_i}, {emb_o});
  Stack stack (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {stk_i}, {stk_o});
  Head head (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {head_i}, {head_o});
  OutFIFO ofifo (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n),
    .I(hd), .I_vld(hd_vld & ~seq_state[7]), .I_rdy(hd_rdy),
    .O(result), .O_vld(result_vld), .O_rdy(result_rdy));
endmodule
"#,
        name = name,
        w_tok = hs_wires("tok", 512),
        w_emb = hs_wires("eb", 512),
        w_stk = hs_wires("sk", 512),
        w_head = hs_wires("hd", 512),
        w_axi = "  wire ar_v; wire ar_r; wire [63:0] ar_a;\n  wire r_v; wire r_r; wire [511:0] r_d;\n",
        emb_i = hs_conn("i", "tok"),
        emb_o = hs_conn("o", "eb"),
        stk_i = hs_conn("i", "eb"),
        stk_o = hs_conn("o", "sk"),
        head_i = hs_conn("i", "sk"),
        head_o = hs_conn("o", "hd"),
    );
    sources.push(top);

    // ---- Assemble through the plugins ------------------------------------
    let src_refs: Vec<&str> = sources.iter().map(|s| s.as_str()).collect();
    let mut design = crate::plugins::importer::import_design(&name, &src_refs)?;
    design.add(crate::plugins::xci::import_xci(&hbm_manifest)?);
    let report_text = report(&entries);
    crate::plugins::hls_report::apply_report(&mut design, &report_text)?;
    // RTL loader resources (handwritten modules get explicit estimates —
    // their real-world counterparts are big burst engines).
    crate::ir::builder::set_module_resources(
        design.module_mut("InputLoader").unwrap(),
        Resources::new(24_000.0 * scale, 30_000.0, 30.0, 0.0, 0.0),
    );
    crate::ir::builder::set_module_resources(
        design.module_mut("OutFIFO").unwrap(),
        Resources::new(14_000.0 * scale, 22_000.0, 24.0, 0.0, 0.0),
    );
    let t = design.module_mut(&name).unwrap();
    t.interfaces.push(Interface::Clock {
        port: "ap_clk".into(),
    });
    t.interfaces.push(Interface::Reset {
        port: "ap_rst_n".into(),
        active_high: false,
    });
    t.interfaces.push(Interface::Handshake {
        name: "result".into(),
        data: vec!["result".into()],
        valid: "result_vld".into(),
        ready: "result_rdy".into(),
        clk: Some("ap_clk".into()),
    });
    Ok(Generated {
        name,
        design,
        sources,
        hls_report: Some(report_text),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::manager::{Pass, PassContext};

    #[test]
    fn generates_hybrid_design() {
        let g = generate(&Llama2Config::default()).unwrap();
        let d = &g.design;
        // Mixed sources present.
        assert!(matches!(
            d.module("hbm_axi_bridge").unwrap().body,
            Body::Leaf {
                format: SourceFormat::Xci,
                ..
            }
        ));
        assert!(d.module("InputLoader").is_some());
        assert!(d.module("Attention").is_some());
        // Pragmas produced AXI handshakes on the RTL loader.
        let il = d.module("InputLoader").unwrap();
        assert_eq!(il.interface_of("m_axi_ARADDR").unwrap().kind(), "handshake");
        assert_eq!(il.interface_of("tok").unwrap().kind(), "handshake");
    }

    #[test]
    fn four_level_hierarchy_rebuilds() {
        let g = generate(&Llama2Config::default()).unwrap();
        let mut d = g.design;
        let mut ctx = PassContext::new();
        crate::passes::rebuild::RebuildAll.run(&mut d, &mut ctx).unwrap();
        crate::ir::validate::assert_clean(&d);
        // top, Stack, Block all became grouped.
        assert!(d.module("llama2").unwrap().is_grouped());
        assert!(d.module("Stack").unwrap().is_grouped());
        assert!(d.module("Block").unwrap().is_grouped());
        // kernels stay leaves
        assert!(d.module("Attention").unwrap().is_leaf());
    }

    #[test]
    fn opt_variant_smaller_and_finer() {
        let base = generate(&Llama2Config::default()).unwrap();
        let opt = generate(&Llama2Config {
            blocks: 4,
            opt: true,
        })
        .unwrap();
        let res = |g: &Generated| {
            let mut d = g.design.clone();
            crate::passes::rebuild::RebuildAll
                .run(&mut d, &mut PassContext::new())
                .unwrap();
            crate::plugins::platform::total_resources(&d)
        };
        let (rb, ro) = (res(&base), res(&opt));
        assert!(ro.lut < rb.lut);
        // More, smaller kernels.
        assert!(opt.design.module("AttnQKV").is_some());
        assert!(opt.design.module("Attention").is_none());
    }
}

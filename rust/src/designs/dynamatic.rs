//! Dynamatic HLS frontend (§4.1): the open-source dynamically-scheduled
//! HLS compiler emits VHDL elastic circuits with consistent
//! `<bundle>_<role>` port naming. Supporting it in RIR takes a metadata
//! parser (the shared VHDL importer), an interface analyzer (the rule set
//! below — the paper used 20 Python rules; Fig 11 shows two), and the
//! shared code rewriter. Table 1 counts the per-tool adaptation code —
//! [`support_loc`] measures ours the same way.

use crate::designs::common::Generated;
use crate::ir::core::*;
use crate::plugins::iface_rules::RuleSet;
use crate::util::rng::Rng;
use anyhow::Result;

/// All 29 examples of the Dynamatic repository [14].
pub const EXAMPLES: [&str; 29] = [
    "binary_search", "bicg", "fir", "fft", "gaussian", "gemm", "gesummv",
    "gsum", "gsumif", "histogram", "if_loop_add", "if_loop_mul", "iir",
    "image_resize", "insertion_sort", "kernel_2mm", "kernel_3mm", "kmp",
    "loop_array", "matrix", "matrix_power", "matvec", "memory_loop",
    "mul_example", "pivot", "sobel", "spmv", "stencil_2d", "triangular",
];

// BEGIN-FRONTEND (counted by support_loc / Table 1)
/// Interface rules for Dynamatic-generated VHDL (cf. Figure 11).
pub fn rules() -> RuleSet {
    RuleSet::new()
        .add_clock(".*", "clk|clock")
        .add_reset(".*", "rst|reset", "high")
        // Elastic channels: <bundle>_<role> with in/out data payloads.
        .add_handshake(".*", "{bundle}_{role}", "valid|pValid", "ready|nReady", "in|out|data|din|dout|addr")
        // Memory-controller buses are latency-sensitive.
        .add_nonpipeline(".*_mc", "address|we|ce")
        .add_feedforward(".*", "start|end_signal")
}

/// Import one Dynamatic VHDL source into a design and apply the rules.
pub fn import(top: &str, vhdl_sources: &[&str]) -> Result<Design> {
    let mut d = Design::new(top);
    for src in vhdl_sources {
        d.add(crate::plugins::importer::import_vhdl(src)?);
    }
    rules().apply(&mut d)?;
    Ok(d)
}
// END-FRONTEND

/// Lines of adaptation code for Table 1 (the BEGIN/END-FRONTEND region).
pub fn support_loc() -> usize {
    let src = include_str!("dynamatic.rs");
    count_frontend_loc(src)
}

pub(crate) fn count_frontend_loc(src: &str) -> usize {
    let mut counting = false;
    let mut n = 0;
    for line in src.lines() {
        if line.contains("BEGIN-FRONTEND") {
            counting = true;
            continue;
        }
        if line.contains("END-FRONTEND") {
            counting = false;
            continue;
        }
        if counting && !line.trim().is_empty() {
            n += 1;
        }
    }
    n
}

/// Generate a synthetic Dynamatic-style VHDL benchmark: a small elastic
/// dataflow seeded by the example's name (operator cores joined by
/// valid/ready channels, the shape `dynamatic --simple-buffers` emits).
pub fn generate(example: &str) -> Result<Generated> {
    let seed = example.bytes().fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
    let mut rng = Rng::new(seed);
    let n_ops = rng.range(3, 8);
    let mut sources = Vec::new();
    // Operator entity (shared).
    sources.push(
        "library ieee;\nentity elastic_op is\n  port (\n    clk : in std_logic;\n    rst : in std_logic;\n    a_data : in std_logic_vector(31 downto 0);\n    a_valid : in std_logic;\n    a_ready : out std_logic;\n    r_data : out std_logic_vector(31 downto 0);\n    r_valid : out std_logic;\n    r_ready : in std_logic\n  );\nend entity;\narchitecture rtl of elastic_op is begin end rtl;\n".to_string(),
    );
    // Top entity.
    let mut top = format!(
        "library ieee;\nentity {example} is\n  port (\n    clk : in std_logic;\n    rst : in std_logic;\n    in0_data : in std_logic_vector(31 downto 0);\n    in0_valid : in std_logic;\n    in0_ready : out std_logic;\n    out0_data : out std_logic_vector(31 downto 0);\n    out0_valid : out std_logic;\n    out0_ready : in std_logic\n  );\nend entity;\narchitecture rtl of {example} is\nbegin\n"
    );
    for k in 0..n_ops {
        top.push_str(&format!("  op{k}: entity work.elastic_op port map (clk, rst, ...);\n"));
    }
    top.push_str("end rtl;\n");
    sources.push(top);

    let src_refs: Vec<&str> = sources.iter().map(|s| s.as_str()).collect();
    let design = import(example, &src_refs)?;
    Ok(Generated {
        name: format!("dynamatic_{example}"),
        design,
        sources,
        hls_report: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_29_examples_import_with_full_interfaces() {
        for ex in EXAMPLES {
            let g = generate(ex).unwrap();
            let top = g.design.module(ex).unwrap();
            assert_eq!(
                top.interface_of("in0_data").map(|i| i.kind()),
                Some("handshake"),
                "{ex}"
            );
            assert_eq!(top.interface_of("clk").map(|i| i.kind()), Some("clock"));
            assert!(
                top.uncovered_ports().is_empty(),
                "{ex}: uncovered {:?}",
                top.uncovered_ports()
            );
        }
    }

    #[test]
    fn support_loc_is_small() {
        let loc = support_loc();
        // The paper needed 146 lines; ours is the same order of magnitude
        // and must stay small — that's the point of the rules mechanism.
        assert!(loc > 5 && loc < 200, "loc = {loc}");
    }

    #[test]
    fn vhdl_entity_roundtrip() {
        let g = generate("fir").unwrap();
        let op = g.design.module("elastic_op").unwrap();
        assert_eq!(op.port("a_data").unwrap().width, 32);
        assert_eq!(op.interface_of("a_data").unwrap().kind(), "handshake");
    }
}

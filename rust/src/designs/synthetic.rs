//! `designs::synthetic` — a seeded, shrinkable generator of arbitrary
//! *valid* [`Design`]s, for the differential fuzzing harness
//! (`testing::oracle`, `tests/fuzz_pipeline.rs`, `rsir fuzz`).
//!
//! The seven hand-written benchmark families exercise seven points of the
//! design space; this module samples the open space the paper actually
//! targets: random module hierarchies at mixed depth, fan-out/fan-in
//! block topologies, feedback edges, mixed interface protocols
//! (handshake / feedforward / non-pipeline), leaf-top and empty-module
//! edge shapes, and optional floorplan hints.
//!
//! ## Plans, not designs
//!
//! The generator does not mutate a [`Design`] directly. It produces a
//! [`DesignPlan`] — a small declarative description (leaf shapes, grouped
//! levels, channel pairings) — and [`materialize`] turns any plan into a
//! `Design` that is **DRC-valid by construction**:
//!
//! * every channel pairs an output bundle with an input bundle of equal
//!   kind and width, so nets have exactly two endpoints and widths match;
//! * every unmatched bundle of a child is exported through parent ports
//!   covered by a mirrored interface, so pipelinable interfaces are never
//!   partially connected and no net dangles after flattening;
//! * clock/reset are broadcast from each grouped module's own
//!   `ap_clk`/`ap_rst_n` ports (the fan-out exemption of the DRC).
//!
//! Shrinking operates on the plan (drop a group, a child, a channel, a
//! bundle…), and every shrunken plan still materializes to a valid
//! design, so counterexample minimization never wanders out of the
//! precondition of the properties under test.
//!
//! Materialization is a pure function of the plan and generation is a
//! pure function of the [`Rng`] stream, so a `(seed, case)` pair replays
//! to the identical design on any platform (pinned by the seed-digest
//! test in `tests/fuzz_pipeline.rs`).

use crate::ir::builder::LeafBuilder;
use crate::ir::core::*;
use crate::util::json::Json;
use crate::util::quickcheck::Gen;
use crate::util::rng::Rng;
use std::collections::{BTreeMap, BTreeSet};

/// Interface protocol of one generated port bundle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum BundleKind {
    /// data + `_vld` + `_rdy` triple with a handshake interface.
    Handshake,
    /// single data port with a feedforward interface.
    Feedforward,
    /// single data port with a non-pipeline (latency-sensitive) interface.
    NonPipeline,
}

/// Shape of one external bundle of a module: protocol, data-flow
/// direction, and data width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BundleSpec {
    pub kind: BundleKind,
    pub dir: Dir,
    pub width: u32,
}

/// Source surrogate a leaf is materialized as on the text path
/// ([`materialize_sources`]): plain Verilog, or one of the vendor-IP
/// container formats the importer supports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeafSource {
    /// Signature-only Verilog module with pragma comments.
    Verilog,
    /// Vivado IP surrogate: a `.xci` JSON manifest (vendor black box).
    Xci,
    /// Vitis kernel surrogate: a `.xo` JSON manifest wrapping Verilog.
    Xo,
}

/// Shape of one generated leaf module.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LeafPlan {
    pub bundles: Vec<BundleSpec>,
    /// Pre-attach resource/timing metadata (otherwise `platform-analyze`
    /// fills it in — both shapes appear in real imports).
    pub with_resource: bool,
    /// Add a second clock port `ap_clk2` + clock interface (multi-clock
    /// leaves are a real-import edge shape; parents broadcast `ap_clk`
    /// onto it, covered by the clock fan-out DRC exemption).
    pub multi_clock: bool,
    /// Preferred text-path surrogate; [`effective_source`] downgrades it
    /// when the protocol does not fit the container format.
    pub source: LeafSource,
}

/// The source surrogate a leaf actually materializes as. `.xci`
/// manifests only describe clock/reset/handshake bus interfaces, so
/// leaves with feedforward/non-pipeline bundles or a second clock
/// downgrade to plain Verilog (mirroring how real vendor IP is only
/// wrapped when the protocol fits the container).
pub fn effective_source(lp: &LeafPlan) -> LeafSource {
    match lp.source {
        LeafSource::Xci
            if lp.multi_clock || lp.bundles.iter().any(|b| b.kind != BundleKind::Handshake) =>
        {
            LeafSource::Verilog
        }
        s => s,
    }
}

/// What a grouped level instantiates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChildRef {
    /// `leaves[i]`.
    Leaf(usize),
    /// `groups[i]` — always a *lower* level, so hierarchies are acyclic.
    Group(usize),
    /// The shared empty grouped module (no ports, no instances).
    Empty,
}

/// One planned point-to-point connection inside a grouped module:
/// `children[src]`'s bundle `src_bundle` (an output) feeds
/// `children[dst]`'s bundle `dst_bundle` (an input). `dst <= src` yields
/// a feedback edge; `dst == src` a self-loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChannelPlan {
    pub src: usize,
    pub src_bundle: usize,
    pub dst: usize,
    pub dst_bundle: usize,
}

/// One grouped hierarchy level.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupPlan {
    pub children: Vec<ChildRef>,
    pub channels: Vec<ChannelPlan>,
    /// Attach a `floorplan` metadata hint to the first instance.
    pub hint: bool,
}

/// Which module is the design top.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopShape {
    /// The last grouped level (the usual shape).
    Group,
    /// `leaf0` — a design whose top is a leaf (degraded-path edge shape).
    LeafTop,
    /// The empty grouped module.
    EmptyTop,
}

/// A complete declarative description of one synthetic design.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DesignPlan {
    pub leaves: Vec<LeafPlan>,
    pub groups: Vec<GroupPlan>,
    pub with_empty: bool,
    pub top: TopShape,
}

/// Tuning knobs for [`DesignGen`]. Defaults keep designs small enough
/// that the tier-1 fuzz run (64 cases × full oracle suite) stays cheap.
#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    pub max_leaves: usize,
    pub max_bundles: usize,
    pub max_groups: usize,
    pub max_children: usize,
    pub widths: Vec<u32>,
    /// Probability that an output bundle gets matched to an input bundle
    /// (unmatched bundles are exported to parent ports).
    pub channel_p: f64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        SyntheticConfig {
            max_leaves: 4,
            max_bundles: 3,
            max_groups: 3,
            max_children: 4,
            widths: vec![1, 8, 32, 64],
            channel_p: 0.7,
        }
    }
}

/// The [`Gen`] implementation: generates and shrinks [`DesignPlan`]s.
#[derive(Debug, Clone, Default)]
pub struct DesignGen {
    pub cfg: SyntheticConfig,
}

impl Gen for DesignGen {
    type Item = DesignPlan;

    fn generate(&self, rng: &mut Rng) -> DesignPlan {
        let cfg = &self.cfg;
        let n_leaves = rng.range(1, cfg.max_leaves.max(1));
        let leaves: Vec<LeafPlan> = (0..n_leaves)
            .map(|_| LeafPlan {
                bundles: (0..rng.range(1, cfg.max_bundles.max(1)))
                    .map(|_| BundleSpec {
                        kind: {
                            let r = rng.f64();
                            if r < 0.6 {
                                BundleKind::Handshake
                            } else if r < 0.9 {
                                BundleKind::Feedforward
                            } else {
                                BundleKind::NonPipeline
                            }
                        },
                        dir: if rng.chance(0.5) { Dir::In } else { Dir::Out },
                        width: *rng.pick(&cfg.widths),
                    })
                    .collect(),
                with_resource: rng.chance(0.5),
                multi_clock: rng.chance(0.15),
                source: {
                    let r = rng.f64();
                    if r < 0.7 {
                        LeafSource::Verilog
                    } else if r < 0.85 {
                        LeafSource::Xci
                    } else {
                        LeafSource::Xo
                    }
                },
            })
            .collect();
        let mut with_empty = rng.chance(0.25);

        let n_groups = rng.range(1, cfg.max_groups.max(1));
        let mut groups: Vec<GroupPlan> = Vec::with_capacity(n_groups);
        let mut group_shapes: Vec<Vec<BundleSpec>> = Vec::with_capacity(n_groups);
        for gi in 0..n_groups {
            let n_children = rng.range(1, cfg.max_children.max(1));
            let children: Vec<ChildRef> = (0..n_children)
                .map(|_| {
                    if gi > 0 && rng.chance(0.35) {
                        ChildRef::Group(rng.below(gi))
                    } else if with_empty && rng.chance(0.15) {
                        ChildRef::Empty
                    } else {
                        ChildRef::Leaf(rng.below(n_leaves))
                    }
                })
                .collect();
            let child_shapes: Vec<Vec<BundleSpec>> = children
                .iter()
                .map(|c| match c {
                    ChildRef::Leaf(i) => leaves[*i].bundles.clone(),
                    ChildRef::Group(h) => group_shapes[*h].clone(),
                    ChildRef::Empty => Vec::new(),
                })
                .collect();

            // Match output slots to input slots of equal (kind, width).
            let mut out_slots: Vec<(usize, usize, BundleKind, u32)> = Vec::new();
            let mut in_buckets: BTreeMap<(BundleKind, u32), Vec<(usize, usize)>> = BTreeMap::new();
            for (k, shape) in child_shapes.iter().enumerate() {
                for (bi, b) in shape.iter().enumerate() {
                    match b.dir {
                        Dir::Out => out_slots.push((k, bi, b.kind, b.width)),
                        Dir::In => in_buckets
                            .entry((b.kind, b.width))
                            .or_default()
                            .push((k, bi)),
                        Dir::InOut => {}
                    }
                }
            }
            rng.shuffle(&mut out_slots);
            let mut channels = Vec::new();
            for (k, bi, kind, width) in out_slots {
                if !rng.chance(cfg.channel_p) {
                    continue;
                }
                let Some(bucket) = in_buckets.get_mut(&(kind, width)) else {
                    continue;
                };
                if bucket.is_empty() {
                    continue;
                }
                let (dk, dbi) = bucket.swap_remove(rng.below(bucket.len()));
                channels.push(ChannelPlan {
                    src: k,
                    src_bundle: bi,
                    dst: dk,
                    dst_bundle: dbi,
                });
            }

            let plan = GroupPlan {
                children,
                channels,
                hint: rng.chance(0.3),
            };
            group_shapes.push(group_shape(&child_shapes, &plan.channels));
            groups.push(plan);
        }

        let top = if rng.f64() < 0.8 {
            TopShape::Group
        } else if rng.chance(0.5) {
            TopShape::LeafTop
        } else {
            with_empty = true;
            TopShape::EmptyTop
        };
        DesignPlan {
            leaves,
            groups,
            with_empty,
            top,
        }
    }

    fn shrink(&self, p: &DesignPlan) -> Vec<DesignPlan> {
        let mut out = Vec::new();
        // Re-root to the previous grouped level.
        if p.top == TopShape::Group && p.groups.len() > 1 {
            let mut q = p.clone();
            q.groups.pop();
            out.push(q);
        }
        // Collapse to a leaf-top design (drops all grouping structure).
        if p.top == TopShape::Group && !p.groups.is_empty() && !p.leaves.is_empty() {
            let mut q = p.clone();
            q.top = TopShape::LeafTop;
            q.groups.clear();
            out.push(q);
        }
        // Drop the last child of each group (and its channels).
        for (gi, g) in p.groups.iter().enumerate() {
            if g.children.is_empty() {
                continue;
            }
            let mut q = p.clone();
            let g = &mut q.groups[gi];
            let k = g.children.len() - 1;
            g.children.pop();
            g.channels.retain(|c| c.src != k && c.dst != k);
            out.push(q);
        }
        // Drop the last channel of each group that has one.
        for (gi, g) in p.groups.iter().enumerate() {
            if g.channels.is_empty() {
                continue;
            }
            let mut q = p.clone();
            q.groups[gi].channels.pop();
            out.push(q);
        }
        // Drop the last leaf when nothing references it.
        if p.leaves.len() > 1 {
            let li = p.leaves.len() - 1;
            let referenced = p
                .groups
                .iter()
                .any(|g| g.children.contains(&ChildRef::Leaf(li)));
            if !referenced {
                let mut q = p.clone();
                q.leaves.pop();
                out.push(q);
            }
        }
        // Drop the last bundle of the last leaf when no channel names it.
        if let Some(lp) = p.leaves.last() {
            if lp.bundles.len() > 1 {
                let li = p.leaves.len() - 1;
                let bi = lp.bundles.len() - 1;
                let referenced = p.groups.iter().any(|g| {
                    g.channels.iter().any(|c| {
                        (g.children.get(c.src) == Some(&ChildRef::Leaf(li)) && c.src_bundle == bi)
                            || (g.children.get(c.dst) == Some(&ChildRef::Leaf(li))
                                && c.dst_bundle == bi)
                    })
                });
                if !referenced {
                    let mut q = p.clone();
                    q.leaves.last_mut().unwrap().bundles.pop();
                    out.push(q);
                }
            }
        }
        // Simplify every leaf back to the plain-Verilog surrogate.
        if p.leaves.iter().any(|l| l.source != LeafSource::Verilog) {
            let mut q = p.clone();
            for l in &mut q.leaves {
                l.source = LeafSource::Verilog;
            }
            out.push(q);
        }
        // Drop secondary clocks.
        if p.leaves.iter().any(|l| l.multi_clock) {
            let mut q = p.clone();
            for l in &mut q.leaves {
                l.multi_clock = false;
            }
            out.push(q);
        }
        // Clear cosmetic features.
        if p.groups.iter().any(|g| g.hint) {
            let mut q = p.clone();
            for g in &mut q.groups {
                g.hint = false;
            }
            out.push(q);
        }
        if p.with_empty
            && p.top != TopShape::EmptyTop
            && !p
                .groups
                .iter()
                .any(|g| g.children.contains(&ChildRef::Empty))
        {
            let mut q = p.clone();
            q.with_empty = false;
            out.push(q);
        }
        out
    }
}

/// External bundle signature of a grouped level: every child bundle not
/// consumed by a valid channel, in (child, bundle) declaration order.
/// Shared by the generator (planning) and [`materialize`] (export ports),
/// so the two always agree on a group's external shape.
pub fn group_shape(child_shapes: &[Vec<BundleSpec>], channels: &[ChannelPlan]) -> Vec<BundleSpec> {
    let (_accepted, used) = validate_channels(child_shapes, channels);
    let mut out = Vec::new();
    for (k, shape) in child_shapes.iter().enumerate() {
        for (bi, b) in shape.iter().enumerate() {
            if !used.contains(&(k, bi)) {
                out.push(*b);
            }
        }
    }
    out
}

/// First-come channel validation against the given child shapes:
/// returns the indices of the accepted channels plus the set of
/// (child, bundle) endpoints they consume. Invalid channels (dangling
/// references, mismatched shapes, already-taken endpoints — possible
/// after sloppy shrinking or in hand-written plans) are skipped, never
/// an error, and only channels in the accepted set are ever wired — an
/// endpoint claimed by an accepted channel can't also admit an earlier
/// mismatched one.
fn validate_channels(
    child_shapes: &[Vec<BundleSpec>],
    channels: &[ChannelPlan],
) -> (BTreeSet<usize>, BTreeSet<(usize, usize)>) {
    let mut accepted = BTreeSet::new();
    let mut used = BTreeSet::new();
    for (ci, c) in channels.iter().enumerate() {
        let (Some(ss), Some(ds)) = (child_shapes.get(c.src), child_shapes.get(c.dst)) else {
            continue;
        };
        let (Some(sb), Some(db)) = (ss.get(c.src_bundle), ds.get(c.dst_bundle)) else {
            continue;
        };
        if sb.dir != Dir::Out
            || db.dir != Dir::In
            || sb.kind != db.kind
            || sb.width != db.width
            || used.contains(&(c.src, c.src_bundle))
            || used.contains(&(c.dst, c.dst_bundle))
        {
            continue;
        }
        accepted.insert(ci);
        used.insert((c.src, c.src_bundle));
        used.insert((c.dst, c.dst_bundle));
    }
    (accepted, used)
}

/// Names + shape of one externally visible bundle of a built module.
#[derive(Debug, Clone)]
struct ExtBundle {
    spec: BundleSpec,
    data: String,
    valid: String,
    ready: String,
}

/// Turn any plan into a valid [`Design`]. Total: structurally impossible
/// references (dangling child/bundle indices, mismatched channel shapes)
/// are skipped rather than rejected, so every shrink candidate
/// materializes. Pure: the same plan always yields the identical design.
pub fn materialize(plan: &DesignPlan) -> Design {
    let mut d = Design::new("placeholder");
    let need_empty = plan.with_empty
        || plan.top == TopShape::EmptyTop
        || plan
            .groups
            .iter()
            .any(|g| g.children.contains(&ChildRef::Empty));
    if need_empty {
        d.add(Module::grouped("empty0"));
    }

    // Leaves.
    let mut leaf_sigs: Vec<Vec<ExtBundle>> = Vec::with_capacity(plan.leaves.len());
    for (i, lp) in plan.leaves.iter().enumerate() {
        let mut b = LeafBuilder::verilog_stub(format!("leaf{i}")).clk_rst();
        if lp.multi_clock {
            b = b.port("ap_clk2", Dir::In, 1).iface(Interface::Clock {
                port: "ap_clk2".into(),
            });
        }
        let mut sig = Vec::with_capacity(lp.bundles.len());
        for (j, bs) in lp.bundles.iter().enumerate() {
            let name = format!("b{j}");
            match bs.kind {
                BundleKind::Handshake => {
                    b = b.handshake(&name, bs.dir, bs.width);
                    sig.push(ExtBundle {
                        spec: *bs,
                        data: name.clone(),
                        valid: format!("{name}_vld"),
                        ready: format!("{name}_rdy"),
                    });
                }
                BundleKind::Feedforward => {
                    b = b.port(&name, bs.dir, bs.width).iface(Interface::Feedforward {
                        name: name.clone(),
                        ports: vec![name.clone()],
                    });
                    sig.push(ExtBundle {
                        spec: *bs,
                        data: name.clone(),
                        valid: String::new(),
                        ready: String::new(),
                    });
                }
                BundleKind::NonPipeline => {
                    b = b.port(&name, bs.dir, bs.width).iface(Interface::NonPipeline {
                        name: name.clone(),
                        ports: vec![name.clone()],
                    });
                    sig.push(ExtBundle {
                        spec: *bs,
                        data: name.clone(),
                        valid: String::new(),
                        ready: String::new(),
                    });
                }
            }
        }
        if lp.with_resource {
            b = b
                .resource(Resources::new(
                    100.0 * (i + 1) as f64,
                    80.0 * (i + 1) as f64,
                    1.0,
                    2.0,
                    0.0,
                ))
                .meta(
                    "timing",
                    Json::parse(r#"{"internal_ns": 2.0}"#).expect("static json"),
                );
        }
        d.add(b.build());
        leaf_sigs.push(sig);
    }

    // Grouped levels, bottom-up.
    let mut group_sigs: Vec<Vec<ExtBundle>> = Vec::with_capacity(plan.groups.len());
    for (gi, gp) in plan.groups.iter().enumerate() {
        let gname = format!("grp{gi}");
        let mut m = Module::grouped(&gname);
        m.ports = vec![
            Port::new("ap_clk", Dir::In, 1),
            Port::new("ap_rst_n", Dir::In, 1),
        ];
        m.interfaces = vec![
            Interface::Clock {
                port: "ap_clk".into(),
            },
            Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            },
        ];

        // Resolve children; None = unmaterializable reference (skipped,
        // but the slot is kept so channel indices stay aligned).
        struct Child {
            inst: Instance,
            sig: Vec<ExtBundle>,
        }
        let mut kids: Vec<Option<Child>> = Vec::with_capacity(gp.children.len());
        for (k, cr) in gp.children.iter().enumerate() {
            let resolved = match cr {
                ChildRef::Leaf(i) if *i < plan.leaves.len() => Some((
                    format!("leaf{i}"),
                    leaf_sigs[*i].clone(),
                    true,
                    plan.leaves[*i].multi_clock,
                )),
                ChildRef::Group(h) if *h < gi => {
                    Some((format!("grp{h}"), group_sigs[*h].clone(), true, false))
                }
                ChildRef::Empty if need_empty => {
                    Some(("empty0".to_string(), Vec::new(), false, false))
                }
                _ => None,
            };
            kids.push(resolved.map(|(module, sig, has_clk, has_clk2)| {
                let mut inst = Instance::new(format!("c{k}"), module);
                if has_clk {
                    inst.connect("ap_clk", ConnExpr::id("ap_clk"));
                    inst.connect("ap_rst_n", ConnExpr::id("ap_rst_n"));
                }
                if has_clk2 {
                    // Secondary clock broadcast off the same source clock
                    // (the clock fan-out DRC exemption covers this net).
                    inst.connect("ap_clk2", ConnExpr::id("ap_clk"));
                }
                Child { inst, sig }
            }));
        }

        let child_shapes: Vec<Vec<BundleSpec>> = kids
            .iter()
            .map(|c| {
                c.as_ref()
                    .map(|c| c.sig.iter().map(|b| b.spec).collect())
                    .unwrap_or_default()
            })
            .collect();
        let (accepted, used) = validate_channels(&child_shapes, &gp.channels);

        // Channels: wires joining a matched (out, in) bundle pair. Only
        // channels the validator accepted are wired — acceptance is by
        // channel index, so a mismatched channel can never ride on
        // endpoints claimed by a valid one.
        let mut wires: Vec<Wire> = Vec::new();
        for (ci, ch) in gp.channels.iter().enumerate() {
            if !accepted.contains(&ci) {
                continue;
            }
            let sb = kids[ch.src].as_ref().unwrap().sig[ch.src_bundle].clone();
            let db = kids[ch.dst].as_ref().unwrap().sig[ch.dst_bundle].clone();
            let w = format!("ch{ci}");
            wires.push(Wire {
                name: w.clone(),
                width: sb.spec.width,
            });
            kids[ch.src]
                .as_mut()
                .unwrap()
                .inst
                .connect(&sb.data, ConnExpr::id(&w));
            kids[ch.dst]
                .as_mut()
                .unwrap()
                .inst
                .connect(&db.data, ConnExpr::id(&w));
            if sb.spec.kind == BundleKind::Handshake {
                for (suffix, sp, dp) in [("vld", &sb.valid, &db.valid), ("rdy", &sb.ready, &db.ready)]
                {
                    let wn = format!("{w}_{suffix}");
                    wires.push(Wire {
                        name: wn.clone(),
                        width: 1,
                    });
                    kids[ch.src].as_mut().unwrap().inst.connect(sp, ConnExpr::id(&wn));
                    kids[ch.dst].as_mut().unwrap().inst.connect(dp, ConnExpr::id(&wn));
                }
            }
        }

        // Exports: every unmatched bundle becomes parent ports + a
        // mirrored interface, keeping the child's interface fully wired.
        let mut sig_out: Vec<ExtBundle> = Vec::new();
        #[allow(clippy::needless_range_loop)] // index needed for the later &mut access
        for k in 0..kids.len() {
            let Some(child) = kids[k].as_ref() else {
                continue;
            };
            let bundles: Vec<(usize, ExtBundle)> = child
                .sig
                .iter()
                .enumerate()
                .filter(|(bi, _)| !used.contains(&(k, *bi)))
                .map(|(bi, b)| (bi, b.clone()))
                .collect();
            for (_bi, b) in bundles {
                let base = format!("x{k}_{}", b.data);
                m.ports.push(Port::new(&base, b.spec.dir, b.spec.width));
                let kid = kids[k].as_mut().unwrap();
                kid.inst.connect(&b.data, ConnExpr::id(&base));
                match b.spec.kind {
                    BundleKind::Handshake => {
                        let (vld, rdy) = (format!("{base}_vld"), format!("{base}_rdy"));
                        m.ports.push(Port::new(&vld, b.spec.dir, 1));
                        m.ports.push(Port::new(&rdy, b.spec.dir.flipped(), 1));
                        kid.inst.connect(&b.valid, ConnExpr::id(&vld));
                        kid.inst.connect(&b.ready, ConnExpr::id(&rdy));
                        m.interfaces.push(Interface::Handshake {
                            name: base.clone(),
                            data: vec![base.clone()],
                            valid: vld.clone(),
                            ready: rdy.clone(),
                            clk: Some("ap_clk".into()),
                        });
                        sig_out.push(ExtBundle {
                            spec: b.spec,
                            data: base,
                            valid: vld,
                            ready: rdy,
                        });
                    }
                    BundleKind::Feedforward => {
                        m.interfaces.push(Interface::Feedforward {
                            name: base.clone(),
                            ports: vec![base.clone()],
                        });
                        sig_out.push(ExtBundle {
                            spec: b.spec,
                            data: base,
                            valid: String::new(),
                            ready: String::new(),
                        });
                    }
                    BundleKind::NonPipeline => {
                        m.interfaces.push(Interface::NonPipeline {
                            name: base.clone(),
                            ports: vec![base.clone()],
                        });
                        sig_out.push(ExtBundle {
                            spec: b.spec,
                            data: base,
                            valid: String::new(),
                            ready: String::new(),
                        });
                    }
                }
            }
        }

        *m.wires_mut() = wires;
        let mut first = true;
        for kid in kids.into_iter().flatten() {
            let mut inst = kid.inst;
            if gp.hint && first {
                inst.metadata
                    .insert("floorplan", Json::str("SLOT_X0Y0"));
                first = false;
            }
            m.instances_mut().push(inst);
        }
        d.add(m);
        group_sigs.push(sig_out);
    }

    // Top selection (with fallbacks so materialize is total).
    d.top = match plan.top {
        TopShape::Group if !plan.groups.is_empty() => format!("grp{}", plan.groups.len() - 1),
        TopShape::LeafTop if !plan.leaves.is_empty() => "leaf0".to_string(),
        TopShape::EmptyTop => "empty0".to_string(),
        _ if !plan.groups.is_empty() => format!("grp{}", plan.groups.len() - 1),
        _ if !plan.leaves.is_empty() => "leaf0".to_string(),
        _ => {
            if d.module("empty0").is_none() {
                d.add(Module::grouped("empty0"));
            }
            "empty0".to_string()
        }
    };
    d
}

/// The text-path twin of [`materialize`]: every module of the plan
/// rendered as source text — Verilog, `.xci` manifest, or `.xo`
/// manifest, per [`effective_source`]. Derived *from* the materialized
/// design, so signatures and interfaces agree with the IR by
/// construction; pragma comments (and `.xci` bus interfaces) carry the
/// interface declarations so `plugins::importer::import_mixed`
/// reconstructs them on the way back in.
#[derive(Debug, Clone, Default)]
pub struct MaterializedSources {
    /// Top module name (same as `materialize(plan).top`).
    pub top: String,
    /// Verilog sources: surrogate leaves in plan order, then every
    /// grouped module (incl. `empty0`) in name order.
    pub verilog: Vec<String>,
    /// `.xci` JSON manifests for vendor-IP surrogate leaves.
    pub xci: Vec<String>,
    /// `.xo` JSON manifests for kernel surrogate leaves.
    pub xo: Vec<String>,
}

/// Render a plan as importable source text (see [`MaterializedSources`]).
/// Like [`materialize`] this is total and pure: any plan yields a source
/// set, and the same plan always yields the identical text.
pub fn materialize_sources(plan: &DesignPlan) -> MaterializedSources {
    let d = materialize(plan);
    let mut out = MaterializedSources {
        top: d.top.clone(),
        ..Default::default()
    };
    for (i, lp) in plan.leaves.iter().enumerate() {
        let m = d
            .module(&format!("leaf{i}"))
            .expect("materialize builds every planned leaf");
        match effective_source(lp) {
            LeafSource::Verilog => out.verilog.push(leaf_verilog(m)),
            LeafSource::Xci => out.xci.push(crate::plugins::xci::module_manifest(m)),
            LeafSource::Xo => {
                let mut o = crate::util::json::JsonObj::new();
                o.insert("kernel", Json::str(&m.name));
                o.insert("sources", Json::Arr(vec![Json::str(&leaf_verilog(m))]));
                out.xo.push(Json::Obj(o).pretty());
            }
        }
    }
    for m in d.modules.values() {
        if matches!(m.body, Body::Grouped { .. }) {
            out.verilog.push(
                crate::plugins::exporter::grouped_to_verilog(&d, m)
                    .expect("materialized groups reference only materialized modules"),
            );
        }
    }
    out
}

/// Signature-only Verilog text for a leaf module: the IR port list plus
/// pragma comments reconstructing its interfaces on re-import.
fn leaf_verilog(m: &Module) -> String {
    let mut s = format!("module {} (\n", m.name);
    for (i, p) in m.ports.iter().enumerate() {
        let dir = match p.dir {
            Dir::In => "input",
            Dir::Out => "output",
            Dir::InOut => "inout",
        };
        let range = if p.width > 1 {
            format!("[{}:0] ", p.width - 1)
        } else {
            String::new()
        };
        let comma = if i + 1 < m.ports.len() { "," } else { "" };
        s.push_str(&format!("  {dir} wire {range}{}{comma}\n", p.name));
    }
    s.push_str(");\n");
    s.push_str(&crate::plugins::pragma::pragma_comments(m));
    s.push_str("endmodule\n");
    s
}

/// FNV-1a 64-bit over a byte string: tiny, dependency-free, and
/// platform-independent — the digest that pins seed-stability. The
/// implementation moved to [`crate::ir::digest`] (the incremental
/// re-flow engine keys on it); this re-export keeps the historical
/// call sites.
pub use crate::ir::digest::fnv1a64;

/// Canonical digest of a design: FNV-1a over its compact IR JSON.
pub fn digest(d: &Design) -> u64 {
    crate::ir::digest::design_digest(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::validate;
    use crate::util::quickcheck::forall;

    #[test]
    fn plans_materialize_to_drc_clean_designs() {
        forall(101, 40, &DesignGen::default(), |p| {
            validate::check(&materialize(p)).is_empty()
        });
    }

    #[test]
    fn materialize_is_pure() {
        let gen = DesignGen::default();
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let p = gen.generate(&mut rng);
            let a = materialize(&p);
            let b = materialize(&p);
            assert_eq!(a, b);
            assert_eq!(digest(&a), digest(&b));
        }
    }

    #[test]
    fn shrink_candidates_stay_valid() {
        let gen = DesignGen::default();
        let mut rng = Rng::new(17);
        for _ in 0..20 {
            let p = gen.generate(&mut rng);
            for cand in gen.shrink(&p) {
                let v = validate::check(&materialize(&cand));
                assert!(v.is_empty(), "shrunk plan {cand:#?} violates DRC: {v:?}");
            }
        }
    }

    #[test]
    fn generator_reaches_edge_shapes() {
        let gen = DesignGen::default();
        let mut rng = Rng::new(2024);
        let (mut leaf_top, mut empty_top, mut feedback, mut nested, mut empty_child) =
            (false, false, false, false, false);
        let (mut channels, mut hints, mut mixed) = (false, false, false);
        let (mut multi_clock, mut xci, mut xo, mut xci_downgrade) = (false, false, false, false);
        for _ in 0..300 {
            let p = gen.generate(&mut rng);
            multi_clock |= p.leaves.iter().any(|l| l.multi_clock);
            xci |= p
                .leaves
                .iter()
                .any(|l| effective_source(l) == LeafSource::Xci);
            xo |= p
                .leaves
                .iter()
                .any(|l| effective_source(l) == LeafSource::Xo);
            xci_downgrade |= p.leaves.iter().any(|l| {
                l.source == LeafSource::Xci && effective_source(l) == LeafSource::Verilog
            });
            leaf_top |= p.top == TopShape::LeafTop;
            empty_top |= p.top == TopShape::EmptyTop;
            feedback |= p
                .groups
                .iter()
                .any(|g| g.channels.iter().any(|c| c.dst <= c.src));
            nested |= p
                .groups
                .iter()
                .any(|g| g.children.iter().any(|c| matches!(c, ChildRef::Group(_))));
            empty_child |= p
                .groups
                .iter()
                .any(|g| g.children.contains(&ChildRef::Empty));
            channels |= p.groups.iter().any(|g| !g.channels.is_empty());
            hints |= p.groups.iter().any(|g| g.hint);
            mixed |= p.leaves.iter().any(|l| {
                l.bundles.iter().any(|b| b.kind == BundleKind::Handshake)
            }) && p.leaves.iter().any(|l| {
                l.bundles.iter().any(|b| b.kind != BundleKind::Handshake)
            });
        }
        assert!(leaf_top, "no leaf-top design in 300 samples");
        assert!(empty_top, "no empty-top design in 300 samples");
        assert!(feedback, "no feedback channel in 300 samples");
        assert!(nested, "no nested grouped level in 300 samples");
        assert!(empty_child, "no empty-module instance in 300 samples");
        assert!(channels, "no channels at all in 300 samples");
        assert!(hints, "no floorplan hints in 300 samples");
        assert!(mixed, "no mixed interface protocols in 300 samples");
        assert!(multi_clock, "no multi-clock leaf in 300 samples");
        assert!(xci, "no effective xci surrogate in 300 samples");
        assert!(xo, "no xo surrogate in 300 samples");
        assert!(xci_downgrade, "no xci→verilog downgrade in 300 samples");
    }

    #[test]
    fn sources_are_pure_and_cover_every_module() {
        let gen = DesignGen::default();
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let p = gen.generate(&mut rng);
            let a = materialize_sources(&p);
            let b = materialize_sources(&p);
            assert_eq!(a.top, b.top);
            assert_eq!(a.verilog, b.verilog);
            assert_eq!(a.xci, b.xci);
            assert_eq!(a.xo, b.xo);
            let d = materialize(&p);
            assert_eq!(
                a.verilog.len() + a.xci.len() + a.xo.len(),
                d.modules.len(),
                "one source per module"
            );
        }
    }

    #[test]
    fn group_shape_matches_materialized_exports() {
        // The planning-side shape and the materialized export ports must
        // describe the same bundles, or cross-level channels would
        // silently vanish.
        let gen = DesignGen::default();
        let mut rng = Rng::new(55);
        for _ in 0..20 {
            let p = gen.generate(&mut rng);
            let d = materialize(&p);
            for (gi, gp) in p.groups.iter().enumerate() {
                // Only validate leaf-only groups precisely (group children
                // would need the transitive shape, covered by DRC anyway).
                if gp
                    .children
                    .iter()
                    .any(|c| !matches!(c, ChildRef::Leaf(_)))
                {
                    continue;
                }
                let child_shapes: Vec<Vec<BundleSpec>> = gp
                    .children
                    .iter()
                    .map(|c| match c {
                        ChildRef::Leaf(i) => p.leaves[*i].bundles.clone(),
                        _ => unreachable!("filtered above"),
                    })
                    .collect();
                let shape = group_shape(&child_shapes, &gp.channels);
                let m = d.module(&format!("grp{gi}")).unwrap();
                let exported = m
                    .interfaces
                    .iter()
                    .filter(|i| !matches!(i, Interface::Clock { .. } | Interface::Reset { .. }))
                    .count();
                assert_eq!(shape.len(), exported, "group grp{gi} shape drift");
            }
        }
    }

    #[test]
    fn mismatched_channel_before_valid_ones_is_skipped_not_wired() {
        // Regression for the totality contract: a kind-mismatched channel
        // listed BEFORE the valid channels that claim its endpoints must
        // be skipped (acceptance is per channel index), and every
        // endpoint it touched must still end up wired or exported.
        let hs = |dir| BundleSpec {
            kind: BundleKind::Handshake,
            dir,
            width: 32,
        };
        let ff = |dir| BundleSpec {
            kind: BundleKind::Feedforward,
            dir,
            width: 32,
        };
        let plan = DesignPlan {
            leaves: vec![
                LeafPlan {
                    // A: hs out, B-feeder: hs out
                    bundles: vec![hs(Dir::Out), hs(Dir::Out)],
                    with_resource: false,
                    multi_clock: false,
                    source: LeafSource::Verilog,
                },
                LeafPlan {
                    // consumers: hs in, ff in
                    bundles: vec![hs(Dir::In), ff(Dir::In)],
                    with_resource: false,
                    multi_clock: false,
                    source: LeafSource::Verilog,
                },
            ],
            groups: vec![GroupPlan {
                children: vec![ChildRef::Leaf(0), ChildRef::Leaf(1)],
                channels: vec![
                    // Mismatched (hs out -> ff in), listed first.
                    ChannelPlan {
                        src: 0,
                        src_bundle: 0,
                        dst: 1,
                        dst_bundle: 1,
                    },
                    // Valid channel claiming the mismatched one's src.
                    ChannelPlan {
                        src: 0,
                        src_bundle: 0,
                        dst: 1,
                        dst_bundle: 0,
                    },
                ],
                hint: false,
            }],
            with_empty: false,
            top: TopShape::Group,
        };
        let d = materialize(&plan);
        let v = validate::check(&d);
        assert!(v.is_empty(), "materialize broke totality: {v:?}");
        // The valid channel is wired under its own index (ch1), and the
        // remaining bundles (leaf0.b1, leaf1.b1) are exported.
        let top = d.module("grp0").unwrap();
        assert!(top.wires().iter().any(|w| w.name == "ch1"));
        assert!(top.wires().iter().all(|w| !w.name.starts_with("ch0")));
        assert!(top.port("x0_b1").is_some(), "unused src bundle must export");
        assert!(top.port("x1_b1").is_some(), "mismatched dst must export");
    }

    #[test]
    fn digest_is_stable_within_process() {
        let gen = DesignGen::default();
        let one = |seed: u64| {
            let mut rng = Rng::new(seed);
            digest(&materialize(&gen.generate(&mut rng)))
        };
        for seed in 0..5 {
            assert_eq!(one(seed), one(seed));
        }
    }

    #[test]
    fn fnv_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85dd_35c9_fd85_9e3f);
    }
}

//! Minimap2 long-read genome-sequencing accelerator (§4.4 item 3 [19]):
//! a deep dataflow of seeding → chaining → alignment with *multiple
//! hierarchical levels of pipelines* — the original authors already
//! inserted stream FIFOs (relay stations) between the top-level stages,
//! which is why the vendor baseline does respectably and RIR's gain is
//! modest (+8 % in Table 2).

use crate::designs::common::*;
use crate::interconnect;
use crate::ir::core::*;
use anyhow::Result;

pub fn generate() -> Result<Generated> {
    let name = "minimap2".to_string();
    let hs_io: [(&str, Dir, u32); 2] = [("i", Dir::In, 256), ("o", Dir::Out, 256)];
    let rep_io: [(&str, &str, u32); 2] = [("i", "in", 256), ("o", "out", 256)];

    // Stage kernels (HLS): seeding, 3 chaining sub-stages, 2 alignment.
    let stages: [(&str, f64, f64, f64, f64); 6] = [
        // name, lut, ff, dsp, internal_ns
        ("SeedExtract", 96_000.0, 64_000.0, 240.0, 3.5),
        ("ChainSort", 98_000.0, 70_000.0, 310.0, 3.5),
        ("ChainScore", 118_000.0, 76_000.0, 380.0, 3.5),
        ("ChainBacktrack", 80_000.0, 58_000.0, 260.0, 3.45),
        ("AlignBand", 118_000.0, 84_000.0, 420.0, 3.5),
        ("AlignTraceback", 88_000.0, 66_000.0, 300.0, 3.45),
    ];
    let mut sources = Vec::new();
    let mut entries = Vec::new();
    for (n, lut, ff, dsp, t) in &stages {
        sources.push(hls_kernel_verilog(n, &hs_io));
        entries.push((
            n.to_string(),
            report_entry(
                &Resources::new(*lut, *ff, 44.0, *dsp, 0.0),
                *t,
                &rep_io,
            ),
        ));
    }

    // Top: stages chained through explicit stream FIFOs (the authors'
    // hand-inserted relay stations — instantiated as rs_w256_s1 modules).
    let rs = interconnect::relay_station(256, 1);
    let rs_name = rs.name.clone();
    let mut top = format!(
        "module {name} (\n  input wire ap_clk,\n  input wire ap_rst_n,\n  input wire [255:0] reads, input wire reads_vld, output wire reads_rdy,\n  output wire [255:0] sam, output wire sam_vld, input wire sam_rdy\n);\n"
    );
    for k in 0..stages.len() {
        top.push_str(&hs_wires(&format!("u{k}"), 256)); // stage output
        if k + 1 < stages.len() {
            top.push_str(&hs_wires(&format!("f{k}"), 256)); // fifo output
        }
    }
    for (k, (n, ..)) in stages.iter().enumerate() {
        let iw = if k == 0 {
            "reads".to_string()
        } else {
            format!("f{}", k - 1)
        };
        let ow = if k + 1 == stages.len() {
            // last stage drives sam via u{k}; alias below
            format!("u{k}")
        } else {
            format!("u{k}")
        };
        top.push_str(&format!(
            "  {n} st{k} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\n",
            hs_conn("i", &iw),
            hs_conn("o", &ow),
        ));
        if k + 1 < stages.len() {
            top.push_str(&format!(
                "  {rs_name} fifo{k} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\n",
                hs_conn("i", &format!("u{k}")),
                hs_conn("o", &format!("f{k}")),
            ));
        }
    }
    let last = stages.len() - 1;
    top.push_str(&format!(
        "  assign sam = u{last};\n  assign sam_vld = u{last}_vld;\n  assign u{last}_rdy = sam_rdy;\n"
    ));
    top.push_str("endmodule\n");
    sources.push(top);

    let src_refs: Vec<&str> = sources.iter().map(|s| s.as_str()).collect();
    let mut design = crate::plugins::importer::import_design(&name, &src_refs)?;
    // The FIFO module comes from the interconnect library (with its
    // resource/timing/pipeline metadata), replacing the bare import.
    design.add(rs);
    let report_text = report(&entries);
    crate::plugins::hls_report::apply_report(&mut design, &report_text)?;
    let t = design.module_mut(&name).unwrap();
    t.interfaces.push(Interface::Clock {
        port: "ap_clk".into(),
    });
    t.interfaces.push(Interface::Reset {
        port: "ap_rst_n".into(),
        active_high: false,
    });
    for (nm, v, r) in [
        ("reads", "reads_vld", "reads_rdy"),
        ("sam", "sam_vld", "sam_rdy"),
    ] {
        t.interfaces.push(Interface::Handshake {
            name: nm.into(),
            data: vec![nm.into()],
            valid: v.into(),
            ready: r.into(),
            clk: Some("ap_clk".into()),
        });
    }
    Ok(Generated {
        name,
        design,
        sources,
        hls_report: Some(report_text),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::manager::{Pass, PassContext};

    #[test]
    fn generates_with_prepipelined_fifos() {
        let g = generate().unwrap();
        let rs = g.design.module("rs_w256_s1").unwrap();
        assert!(rs
            .metadata
            .get("pipeline_element")
            .and_then(|v| v.as_bool())
            .unwrap());
    }

    #[test]
    fn rebuild_and_validate() {
        let g = generate().unwrap();
        let mut d = g.design;
        crate::passes::rebuild::RebuildAll
            .run(&mut d, &mut PassContext::new())
            .unwrap();
        crate::ir::validate::assert_clean(&d);
        // 6 stages + 5 fifos + aux
        assert_eq!(d.module("minimap2").unwrap().instances().len(), 12);
    }
}

//! Catapult HLS frontend (§4.1): Catapult synthesizes handshakes with
//! customizable library components (`ccs_out_wait` / `ccs_in_wait`).
//! "With simple pragmas in these modules' Verilog code, the interface can
//! be automatically propagated during the interface inference pass to
//! neighboring modules" — exactly what this adapter does: the two library
//! modules carry pragmas; everything else gets its interfaces inferred.
//!
//! The evaluation benchmark is a sparse linear-algebra accelerator [13].

use crate::designs::common::Generated;
use crate::ir::core::*;
use anyhow::Result;

// BEGIN-FRONTEND (counted by support_loc / Table 1)
/// Catapult's handshake library components, annotated with RIR pragmas.
pub fn library_sources() -> Vec<String> {
    vec![
        "// Catapult output-register with wait protocol.\nmodule ccs_out_wait (\n  input  wire clk,\n  input  wire [63:0] idat, input wire ivld, output wire irdy,\n  output wire [63:0] dat, output wire vld, input wire rdy\n);\n// pragma clock port=clk\n// pragma handshake pattern=i{role} role.valid=vld role.ready=rdy role.data=dat\n// pragma handshake pattern={bundle}{role} role.valid=vld role.ready=rdy role.data=dat\n  assign dat = idat;\n  assign vld = ivld;\n  assign irdy = rdy;\nendmodule\n".to_string(),
        "// Catapult input-register with wait protocol.\nmodule ccs_in_wait (\n  input  wire clk,\n  input  wire [63:0] dat, input wire vld, output wire rdy,\n  output wire [63:0] odat, output wire ovld, input wire ordy\n);\n// pragma clock port=clk\n// pragma handshake pattern=o{role} role.valid=vld role.ready=rdy role.data=dat\n// pragma handshake pattern={bundle}{role} role.valid=vld role.ready=rdy role.data=dat\n  assign odat = dat;\n  assign ovld = vld;\n  assign rdy = ordy;\nendmodule\n".to_string(),
    ]
}

/// Import Catapult RTL: library modules (with pragmas) + generated
/// design sources; interface inference completes the kernels' ports.
pub fn import(top: &str, design_sources: &[&str]) -> Result<Design> {
    let lib = library_sources();
    let mut all: Vec<&str> = lib.iter().map(|s| s.as_str()).collect();
    all.extend_from_slice(design_sources);
    let mut d = crate::plugins::importer::import_design(top, &all)?;
    // Clock/reset conventions of Catapult RTL.
    crate::plugins::iface_rules::RuleSet::new()
        .add_clock(".*", "clk")
        .add_reset(".*", "rst|arst_n", "high")
        .apply(&mut d)?;
    Ok(d)
}
// END-FRONTEND

pub fn support_loc() -> usize {
    crate::designs::dynamatic::count_frontend_loc(include_str!("catapult.rs"))
}

/// The sparse linear-algebra accelerator benchmark: SpMV compute cores
/// wrapped in ccs_*_wait channel registers, plus a hierarchy level.
pub fn generate() -> Result<Generated> {
    let mut sources = Vec::new();
    sources.push(
        "// Catapult-generated SpMV core.\nmodule spmv_core (\n  input  wire clk,\n  input  wire rst,\n  input  wire [63:0] row_dat, input wire row_vld, output wire row_rdy,\n  output wire [63:0] acc_dat, output wire acc_vld, input wire acc_rdy\n);\n  reg [63:0] acc;\n  always @(posedge clk) if (row_vld) acc <= acc + row_dat;\nendmodule\n"
            .to_string(),
    );
    sources.push(
        "module spmv_top (\n  input  wire clk,\n  input  wire rst,\n  input  wire [63:0] rows, input wire rows_vld, output wire rows_rdy,\n  output wire [63:0] y, output wire y_vld, input wire y_rdy\n);\n  wire [63:0] r0; wire r0_v; wire r0_r;\n  wire [63:0] a0; wire a0_v; wire a0_r;\n  ccs_in_wait in_reg (.clk(clk), .dat(rows), .vld(rows_vld), .rdy(rows_rdy),\n                      .odat(r0), .ovld(r0_v), .ordy(r0_r));\n  spmv_core core (.clk(clk), .rst(rst), .row_dat(r0), .row_vld(r0_v), .row_rdy(r0_r),\n                  .acc_dat(a0), .acc_vld(a0_v), .acc_rdy(a0_r));\n  ccs_out_wait out_reg (.clk(clk), .idat(a0), .ivld(a0_v), .irdy(a0_r),\n                        .dat(y), .vld(y_vld), .rdy(y_rdy));\nendmodule\n"
            .to_string(),
    );
    let src_refs: Vec<&str> = sources.iter().map(|s| s.as_str()).collect();
    let mut design = import("spmv_top", &src_refs)?;
    // Inference propagates the library pragma interfaces to spmv_core:
    // rebuild exposes the structure, partition + passthrough remove the
    // pure-alias aux between the library regs and the core, and the final
    // inference mirrors the handshakes onto the core's ports.
    use crate::passes::manager::{Pass, PassContext};
    let mut ctx = PassContext::new();
    crate::passes::rebuild::RebuildAll.run(&mut design, &mut ctx)?;
    crate::passes::iface_infer::InterfaceInference.run(&mut design, &mut ctx)?;
    crate::passes::partition::PartitionAllAux.run(&mut design, &mut ctx)?;
    crate::passes::passthrough::Passthrough.run(&mut design, &mut ctx)?;
    crate::passes::iface_infer::InterfaceInference.run(&mut design, &mut ctx)?;
    Ok(Generated {
        name: "catapult_spmv".to_string(),
        design,
        sources,
        hls_report: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn library_pragmas_give_handshakes() {
        let g = generate().unwrap();
        let lib = g.design.module("ccs_out_wait").unwrap();
        assert_eq!(lib.interface_of("dat").unwrap().kind(), "handshake");
        assert_eq!(lib.interface_of("idat").unwrap().kind(), "handshake");
    }

    #[test]
    fn inference_propagates_to_core() {
        let g = generate().unwrap();
        let core = g.design.module("spmv_core").unwrap();
        assert_eq!(
            core.interface_of("row_dat").map(|i| i.kind()),
            Some("handshake"),
            "{:?}",
            core.interfaces
        );
        assert_eq!(
            core.interface_of("acc_dat").map(|i| i.kind()),
            Some("handshake")
        );
    }

    #[test]
    fn support_loc_counted() {
        let loc = support_loc();
        assert!(loc > 5 && loc < 220, "loc = {loc}");
    }
}

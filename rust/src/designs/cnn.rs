//! CNN systolic-array benchmark (AutoSA-style, §4.4 item 1).
//!
//! A `rows × cols` grid of processing elements in Vitis-HLS style with a
//! flat hierarchy (the configuration AutoBridge also supports — Table 2
//! compares RIR against AutoBridge on exactly these): data flows east
//! through each row, partial sums flow south; edge loaders feed rows and
//! columns; a drain collects results. Every link is a handshake (AutoSA
//! generates FIFO-connected PE arrays).
//!
//! Resource weights are calibrated to the paper's utilization columns:
//! ~40 DSP / 3.5 kLUT per PE puts 13×4 at ≈13 % LUT / 17 % DSP of a U250
//! and sends 13×10+ past the DSP balance point where the unfloorplanned
//! vendor flow becomes unroutable ("-" rows in Table 2).

use crate::designs::common::*;
use crate::ir::core::*;
use anyhow::Result;

pub struct CnnConfig {
    pub rows: usize,
    pub cols: usize,
}

/// PE internal path: HLS PEs close near 333 MHz when uncongested.
const PE_INTERNAL_NS: f64 = 3.0;

pub fn generate(cfg: &CnnConfig) -> Result<Generated> {
    let (rows, cols) = (cfg.rows, cfg.cols);
    let name = format!("cnn_{rows}x{cols}");

    // ---- Sources -----------------------------------------------------
    let pe_src = hls_kernel_verilog(
        "PE",
        &[
            ("a_in", Dir::In, 64),
            ("a_out", Dir::Out, 64),
            ("b_in", Dir::In, 64),
            ("b_out", Dir::Out, 64),
        ],
    );
    let lda_src = hls_kernel_verilog("LoaderA", &[("o", Dir::Out, 64)]);
    let ldb_src = hls_kernel_verilog("LoaderB", &[("o", Dir::Out, 64)]);
    let drain_src = hls_kernel_verilog("Drain", &[("i", Dir::In, 64)]);

    // Flat structural top (what AutoSA emits from the HLS dataflow):
    // every inter-PE link goes through an explicit stream FIFO — AutoSA
    // connects PEs with hls::stream channels, which synthesize to FIFO
    // primitives with registered outputs.
    let fifo = crate::interconnect::relay_station(64, 1);
    let fifo_name = fifo.name.clone();
    let mut top = String::new();
    top.push_str(&format!(
        "// AutoSA-style flat systolic top (FIFO-connected PE array).\nmodule {name} (\n  input wire ap_clk,\n  input wire ap_rst_n\n);\n"
    ));
    // a_{r}_{c}: PE/loader output; a_{r}_{c}f: FIFO output feeding the
    // next consumer.
    for r in 0..rows {
        for c in 0..=cols {
            top.push_str(&hs_wires(&format!("a_{r}_{c}"), 64));
            top.push_str(&hs_wires(&format!("a_{r}_{c}f"), 64));
        }
    }
    for r in 0..=rows {
        for c in 0..cols {
            top.push_str(&hs_wires(&format!("b_{r}_{c}"), 64));
            top.push_str(&hs_wires(&format!("b_{r}_{c}f"), 64));
        }
    }
    let emit_fifo = |top: &mut String, label: String, from: String, to: String| {
        top.push_str(&format!(
            "  {fifo_name} {label} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {});\n",
            hs_conn("i", &from),
            hs_conn("o", &to),
        ));
    };
    for r in 0..rows {
        top.push_str(&format!(
            "  LoaderA la_{r} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {});\n",
            hs_conn("o", &format!("a_{r}_0"))
        ));
        for c in 0..=cols {
            emit_fifo(
                &mut top,
                format!("fa_{r}_{c}"),
                format!("a_{r}_{c}"),
                format!("a_{r}_{c}f"),
            );
        }
    }
    for c in 0..cols {
        top.push_str(&format!(
            "  LoaderB lb_{c} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {});\n",
            hs_conn("o", &format!("b_0_{c}"))
        ));
        for r in 0..=rows {
            emit_fifo(
                &mut top,
                format!("fb_{r}_{c}"),
                format!("b_{r}_{c}"),
                format!("b_{r}_{c}f"),
            );
        }
    }
    for r in 0..rows {
        for c in 0..cols {
            top.push_str(&format!(
                "  PE pe_{r}_{c} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {}, {}, {}, {});\n",
                hs_conn("a_in", &format!("a_{r}_{c}f")),
                hs_conn("a_out", &format!("a_{r}_{}", c + 1)),
                hs_conn("b_in", &format!("b_{r}_{c}f")),
                hs_conn("b_out", &format!("b_{}_{c}", r + 1)),
            ));
        }
    }
    // Row tails and column drains terminate into Drain units.
    for r in 0..rows {
        top.push_str(&format!(
            "  Drain da_{r} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {});\n",
            hs_conn("i", &format!("a_{r}_{cols}f"))
        ));
    }
    for c in 0..cols {
        top.push_str(&format!(
            "  Drain db_{c} (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n), {});\n",
            hs_conn("i", &format!("b_{rows}_{c}f"))
        ));
    }
    top.push_str("endmodule\n");

    // ---- HLS report ----------------------------------------------------
    // Per-PE DSP varies with AutoSA's tiling factors per configuration
    // (the paper's utilization column is not linear in array size:
    // 13x4 = 17 %, 13x8 = 24 %, 13x10 = 43 % of a U250).
    let dsp_per_pe = match (rows, cols) {
        (13, 8) => 28.0,
        (13, 6) => 41.0,
        _ => 40.0,
    };
    let pe_res = Resources::new(3_500.0, 6_200.0, 4.0, dsp_per_pe, 0.0);
    let ld_res = Resources::new(2_400.0, 3_000.0, 6.0, 0.0, 0.0);
    let dr_res = Resources::new(900.0, 1_400.0, 2.0, 0.0, 0.0);
    let hs4: [(&str, &str, u32); 4] = [
        ("a_in", "in", 64),
        ("a_out", "out", 64),
        ("b_in", "in", 64),
        ("b_out", "out", 64),
    ];
    let report_text = report(&[
        ("PE".to_string(), report_entry(&pe_res, PE_INTERNAL_NS, &hs4)),
        (
            "LoaderA".to_string(),
            report_entry(&ld_res, 2.6, &[("o", "out", 64)]),
        ),
        (
            "LoaderB".to_string(),
            report_entry(&ld_res, 2.6, &[("o", "out", 64)]),
        ),
        (
            "Drain".to_string(),
            report_entry(&dr_res, 2.2, &[("i", "in", 64)]),
        ),
    ]);

    // ---- Import through the standard plugins ---------------------------
    let fifo_src = match &fifo.body {
        Body::Leaf { source, .. } => source.clone(),
        _ => unreachable!(),
    };
    let sources = vec![pe_src, lda_src, ldb_src, drain_src, fifo_src, top];
    let src_refs: Vec<&str> = sources.iter().map(|s| s.as_str()).collect();
    let mut design = crate::plugins::importer::import_design(&name, &src_refs)?;
    // Replace the bare-imported FIFO with the interconnect library module
    // (resource/timing/pipeline_element metadata).
    design.add(fifo);
    crate::plugins::hls_report::apply_report(&mut design, &report_text)?;
    // Top-level clock/reset interfaces.
    let t = design.module_mut(&name).unwrap();
    t.interfaces.push(Interface::Clock {
        port: "ap_clk".into(),
    });
    t.interfaces.push(Interface::Reset {
        port: "ap_rst_n".into(),
        active_high: false,
    });
    Ok(Generated {
        name,
        design,
        sources,
        hls_report: Some(report_text),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::manager::{Pass, PassContext};
    use crate::passes::rebuild::RebuildAll;

    #[test]
    fn generates_and_imports() {
        let g = generate(&CnnConfig { rows: 3, cols: 2 }).unwrap();
        assert_eq!(g.name, "cnn_3x2");
        // top + 4 HLS leaf kinds + the stream FIFO
        assert_eq!(g.design.modules.len(), 6);
        let pe = g.design.module("PE").unwrap();
        assert_eq!(pe.interface_of("a_in").unwrap().kind(), "handshake");
    }

    #[test]
    fn rebuild_extracts_full_array() {
        let g = generate(&CnnConfig { rows: 3, cols: 2 }).unwrap();
        let mut d = g.design;
        RebuildAll.run(&mut d, &mut PassContext::new()).unwrap();
        let top = d.module("cnn_3x2").unwrap();
        assert!(top.is_grouped());
        // 6 PEs + 3 LoaderA + 2 LoaderB + 5 Drains + 17 FIFOs + aux
        assert_eq!(top.instances().len(), 34);
        crate::ir::validate::assert_clean(&d);
    }

    #[test]
    fn resource_totals_scale_with_array() {
        let small = generate(&CnnConfig { rows: 13, cols: 4 }).unwrap();
        let big = generate(&CnnConfig { rows: 13, cols: 10 }).unwrap();
        let rs = |g: &Generated| {
            let mut d = g.design.clone();
            RebuildAll.run(&mut d, &mut PassContext::new()).unwrap();
            crate::plugins::platform::total_resources(&d)
        };
        let (a, b) = (rs(&small), rs(&big));
        assert!(b.dsp > a.dsp * 1.8);
        // 13x4 DSP ≈ 52 × 40 = 2080 (≈17 % of U250's 12288, Table 2).
        assert!((a.dsp - 2080.0).abs() < 1.0);
    }
}

//! Intel HLS frontend (§4.1): the Intel HLS compiler (i++) emits
//! Avalon-ST style streaming interfaces with consistent port naming,
//! "making them also compatible with the Python-based interface rules
//! method". Benchmarks: the 12 CHStone programs [11].

use crate::designs::common::Generated;
use crate::ir::core::*;
use crate::plugins::iface_rules::RuleSet;
use crate::util::rng::Rng;
use anyhow::Result;

/// The 12 CHStone benchmarks.
pub const CHSTONE: [&str; 12] = [
    "adpcm", "aes", "blowfish", "dfadd", "dfdiv", "dfmul", "dfsin", "gsm",
    "jpeg", "mips", "motion", "sha",
];

// BEGIN-FRONTEND (counted by support_loc / Table 1)
/// Interface rules for Intel-HLS (i++) generated Verilog.
pub fn rules() -> RuleSet {
    RuleSet::new()
        .add_clock(".*", "clock|clock2x")
        .add_reset(".*", "resetn", "low")
        // Avalon-ST streams: <bundle>_<role>.
        .add_handshake(".*", "{bundle}_{role}", "valid", "ready", "data|channel|startofpacket|endofpacket")
        // Component start/busy/done control group.
        .add_handshake(".*", "avst_{bundle}_{role}", "valid", "ready", ".*")
        .add_feedforward(".*", "start|busy|done|stall_out|stall_in")
}

/// Import i++-generated Verilog and apply the rules.
pub fn import(top: &str, sources: &[&str]) -> Result<Design> {
    let mut d = crate::plugins::importer::import_design(top, sources)?;
    rules().apply(&mut d)?;
    Ok(d)
}
// END-FRONTEND

pub fn support_loc() -> usize {
    crate::designs::dynamatic::count_frontend_loc(include_str!("intel_hls.rs"))
}

/// Generate one CHStone benchmark in i++ output style: a component with
/// Avalon-ST input/output streams and a few internal basic-block modules.
pub fn generate(bench: &str) -> Result<Generated> {
    let seed = bench.bytes().fold(7u64, |a, b| a.wrapping_mul(257).wrapping_add(b as u64));
    let mut rng = Rng::new(seed);
    let n_bb = rng.range(2, 6);
    let mut sources = Vec::new();
    sources.push(
        "module bb_compute (\n  input wire clock,\n  input wire resetn,\n  input wire [31:0] x_data, input wire x_valid, output wire x_ready,\n  output wire [31:0] y_data, output wire y_valid, input wire y_ready\n);\n  reg [31:0] t;\n  always @(posedge clock) if (x_valid) t <= t ^ x_data;\nendmodule\n"
            .to_string(),
    );
    let mut top = format!(
        "module {bench} (\n  input wire clock,\n  input wire resetn,\n  input wire [31:0] avst_din_data, input wire avst_din_valid, output wire avst_din_ready,\n  output wire [31:0] avst_dout_data, output wire avst_dout_valid, input wire avst_dout_ready,\n  input wire start, output wire done\n);\n"
    );
    for k in 0..n_bb {
        top.push_str(&format!(
            "  wire [31:0] c{k}_data; wire c{k}_valid; wire c{k}_ready;\n"
        ));
    }
    for k in 0..n_bb {
        let i = if k == 0 {
            "avst_din".to_string()
        } else {
            format!("c{}", k - 1)
        };
        let o = format!("c{k}");
        top.push_str(&format!(
            "  bb_compute bb{k} (.clock(clock), .resetn(resetn), .x_data({i}_data), .x_valid({i}_valid), .x_ready({i}_ready), .y_data({o}_data), .y_valid({o}_valid), .y_ready({o}_ready));\n"
        ));
    }
    let last = n_bb - 1;
    top.push_str(&format!(
        "  assign avst_dout_data = c{last}_data;\n  assign avst_dout_valid = c{last}_valid;\n  assign c{last}_ready = avst_dout_ready;\n  assign done = ~start;\nendmodule\n"
    ));
    sources.push(top);

    let src_refs: Vec<&str> = sources.iter().map(|s| s.as_str()).collect();
    let design = import(bench, &src_refs)?;
    Ok(Generated {
        name: format!("intel_{bench}"),
        design,
        sources,
        hls_report: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_chstone_benchmarks_import() {
        for b in CHSTONE {
            let g = generate(b).unwrap();
            let top = g.design.module(b).unwrap();
            assert_eq!(
                top.interface_of("avst_din_data").map(|i| i.kind()),
                Some("handshake"),
                "{b}"
            );
            assert_eq!(top.interface_of("clock").map(|i| i.kind()), Some("clock"));
            assert!(top.uncovered_ports().is_empty(), "{b}: {:?}", top.uncovered_ports());
        }
    }

    #[test]
    fn internal_streams_detected() {
        let g = generate("aes").unwrap();
        let bb = g.design.module("bb_compute").unwrap();
        assert_eq!(bb.interface_of("x_data").unwrap().kind(), "handshake");
    }

    #[test]
    fn support_loc_counted() {
        let loc = support_loc();
        assert!(loc > 5 && loc < 220, "loc = {loc}");
    }
}

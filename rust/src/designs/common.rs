//! Shared helpers for the benchmark design generators.
//!
//! Generators emit *real artifacts* — Verilog sources, HLS-report JSON,
//! XCI/XO manifests — and build the IR by running them through the same
//! plugins a user would (§3.2), so every Table-2 row exercises the full
//! import path, not a hand-assembled IR.

use crate::ir::core::*;
use crate::util::json::{Json, JsonObj};

/// A generated benchmark: sources plus the assembled design.
pub struct Generated {
    pub name: String,
    pub design: Design,
    /// Verilog/VHDL sources (for RQ1 export/reimport tests).
    pub sources: Vec<String>,
    /// HLS-report JSON, when the benchmark has HLS kernels.
    pub hls_report: Option<String>,
}

/// Render an HLS report entry for one module.
pub fn report_entry(
    resource: &Resources,
    internal_ns: f64,
    handshakes: &[(&str, &str, u32)], // (bundle, dir "in"/"out", width) with _vld/_rdy suffixes
) -> Json {
    let mut o = JsonObj::new();
    o.insert("resource", crate::ir::builder::resources_to_json(resource));
    let mut t = JsonObj::new();
    t.insert("internal_ns", Json::num(internal_ns));
    o.insert("timing", Json::Obj(t));
    let mut ifaces = vec![
        {
            let mut c = JsonObj::new();
            c.insert("type", Json::str("clock"));
            c.insert("port", Json::str("ap_clk"));
            Json::Obj(c)
        },
        {
            let mut r = JsonObj::new();
            r.insert("type", Json::str("reset"));
            r.insert("port", Json::str("ap_rst_n"));
            r.insert("active_high", Json::Bool(false));
            Json::Obj(r)
        },
    ];
    for (bundle, _dir, _w) in handshakes {
        let mut h = JsonObj::new();
        h.insert("type", Json::str("handshake"));
        h.insert("name", Json::str(*bundle));
        h.insert("data", Json::Arr(vec![Json::str(*bundle)]));
        h.insert("valid", Json::str(format!("{bundle}_vld")));
        h.insert("ready", Json::str(format!("{bundle}_rdy")));
        ifaces.push(Json::Obj(h));
    }
    o.insert("interfaces", Json::Arr(ifaces));
    Json::Obj(o)
}

/// Render a full HLS report from (module, entry) pairs.
pub fn report(entries: &[(String, Json)]) -> String {
    let mut mods = JsonObj::new();
    for (name, e) in entries {
        mods.insert(name, e.clone());
    }
    let mut top = JsonObj::new();
    top.insert("modules", Json::Obj(mods));
    Json::Obj(top).pretty()
}

/// Verilog for an HLS-style kernel stub: ap_clk/ap_rst_n + handshake
/// bundles (`name`, `name_vld`, `name_rdy`), body is a registered
/// placeholder datapath so the synthesis estimator sees real logic.
pub fn hls_kernel_verilog(name: &str, bundles: &[(&str, Dir, u32)]) -> String {
    let mut ports = String::from("  input  wire ap_clk,\n  input  wire ap_rst_n");
    for (b, dir, w) in bundles {
        let (d, vd, rd) = match dir {
            Dir::In => ("input  wire", "input  wire", "output wire"),
            _ => ("output wire", "output wire", "input  wire"),
        };
        let range = if *w > 1 {
            format!("[{}:0] ", w - 1)
        } else {
            String::new()
        };
        ports.push_str(&format!(",\n  {d} {range}{b}"));
        ports.push_str(&format!(",\n  {vd} {b}_vld"));
        ports.push_str(&format!(",\n  {rd} {b}_rdy"));
    }
    format!(
        "// HLS-generated kernel (Vitis HLS style).\nmodule {name} (\n{ports}\n);\n  reg [7:0] ap_state;\n  always @(posedge ap_clk) begin\n    if (!ap_rst_n) ap_state <= 8'd0;\n    else ap_state <= ap_state + 8'd1;\n  end\nendmodule\n"
    )
}

/// Handshake wire triple declaration for structural tops.
pub fn hs_wires(name: &str, width: u32) -> String {
    let range = if width > 1 {
        format!("[{}:0] ", width - 1)
    } else {
        String::new()
    };
    format!("  wire {range}{name};\n  wire {name}_vld;\n  wire {name}_rdy;\n")
}

/// Handshake connection triple for an instance port bundle.
pub fn hs_conn(port: &str, wire: &str) -> String {
    format!(".{port}({wire}), .{port}_vld({wire}_vld), .{port}_rdy({wire}_rdy)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_stub_parses_and_imports() {
        let src = hls_kernel_verilog(
            "PE",
            &[("i", Dir::In, 64), ("o", Dir::Out, 64)],
        );
        let mods = crate::plugins::importer::import_verilog(&src).unwrap();
        assert_eq!(mods[0].name, "PE");
        assert_eq!(mods[0].port("i").unwrap().width, 64);
        assert_eq!(mods[0].port("o_rdy").unwrap().dir, Dir::In);
    }

    #[test]
    fn report_applies() {
        let src = hls_kernel_verilog("K", &[("x", Dir::In, 32)]);
        let mut d = Design::new("K");
        for m in crate::plugins::importer::import_verilog(&src).unwrap() {
            d.add(m);
        }
        let rep = report(&[(
            "K".into(),
            report_entry(
                &Resources::new(5000.0, 4000.0, 2.0, 8.0, 0.0),
                3.0,
                &[("x", "in", 32)],
            ),
        )]);
        crate::plugins::hls_report::apply_report(&mut d, &rep).unwrap();
        let k = d.module("K").unwrap();
        assert_eq!(k.interface_of("x").unwrap().kind(), "handshake");
        assert_eq!(k.interface_of("ap_clk").unwrap().kind(), "clock");
        assert!(k.uncovered_ports().is_empty());
    }
}

//! Best-first branch & bound over the simplex LP relaxation.
//!
//! Small exact MILP solver sufficient for the AutoBridge floorplan
//! formulation (hundreds of binaries). Budgeted by node count — the
//! analogue of the paper's 400-second COIN-OR limit.

use crate::ilp::model::{IlpModel, Solution, Status};
use crate::ilp::simplex::solve_lp;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

const INT_TOL: f64 = 1e-6;

#[derive(Debug, Clone)]
pub struct BnbConfig {
    /// Maximum number of B&B nodes to expand.
    pub max_nodes: usize,
    /// Stop when |best - bound| / max(1,|best|) below this gap.
    pub rel_gap: f64,
    /// Warm-start incumbent (full variable vector). If feasible, search
    /// starts with it and prunes against it immediately — the structured
    /// callers (floorplanning) can supply a cheap greedy solution.
    pub initial: Option<Vec<f64>>,
}

impl Default for BnbConfig {
    fn default() -> Self {
        BnbConfig {
            max_nodes: 200_000,
            rel_gap: 1e-6,
            initial: None,
        }
    }
}

struct Node {
    bound: f64,
    lb: Vec<f64>,
    ub: Vec<f64>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on bound (best-first): reverse for BinaryHeap max-heap.
        other
            .bound
            .partial_cmp(&self.bound)
            .unwrap_or(Ordering::Equal)
    }
}

/// Greedy LP dive: repeatedly fix the most-fractional integer variable to
/// its nearest value and re-solve; returns a feasible integer incumbent
/// if the dive survives.
fn dive(m: &IlpModel, mut lb: Vec<f64>, mut ub: Vec<f64>) -> Option<Solution> {
    for _ in 0..m.num_vars() + 1 {
        let sol = solve_lp(m, Some(&lb), Some(&ub));
        if sol.status != Status::Optimal {
            return None;
        }
        let frac = m
            .vars
            .iter()
            .enumerate()
            .filter(|(_, v)| v.integer)
            .map(|(i, _)| (i, (sol.x[i] - sol.x[i].round()).abs()))
            .filter(|(_, f)| *f > INT_TOL)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(Ordering::Equal));
        match frac {
            None => {
                let mut x = sol.x;
                for (i, v) in m.vars.iter().enumerate() {
                    if v.integer {
                        x[i] = x[i].round();
                    }
                }
                if !m.is_feasible(&x, 1e-6) {
                    return None;
                }
                let objective = m.objective_value(&x);
                return Some(Solution {
                    status: Status::Optimal,
                    objective,
                    x,
                });
            }
            Some((i, _)) => {
                let r = sol.x[i].round().clamp(lb[i], ub[i]);
                lb[i] = r;
                ub[i] = r;
            }
        }
    }
    None
}

/// Solve the MILP. Returns the incumbent with status:
/// `Optimal` (proved), `Limit` (budget hit, best found returned),
/// `Infeasible`, or `Unbounded`.
pub fn solve(m: &IlpModel, cfg: &BnbConfig) -> Solution {
    let n = m.num_vars();
    let root_lb: Vec<f64> = m.vars.iter().map(|v| v.lb).collect();
    let root_ub: Vec<f64> = m.vars.iter().map(|v| v.ub).collect();

    let root = solve_lp(m, Some(&root_lb), Some(&root_ub));
    match root.status {
        Status::Infeasible => return root,
        Status::Unbounded => return root,
        _ => {}
    }

    let mut heap = BinaryHeap::new();
    heap.push(Node {
        bound: root.objective,
        lb: root_lb.clone(),
        ub: root_ub.clone(),
    });

    // Incumbent: the caller's warm start if feasible, else a greedy LP
    // dive — either way best-first search gets a pruning bound and a
    // fallback answer when the node budget runs out.
    let mut best: Option<Solution> = cfg
        .initial
        .as_ref()
        .filter(|x0| x0.len() == n && m.is_feasible(x0, 1e-6))
        .map(|x0| Solution {
            status: Status::Optimal,
            objective: m.objective_value(x0),
            x: x0.clone(),
        })
        .or_else(|| dive(m, root_lb, root_ub));
    let mut nodes = 0usize;
    let mut budget_hit = false;

    while let Some(node) = heap.pop() {
        // Prune by bound.
        if let Some(b) = &best {
            if node.bound >= b.objective - cfg.rel_gap * b.objective.abs().max(1.0) {
                continue;
            }
        }
        if nodes >= cfg.max_nodes {
            budget_hit = true;
            break;
        }
        nodes += 1;

        let sol = solve_lp(m, Some(&node.lb), Some(&node.ub));
        if sol.status != Status::Optimal {
            continue;
        }
        if let Some(b) = &best {
            if sol.objective >= b.objective - 1e-12 {
                continue;
            }
        }

        // Most-fractional integer variable.
        let frac_var = m
            .vars
            .iter()
            .enumerate()
            .filter(|(_, v)| v.integer)
            .map(|(i, _)| (i, (sol.x[i] - sol.x[i].round()).abs()))
            .filter(|(_, f)| *f > INT_TOL)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(Ordering::Equal));

        match frac_var {
            None => {
                // Integral — new incumbent.
                let better = best
                    .as_ref()
                    .map(|b| sol.objective < b.objective - 1e-12)
                    .unwrap_or(true);
                if better {
                    best = Some(Solution {
                        status: Status::Optimal,
                        objective: sol.objective,
                        x: sol.x,
                    });
                }
            }
            Some((i, _)) => {
                let xi = sol.x[i];
                // Down branch: ub_i = floor(xi)
                let mut ub_dn = node.ub.clone();
                ub_dn[i] = xi.floor();
                if node.lb[i] <= ub_dn[i] {
                    heap.push(Node {
                        bound: sol.objective,
                        lb: node.lb.clone(),
                        ub: ub_dn,
                    });
                }
                // Up branch: lb_i = ceil(xi)
                let mut lb_up = node.lb.clone();
                lb_up[i] = xi.ceil();
                if lb_up[i] <= node.ub[i] {
                    heap.push(Node {
                        bound: sol.objective,
                        lb: lb_up,
                        ub: node.ub.clone(),
                    });
                }
            }
        }
    }

    match best {
        Some(mut b) => {
            if budget_hit {
                b.status = Status::Limit;
            }
            debug_assert!(m.is_feasible(&b.x, 1e-4));
            b
        }
        None => Solution {
            status: if budget_hit {
                Status::Limit
            } else {
                Status::Infeasible
            },
            objective: f64::INFINITY,
            x: vec![0.0; n],
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::model::{Cmp, IlpModel};

    #[test]
    fn knapsack() {
        // max 10a + 6b + 4c s.t. a+b+c<=2  (values as min of negatives)
        let mut m = IlpModel::new();
        let a = m.binary("a");
        let b = m.binary("b");
        let c = m.binary("c");
        m.obj(a, -10.0);
        m.obj(b, -6.0);
        m.obj(c, -4.0);
        m.constraint("cap", vec![(a, 1.0), (b, 1.0), (c, 1.0)], Cmp::Le, 2.0);
        let s = solve(&m, &BnbConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.objective - (-16.0)).abs() < 1e-6);
        assert!((s.x[0] - 1.0).abs() < 1e-6);
        assert!((s.x[1] - 1.0).abs() < 1e-6);
        assert!(s.x[2] < 1e-6);
    }

    #[test]
    fn integrality_matters() {
        // min -x s.t. 2x <= 3, x integer → x=1 (LP gives 1.5)
        let mut m = IlpModel::new();
        let x = m.int("x", 0.0, 10.0);
        m.obj(x, -1.0);
        m.constraint("c", vec![(x, 2.0)], Cmp::Le, 3.0);
        let s = solve(&m, &BnbConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.x[0] - 1.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn assignment_problem() {
        // 3 items to 3 bins, cost matrix; classic assignment → optimal perm.
        let cost = [[4.0, 2.0, 8.0], [4.0, 3.0, 7.0], [3.0, 1.0, 6.0]];
        let mut m = IlpModel::new();
        let mut v = [[0usize; 3]; 3];
        for i in 0..3 {
            for j in 0..3 {
                v[i][j] = m.binary(format!("x{i}{j}"));
                m.obj(v[i][j], cost[i][j]);
            }
        }
        for i in 0..3 {
            m.constraint(
                format!("row{i}"),
                (0..3).map(|j| (v[i][j], 1.0)).collect(),
                Cmp::Eq,
                1.0,
            );
            m.constraint(
                format!("col{i}"),
                (0..3).map(|j| (v[j][i], 1.0)).collect(),
                Cmp::Eq,
                1.0,
            );
        }
        let s = solve(&m, &BnbConfig::default());
        assert_eq!(s.status, Status::Optimal);
        // optimum: (0,1)=2? rows to cols: r0→c1 (2), r1→c0 (4), r2→c2 (6)? =12
        // alternative r0→c0(4), r1→c2(7)... 4+7+1=12. Both 12.
        assert!((s.objective - 12.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn infeasible_ilp() {
        let mut m = IlpModel::new();
        let a = m.binary("a");
        let b = m.binary("b");
        m.constraint("c1", vec![(a, 1.0), (b, 1.0)], Cmp::Ge, 3.0);
        let s = solve(&m, &BnbConfig::default());
        assert_eq!(s.status, Status::Infeasible);
    }

    #[test]
    fn budget_limit_returns_incumbent_status() {
        // A slightly larger knapsack with tiny node budget.
        let mut m = IlpModel::new();
        let vars: Vec<_> = (0..12).map(|i| m.binary(format!("v{i}"))).collect();
        for (i, &v) in vars.iter().enumerate() {
            m.obj(v, -((i % 5) as f64 + 1.0));
        }
        m.constraint(
            "cap",
            vars.iter().map(|&v| (v, 1.0)).collect(),
            Cmp::Le,
            6.0,
        );
        let s = solve(
            &m,
            &BnbConfig {
                max_nodes: 1,
                rel_gap: 1e-9,
                initial: None,
            },
        );
        // With 1 node we may or may not find the incumbent; status must be
        // Limit or Optimal-with-value.
        assert!(matches!(s.status, Status::Limit | Status::Optimal));
    }

    #[test]
    fn mixed_integer_continuous() {
        // min y s.t. y >= x - 0.5, y >= 0.5 - x, x in {0,1} → y = 0.5 at either x
        let mut m = IlpModel::new();
        let x = m.binary("x");
        let y = m.cont("y", 0.0, 10.0);
        m.obj(y, 1.0);
        m.constraint("a", vec![(y, 1.0), (x, -1.0)], Cmp::Ge, -0.5);
        m.constraint("b", vec![(y, 1.0), (x, 1.0)], Cmp::Ge, 0.5);
        let s = solve(&m, &BnbConfig::default());
        assert_eq!(s.status, Status::Optimal);
        assert!((s.objective - 0.5).abs() < 1e-6, "{s:?}");
    }
}

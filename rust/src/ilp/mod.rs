//! Exact MILP solver substrate: model builder, dense two-phase simplex for
//! the LP relaxation, and best-first branch & bound (replaces COIN-OR CBC
//! in the paper's toolchain).

pub mod branch_bound;
pub mod model;
pub mod simplex;

pub use branch_bound::{solve, BnbConfig};
pub use model::{Cmp, IlpModel, Solution, Status, VarId};
pub use simplex::solve_lp;

//! ILP model builder — the interface the AutoBridge floorplan formulation
//! (§3.4 stage 3) targets. Solved exactly by the bundled simplex + branch
//! & bound (the paper uses the COIN-OR CBC solver with a 400 s limit; we
//! bound work with node/iteration budgets instead).

use std::fmt;

/// Index of a decision variable.
pub type VarId = usize;

#[derive(Debug, Clone)]
pub struct Var {
    pub name: String,
    pub lb: f64,
    pub ub: f64,
    pub integer: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

impl fmt::Display for Cmp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Cmp::Le => "<=",
            Cmp::Ge => ">=",
            Cmp::Eq => "=",
        })
    }
}

#[derive(Debug, Clone)]
pub struct Constraint {
    pub name: String,
    pub terms: Vec<(VarId, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// A minimization ILP.
#[derive(Debug, Clone, Default)]
pub struct IlpModel {
    pub vars: Vec<Var>,
    /// Linear objective to minimize.
    pub objective: Vec<(VarId, f64)>,
    pub constraints: Vec<Constraint>,
}

impl IlpModel {
    pub fn new() -> IlpModel {
        IlpModel::default()
    }

    /// Add a binary 0/1 variable.
    pub fn binary(&mut self, name: impl Into<String>) -> VarId {
        self.var(name, 0.0, 1.0, true)
    }

    /// Add an integer variable in [lb, ub].
    pub fn int(&mut self, name: impl Into<String>, lb: f64, ub: f64) -> VarId {
        self.var(name, lb, ub, true)
    }

    /// Add a continuous variable in [lb, ub].
    pub fn cont(&mut self, name: impl Into<String>, lb: f64, ub: f64) -> VarId {
        self.var(name, lb, ub, false)
    }

    fn var(&mut self, name: impl Into<String>, lb: f64, ub: f64, integer: bool) -> VarId {
        assert!(lb <= ub, "var bounds");
        assert!(lb >= 0.0, "only non-negative variables supported");
        self.vars.push(Var {
            name: name.into(),
            lb,
            ub,
            integer,
        });
        self.vars.len() - 1
    }

    /// Set (replace) the objective coefficient of `v`.
    pub fn obj(&mut self, v: VarId, coeff: f64) {
        if let Some(t) = self.objective.iter_mut().find(|(id, _)| *id == v) {
            t.1 += coeff;
        } else {
            self.objective.push((v, coeff));
        }
    }

    pub fn constraint(
        &mut self,
        name: impl Into<String>,
        terms: Vec<(VarId, f64)>,
        cmp: Cmp,
        rhs: f64,
    ) {
        self.constraints.push(Constraint {
            name: name.into(),
            terms,
            cmp,
            rhs,
        });
    }

    pub fn num_vars(&self) -> usize {
        self.vars.len()
    }

    /// Evaluate the objective at a point.
    pub fn objective_value(&self, x: &[f64]) -> f64 {
        self.objective.iter().map(|(v, c)| c * x[*v]).sum()
    }

    /// Check feasibility of a point within tolerance.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        for (i, v) in self.vars.iter().enumerate() {
            if x[i] < v.lb - tol || x[i] > v.ub + tol {
                return false;
            }
            if v.integer && (x[i] - x[i].round()).abs() > tol {
                return false;
            }
        }
        for c in &self.constraints {
            let lhs: f64 = c.terms.iter().map(|(v, co)| co * x[*v]).sum();
            let ok = match c.cmp {
                Cmp::Le => lhs <= c.rhs + tol,
                Cmp::Ge => lhs >= c.rhs - tol,
                Cmp::Eq => (lhs - c.rhs).abs() <= tol,
            };
            if !ok {
                return false;
            }
        }
        true
    }
}

/// Outcome of an LP/ILP solve.
#[derive(Debug, Clone, PartialEq)]
pub enum Status {
    Optimal,
    Infeasible,
    Unbounded,
    /// Budget exhausted; the incumbent (if any) is returned.
    Limit,
}

#[derive(Debug, Clone)]
pub struct Solution {
    pub status: Status,
    pub objective: f64,
    pub x: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_evaluate() {
        let mut m = IlpModel::new();
        let a = m.binary("a");
        let b = m.cont("b", 0.0, 10.0);
        m.obj(a, 3.0);
        m.obj(b, 1.0);
        m.constraint("c0", vec![(a, 1.0), (b, 2.0)], Cmp::Le, 5.0);
        assert_eq!(m.num_vars(), 2);
        assert_eq!(m.objective_value(&[1.0, 2.0]), 5.0);
        assert!(m.is_feasible(&[1.0, 2.0], 1e-9));
        assert!(!m.is_feasible(&[1.0, 2.5], 1e-9)); // violates c0
        assert!(!m.is_feasible(&[0.5, 0.0], 1e-9)); // a not integral
    }

    #[test]
    fn obj_accumulates() {
        let mut m = IlpModel::new();
        let a = m.cont("a", 0.0, 1.0);
        m.obj(a, 1.0);
        m.obj(a, 2.0);
        assert_eq!(m.objective_value(&[1.0]), 3.0);
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn rejects_negative_lb() {
        let mut m = IlpModel::new();
        m.cont("bad", -1.0, 1.0);
    }
}

//! Dense two-phase primal simplex for the LP relaxation.
//!
//! Solves `min cᵀx  s.t.  Ax {≤,≥,=} b,  lb ≤ x ≤ ub` with all `lb ≥ 0`.
//! Lower bounds are handled by shifting, upper bounds by explicit rows
//! (problem sizes in the floorplanner are a few hundred variables, where a
//! dense tableau is fast and simple). Bland's rule guards against cycling.

use crate::ilp::model::{Cmp, IlpModel, Solution, Status};

const EPS: f64 = 1e-9;

/// Solve the LP relaxation of `m` (integrality dropped). Additional bound
/// overrides (used by branch & bound) may tighten `lb`/`ub` per variable.
pub fn solve_lp(m: &IlpModel, lb_over: Option<&[f64]>, ub_over: Option<&[f64]>) -> Solution {
    let n = m.num_vars();
    let lb: Vec<f64> = (0..n)
        .map(|i| lb_over.map(|o| o[i]).unwrap_or(m.vars[i].lb))
        .collect();
    let ub: Vec<f64> = (0..n)
        .map(|i| ub_over.map(|o| o[i]).unwrap_or(m.vars[i].ub))
        .collect();
    if lb.iter().zip(&ub).any(|(l, u)| *l > u + EPS) {
        return Solution {
            status: Status::Infeasible,
            objective: f64::INFINITY,
            x: vec![0.0; n],
        };
    }

    // Shift x = x' + lb so x' >= 0; fold shift into rhs.
    // Build row list: model constraints (+ shifted rhs), then finite
    // upper-bound rows x'_i <= ub_i - lb_i.
    struct Row {
        coeffs: Vec<(usize, f64)>,
        cmp: Cmp,
        rhs: f64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for c in &m.constraints {
        let shift: f64 = c.terms.iter().map(|(v, co)| co * lb[*v]).sum();
        rows.push(Row {
            coeffs: c.terms.clone(),
            cmp: c.cmp,
            rhs: c.rhs - shift,
        });
    }
    for i in 0..n {
        let range = ub[i] - lb[i];
        if range.is_finite() {
            rows.push(Row {
                coeffs: vec![(i, 1.0)],
                cmp: Cmp::Le,
                rhs: range,
            });
        }
    }

    let nrows = rows.len();
    // Columns: n structural + nrows slack/surplus + up to nrows artificial.
    // Count slacks and artificials.
    let mut ncols = n;
    let mut slack_col = vec![usize::MAX; nrows];
    let mut art_col = vec![usize::MAX; nrows];
    // Normalize rhs >= 0 first.
    let mut norm: Vec<(Vec<(usize, f64)>, Cmp, f64)> = rows
        .iter()
        .map(|r| {
            if r.rhs < 0.0 {
                let flipped = r.coeffs.iter().map(|(v, c)| (*v, -c)).collect();
                let cmp = match r.cmp {
                    Cmp::Le => Cmp::Ge,
                    Cmp::Ge => Cmp::Le,
                    Cmp::Eq => Cmp::Eq,
                };
                (flipped, cmp, -r.rhs)
            } else {
                (r.coeffs.clone(), r.cmp, r.rhs)
            }
        })
        .collect();
    for (ri, (_, cmp, _)) in norm.iter().enumerate() {
        match cmp {
            Cmp::Le => {
                slack_col[ri] = ncols;
                ncols += 1;
            }
            Cmp::Ge => {
                slack_col[ri] = ncols; // surplus (coeff -1)
                ncols += 1;
                art_col[ri] = ncols;
                ncols += 1;
            }
            Cmp::Eq => {
                art_col[ri] = ncols;
                ncols += 1;
            }
        }
    }

    // Tableau: nrows x (ncols + 1 rhs).
    let width = ncols + 1;
    let mut t = vec![0.0f64; nrows * width];
    let mut basis = vec![usize::MAX; nrows];
    for (ri, (coeffs, cmp, rhs)) in norm.iter_mut().enumerate() {
        for (v, c) in coeffs.iter() {
            t[ri * width + v] += c;
        }
        match cmp {
            Cmp::Le => {
                t[ri * width + slack_col[ri]] = 1.0;
                basis[ri] = slack_col[ri];
            }
            Cmp::Ge => {
                t[ri * width + slack_col[ri]] = -1.0;
                t[ri * width + art_col[ri]] = 1.0;
                basis[ri] = art_col[ri];
            }
            Cmp::Eq => {
                t[ri * width + art_col[ri]] = 1.0;
                basis[ri] = art_col[ri];
            }
        }
        t[ri * width + ncols] = *rhs;
    }

    let has_artificials = art_col.iter().any(|&c| c != usize::MAX);

    // Phase 1: minimize sum of artificials.
    if has_artificials {
        let mut obj = vec![0.0f64; width];
        for &c in &art_col {
            if c != usize::MAX {
                obj[c] = 1.0;
            }
        }
        // Price out basic artificials.
        let mut z = vec![0.0f64; width];
        for (ri, &b) in basis.iter().enumerate() {
            if obj[b] != 0.0 {
                for j in 0..width {
                    z[j] += obj[b] * t[ri * width + j];
                }
            }
        }
        let mut red: Vec<f64> = (0..width).map(|j| obj[j] - z[j]).collect();
        if !pivot_loop(&mut t, &mut basis, &mut red, nrows, ncols, width) {
            // Phase 1 LP can't be unbounded (objective bounded below by 0);
            // treat failure as infeasible.
            return infeasible(n);
        }
        let phase1_obj = -red[ncols];
        if phase1_obj > 1e-6 {
            return infeasible(n);
        }
        // Drive remaining basic artificials out (degenerate).
        for ri in 0..nrows {
            if art_col.contains(&basis[ri]) && basis[ri] != usize::MAX {
                // pivot on any nonzero structural/slack column
                if let Some(j) = (0..ncols)
                    .filter(|j| !art_col.contains(j))
                    .find(|&j| t[ri * width + j].abs() > EPS)
                {
                    pivot(&mut t, &mut basis, &mut red, ri, j, nrows, width);
                } else {
                    // redundant row; leave artificial at zero
                }
            }
        }
    }

    // Phase 2: minimize the real objective over current basis.
    let mut obj = vec![0.0f64; width];
    for (v, c) in &m.objective {
        obj[*v] += c;
    }
    // Forbid artificials from re-entering by giving them huge cost.
    for &c in &art_col {
        if c != usize::MAX {
            obj[c] = 1e18;
        }
    }
    let mut z = vec![0.0f64; width];
    for (ri, &b) in basis.iter().enumerate() {
        if obj[b] != 0.0 {
            for j in 0..width {
                z[j] += obj[b] * t[ri * width + j];
            }
        }
    }
    let mut red: Vec<f64> = (0..width).map(|j| obj[j] - z[j]).collect();
    if !pivot_loop(&mut t, &mut basis, &mut red, nrows, ncols, width) {
        return Solution {
            status: Status::Unbounded,
            objective: f64::NEG_INFINITY,
            x: vec![0.0; n],
        };
    }

    // Extract solution (unshift).
    let mut x = lb.clone();
    for (ri, &b) in basis.iter().enumerate() {
        if b < n {
            x[b] = lb[b] + t[ri * width + ncols];
        }
    }
    let objective = m.objective_value(&x);
    Solution {
        status: Status::Optimal,
        objective,
        x,
    }
}

fn infeasible(n: usize) -> Solution {
    Solution {
        status: Status::Infeasible,
        objective: f64::INFINITY,
        x: vec![0.0; n],
    }
}

/// Primal simplex pivot loop on reduced costs `red` (index ncols = -obj).
/// Returns false if unbounded.
fn pivot_loop(
    t: &mut [f64],
    basis: &mut [usize],
    red: &mut [f64],
    nrows: usize,
    ncols: usize,
    width: usize,
) -> bool {
    let max_iters = 50_000.max(200 * (nrows + ncols));
    for iter in 0..max_iters {
        // Entering: Dantzig rule normally, Bland's rule after many iters.
        let entering = if iter < max_iters / 2 {
            let mut best = usize::MAX;
            let mut best_val = -1e-7;
            for (j, &r) in red.iter().enumerate().take(ncols) {
                if r < best_val {
                    best_val = r;
                    best = j;
                }
            }
            best
        } else {
            (0..ncols).find(|&j| red[j] < -1e-9).unwrap_or(usize::MAX)
        };
        if entering == usize::MAX {
            return true; // optimal
        }
        // Leaving: min ratio.
        let mut leave = usize::MAX;
        let mut best_ratio = f64::INFINITY;
        for ri in 0..nrows {
            let a = t[ri * width + entering];
            if a > EPS {
                let ratio = t[ri * width + ncols] / a;
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave != usize::MAX
                        && basis[ri] < basis[leave])
                {
                    best_ratio = ratio;
                    leave = ri;
                }
            }
        }
        if leave == usize::MAX {
            return false; // unbounded
        }
        pivot(t, basis, red, leave, entering, nrows, width);
    }
    true // iteration budget exhausted: return current (near-optimal) point
}

fn pivot(
    t: &mut [f64],
    basis: &mut [usize],
    red: &mut [f64],
    leave: usize,
    entering: usize,
    nrows: usize,
    width: usize,
) {
    let piv = t[leave * width + entering];
    debug_assert!(piv.abs() > EPS);
    let inv = 1.0 / piv;
    for j in 0..width {
        t[leave * width + j] *= inv;
    }
    for ri in 0..nrows {
        if ri != leave {
            let f = t[ri * width + entering];
            if f.abs() > EPS {
                for j in 0..width {
                    t[ri * width + j] -= f * t[leave * width + j];
                }
            }
        }
    }
    let f = red[entering];
    if f.abs() > EPS {
        for j in 0..width {
            red[j] -= f * t[leave * width + j];
        }
    }
    basis[leave] = entering;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::model::*;

    #[test]
    fn simple_lp() {
        // min -x - 2y  s.t. x + y <= 4, x <= 3, y <= 2  → x=2..3? optimum x=2,y=2? obj -6 at (2,2)
        let mut m = IlpModel::new();
        let x = m.cont("x", 0.0, 3.0);
        let y = m.cont("y", 0.0, 2.0);
        m.obj(x, -1.0);
        m.obj(y, -2.0);
        m.constraint("c", vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        let s = solve_lp(&m, None, None);
        assert_eq!(s.status, Status::Optimal);
        assert!((s.objective - (-6.0)).abs() < 1e-6, "{s:?}");
        assert!((s.x[0] - 2.0).abs() < 1e-6);
        assert!((s.x[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn equality_and_ge() {
        // min x + y  s.t. x + y = 10, x >= 3, y >= 2 → handled via bounds
        let mut m = IlpModel::new();
        let x = m.cont("x", 3.0, 100.0);
        let y = m.cont("y", 2.0, 100.0);
        m.obj(x, 1.0);
        m.obj(y, 1.0);
        m.constraint("eq", vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        let s = solve_lp(&m, None, None);
        assert_eq!(s.status, Status::Optimal);
        assert!((s.objective - 10.0).abs() < 1e-6);
        assert!(s.x[0] >= 3.0 - 1e-6 && s.x[1] >= 2.0 - 1e-6);
    }

    #[test]
    fn ge_constraint() {
        // min 2x + 3y  s.t. x + y >= 5 → pick x=5, obj 10
        let mut m = IlpModel::new();
        let x = m.cont("x", 0.0, 100.0);
        let y = m.cont("y", 0.0, 100.0);
        m.obj(x, 2.0);
        m.obj(y, 3.0);
        m.constraint("g", vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 5.0);
        let s = solve_lp(&m, None, None);
        assert_eq!(s.status, Status::Optimal);
        assert!((s.objective - 10.0).abs() < 1e-6, "{s:?}");
    }

    #[test]
    fn infeasible_detected() {
        let mut m = IlpModel::new();
        let x = m.cont("x", 0.0, 1.0);
        m.constraint("c", vec![(x, 1.0)], Cmp::Ge, 5.0);
        let s = solve_lp(&m, None, None);
        assert_eq!(s.status, Status::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut m = IlpModel::new();
        let x = m.cont("x", 0.0, f64::INFINITY);
        m.obj(x, -1.0);
        let s = solve_lp(&m, None, None);
        assert_eq!(s.status, Status::Unbounded);
    }

    #[test]
    fn bound_overrides() {
        let mut m = IlpModel::new();
        let x = m.cont("x", 0.0, 10.0);
        m.obj(x, -1.0);
        let s = solve_lp(&m, None, Some(&[4.0]));
        assert_eq!(s.status, Status::Optimal);
        assert!((s.x[0] - 4.0).abs() < 1e-6);
        // contradictory overrides
        let s2 = solve_lp(&m, Some(&[5.0]), Some(&[4.0]));
        assert_eq!(s2.status, Status::Infeasible);
    }

    #[test]
    fn degenerate_with_redundant_rows() {
        let mut m = IlpModel::new();
        let x = m.cont("x", 0.0, 10.0);
        let y = m.cont("y", 0.0, 10.0);
        m.obj(x, 1.0);
        m.obj(y, 1.0);
        m.constraint("a", vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 4.0);
        m.constraint("b", vec![(x, 2.0), (y, 2.0)], Cmp::Eq, 8.0); // redundant
        let s = solve_lp(&m, None, None);
        assert_eq!(s.status, Status::Optimal);
        assert!((s.objective - 4.0).abs() < 1e-6);
    }

    #[test]
    fn lower_bound_shifting() {
        // min x s.t. x >= lb via bounds only.
        let mut m = IlpModel::new();
        let x = m.cont("x", 2.5, 7.0);
        m.obj(x, 1.0);
        let s = solve_lp(&m, None, None);
        assert_eq!(s.status, Status::Optimal);
        assert!((s.x[0] - 2.5).abs() < 1e-6);
    }
}

//! Design Rule Checking (DRC) passes: verify the IR invariant assumptions
//! of §3.1 plus referential integrity. Run after every transformation pass
//! by the pass manager (when DRC hooks are enabled).

use crate::ir::core::*;
use crate::ir::index::{DesignIndex, ModuleConn};
use crate::ir::intern::Interner;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DrcViolation {
    pub module: String,
    pub rule: &'static str,
    pub detail: String,
}

impl fmt::Display for DrcViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}: {}", self.rule, self.module, self.detail)
    }
}

/// Run all DRC rules over the design. Empty result = clean.
pub fn check(d: &Design) -> Vec<DrcViolation> {
    let mut index = DesignIndex::for_design(d);
    check_with(d, &mut index)
}

/// Run all DRC rules, reusing `index`'s cached connectivity. The pass
/// pipeline's after-each-pass hook passes its long-lived index here, so
/// only modules dirtied since the last check are re-analyzed instead of
/// rebuilding every block graph from scratch.
pub fn check_with(d: &Design, index: &mut DesignIndex) -> Vec<DrcViolation> {
    let mut v = Vec::new();
    check_referential(d, &mut v);
    for m in d.modules.values() {
        check_interfaces_cover_known_ports(m, &mut v);
        if m.is_grouped() {
            let (conn, interner) = index.conn(d, &m.name).expect("grouped module");
            check_grouped(d, m, conn, interner, &mut v);
        }
    }
    v
}

/// Panic with a readable report if the design has violations (test helper).
pub fn assert_clean(d: &Design) {
    let violations = check(d);
    if !violations.is_empty() {
        let mut msg = format!("{} DRC violations:\n", violations.len());
        for viol in &violations {
            msg.push_str(&format!("  {viol}\n"));
        }
        panic!("{msg}");
    }
}

fn check_referential(d: &Design, out: &mut Vec<DrcViolation>) {
    if !d.modules.contains_key(&d.top) {
        out.push(DrcViolation {
            module: d.top.clone(),
            rule: "top-exists",
            detail: "top module not found in design".into(),
        });
    }
    for m in d.modules.values() {
        for inst in m.instances() {
            if !d.modules.contains_key(&inst.module_name) {
                out.push(DrcViolation {
                    module: m.name.clone(),
                    rule: "module-ref",
                    detail: format!(
                        "instance '{}' references unknown module '{}'",
                        inst.instance_name, inst.module_name
                    ),
                });
            }
        }
    }
}

fn check_grouped(
    d: &Design,
    m: &Module,
    conn: &ModuleConn,
    interner: &Interner,
    out: &mut Vec<DrcViolation>,
) {
    // Invariant 1: each wire connects exactly two endpoints (no fan-out).
    // Parent ports count as one endpoint; a completely unused wire is also
    // flagged. Clock/reset identifiers are exempt: they are broadcast nets
    // handled by dedicated broadcasting aux modules (§3.3 Partitioning).
    let clockish: Vec<&str> = m
        .interfaces
        .iter()
        .filter(|i| matches!(i, Interface::Clock { .. } | Interface::Reset { .. }))
        .flat_map(|i| i.ports())
        .collect();
    for net in &conn.nets {
        let name = interner.resolve(net.name);
        if clockish.contains(&name) {
            continue;
        }
        if net.endpoints.len() != 2 {
            out.push(DrcViolation {
                module: m.name.clone(),
                rule: "two-endpoints",
                detail: format!(
                    "net '{}' has {} endpoints: [{}]",
                    name,
                    net.endpoints.len(),
                    net.endpoints
                        .iter()
                        .map(|e| conn.describe_endpoint(e, interner))
                        .collect::<Vec<_>>()
                        .join(", ")
                ),
            });
        }
    }

    // Invariant 2: every instance connection targets a known identifier
    // (wire or parent port) or a constant — schema enforces the expression
    // shape; here we check identifier resolution and port existence.
    let known_ids: std::collections::BTreeSet<&str> = m
        .wires()
        .iter()
        .map(|w| w.name.as_str())
        .chain(m.ports.iter().map(|p| p.name.as_str()))
        .collect();
    for inst in m.instances() {
        let target = d.module(&inst.module_name);
        let mut seen = std::collections::BTreeSet::new();
        for conn in &inst.connections {
            if !seen.insert(conn.port.as_str()) {
                out.push(DrcViolation {
                    module: m.name.clone(),
                    rule: "dup-connection",
                    detail: format!("instance '{}' connects port '{}' twice", inst.instance_name, conn.port),
                });
            }
            if let Some(t) = target {
                if t.port(&conn.port).is_none() {
                    out.push(DrcViolation {
                        module: m.name.clone(),
                        rule: "port-exists",
                        detail: format!(
                            "instance '{}' connects unknown port '{}.{}'",
                            inst.instance_name, inst.module_name, conn.port
                        ),
                    });
                }
            }
            if let ConnExpr::Id(id) = &conn.value {
                if !known_ids.contains(id.as_str()) {
                    out.push(DrcViolation {
                        module: m.name.clone(),
                        rule: "id-resolves",
                        detail: format!(
                            "instance '{}' port '{}' connects to undeclared identifier '{}'",
                            inst.instance_name, conn.port, id
                        ),
                    });
                }
            }
        }
        // Invariant 3 (interface completeness): all non-constant ports of
        // any interface on the target module must be connected.
        if let Some(t) = target {
            for iface in &t.interfaces {
                if !iface.pipelinable() {
                    continue;
                }
                let connected: Vec<&str> = iface
                    .ports()
                    .into_iter()
                    .filter(|p| {
                        matches!(inst.connection(p), Some(ConnExpr::Id(_)) | Some(ConnExpr::Const { .. }))
                    })
                    .collect();
                if !connected.is_empty() && connected.len() != iface.ports().len() {
                    out.push(DrcViolation {
                        module: m.name.clone(),
                        rule: "iface-complete",
                        detail: format!(
                            "instance '{}': interface '{}' of '{}' partially connected ({}/{})",
                            inst.instance_name,
                            iface.name(),
                            inst.module_name,
                            connected.len(),
                            iface.ports().len()
                        ),
                    });
                }
            }
        }
    }

    // Width consistency between connection endpoints.
    for inst in m.instances() {
        let Some(t) = d.module(&inst.module_name) else {
            continue;
        };
        for conn in &inst.connections {
            let Some(port) = t.port(&conn.port) else {
                continue;
            };
            if let ConnExpr::Id(id) = &conn.value {
                let id_width = m
                    .wires()
                    .iter()
                    .find(|w| &w.name == id)
                    .map(|w| w.width)
                    .or_else(|| m.port(id).map(|p| p.width));
                if let Some(w) = id_width {
                    if w != port.width {
                        out.push(DrcViolation {
                            module: m.name.clone(),
                            rule: "width-match",
                            detail: format!(
                                "'{}'.{} is {}b but identifier '{}' is {}b",
                                inst.instance_name, conn.port, port.width, id, w
                            ),
                        });
                    }
                }
            }
        }
    }
}

/// Interfaces must reference ports that exist on the module.
fn check_interfaces_cover_known_ports(m: &Module, out: &mut Vec<DrcViolation>) {
    for iface in &m.interfaces {
        for p in iface.ports() {
            if m.port(p).is_none() {
                out.push(DrcViolation {
                    module: m.name.clone(),
                    rule: "iface-port-exists",
                    detail: format!("interface '{}' references unknown port '{}'", iface.name(), p),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::core::*;

    fn leaf_ab(d: &mut Design) {
        let mut a = Module::leaf("A", SourceFormat::Verilog, "");
        a.ports = vec![Port::new("o", Dir::Out, 8), Port::new("i", Dir::In, 32)];
        d.add(a);
        let mut b = Module::leaf("B", SourceFormat::Verilog, "");
        b.ports = vec![Port::new("i", Dir::In, 8)];
        d.add(b);
    }

    fn clean_design() -> Design {
        let mut d = Design::new("Top");
        let mut m = Module::grouped("Top");
        m.ports = vec![Port::new("in_data", Dir::In, 32)];
        m.wires_mut().push(Wire {
            name: "w".into(),
            width: 8,
        });
        let mut a = Instance::new("a", "A");
        a.connect("o", ConnExpr::id("w"));
        a.connect("i", ConnExpr::id("in_data"));
        let mut b = Instance::new("b", "B");
        b.connect("i", ConnExpr::id("w"));
        m.instances_mut().push(a);
        m.instances_mut().push(b);
        d.add(m);
        leaf_ab(&mut d);
        d
    }

    #[test]
    fn clean_design_passes() {
        assert_clean(&clean_design());
    }

    #[test]
    fn detects_fanout() {
        let mut d = clean_design();
        // Connect a third endpoint to w.
        let top = d.module_mut("Top").unwrap();
        let mut c = Instance::new("c", "B");
        c.connect("i", ConnExpr::id("w"));
        top.instances_mut().push(c);
        let v = check(&d);
        assert!(v.iter().any(|x| x.rule == "two-endpoints"), "{v:?}");
    }

    #[test]
    fn detects_unknown_module() {
        let mut d = clean_design();
        d.module_mut("Top")
            .unwrap()
            .instances_mut()
            .push(Instance::new("x", "Ghost"));
        let v = check(&d);
        assert!(v.iter().any(|x| x.rule == "module-ref"));
    }

    #[test]
    fn detects_unresolved_identifier() {
        let mut d = clean_design();
        d.module_mut("Top").unwrap().instances_mut()[0]
            .connection_mut("o")
            .map(|c| *c = ConnExpr::id("ghost_wire"));
        let v = check(&d);
        assert!(v.iter().any(|x| x.rule == "id-resolves"));
    }

    #[test]
    fn detects_width_mismatch() {
        let mut d = clean_design();
        d.module_mut("Top").unwrap().wires_mut()[0].width = 16;
        let v = check(&d);
        assert!(v.iter().any(|x| x.rule == "width-match"));
    }

    #[test]
    fn detects_unknown_port() {
        let mut d = clean_design();
        d.module_mut("Top").unwrap().instances_mut()[1].connect("ghost", ConnExpr::id("w"));
        let v = check(&d);
        assert!(v.iter().any(|x| x.rule == "port-exists"));
        // also creates a 3-endpoint net
        assert!(v.iter().any(|x| x.rule == "two-endpoints"));
    }

    #[test]
    fn detects_partial_interface() {
        let mut d = clean_design();
        // Give B a handshake interface; Top only connects the data port.
        let b = d.module_mut("B").unwrap();
        b.ports.push(Port::new("i_vld", Dir::In, 1));
        b.ports.push(Port::new("i_rdy", Dir::Out, 1));
        b.interfaces.push(Interface::Handshake {
            name: "i".into(),
            data: vec!["i".into()],
            valid: "i_vld".into(),
            ready: "i_rdy".into(),
            clk: None,
        });
        let v = check(&d);
        assert!(v.iter().any(|x| x.rule == "iface-complete"), "{v:?}");
    }

    #[test]
    fn detects_bad_interface_port_ref() {
        let mut d = clean_design();
        d.module_mut("A").unwrap().interfaces.push(Interface::Feedforward {
            name: "ff".into(),
            ports: vec!["nonexistent".into()],
        });
        let v = check(&d);
        assert!(v.iter().any(|x| x.rule == "iface-port-exists"));
    }

    #[test]
    fn clock_nets_exempt_from_fanout() {
        let mut d = clean_design();
        let top = d.module_mut("Top").unwrap();
        top.ports.push(Port::new("ap_clk", Dir::In, 1));
        top.interfaces.push(Interface::Clock {
            port: "ap_clk".into(),
        });
        // Broadcast clk to both instances (fan-out of 3 incl parent).
        for a_module_port in ["a", "b"] {
            let _ = a_module_port;
        }
        let a = d.module_mut("A").unwrap();
        a.ports.push(Port::new("ap_clk", Dir::In, 1));
        let b = d.module_mut("B").unwrap();
        b.ports.push(Port::new("ap_clk", Dir::In, 1));
        let top = d.module_mut("Top").unwrap();
        top.instances_mut()[0].connect("ap_clk", ConnExpr::id("ap_clk"));
        top.instances_mut()[1].connect("ap_clk", ConnExpr::id("ap_clk"));
        assert_clean(&d);
    }
}

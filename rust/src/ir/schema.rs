//! JSON (de)serialization of the IR, following the field names of the
//! paper's Figure 8: `module_name`, `module_ports`, `module_wires`,
//! `module_submodules`, `module_verilog` (generalized to `module_source` +
//! `source_format`), `module_interfaces`, `module_metadata`.

use crate::ir::core::*;
use crate::util::json::{Json, JsonObj};
use anyhow::{anyhow, bail, Context, Result};

/// Serialize a whole design (top name, all modules, design metadata) to
/// the paper's JSON schema.
///
/// ```
/// use rsir::ir::builder::LeafBuilder;
/// use rsir::ir::core::Design;
/// use rsir::ir::schema::{design_from_json, design_to_json};
///
/// let mut d = Design::new("Top");
/// d.add(LeafBuilder::verilog_stub("Top").clk_rst().build());
/// let roundtrip = design_from_json(&design_to_json(&d)).unwrap();
/// assert_eq!(roundtrip.top, "Top");
/// ```
pub fn design_to_json(d: &Design) -> Json {
    let mut o = JsonObj::new();
    o.insert("top", Json::str(&d.top));
    let mods: Vec<Json> = d.modules.values().map(module_to_json).collect();
    o.insert("modules", Json::Arr(mods));
    if !d.metadata.is_empty() {
        o.insert("metadata", Json::Obj(d.metadata.clone()));
    }
    Json::Obj(o)
}

/// Deserialize a design from the JSON schema, failing with a path-scoped
/// error (`modules[i]: …`) on the first malformed module.
///
/// ```
/// use rsir::ir::schema::design_from_json;
/// use rsir::util::json::Json;
///
/// let j = Json::parse(r#"{"top": "T", "modules": []}"#).unwrap();
/// assert_eq!(design_from_json(&j).unwrap().top, "T");
/// assert!(design_from_json(&Json::parse("{}").unwrap()).is_err());
/// ```
pub fn design_from_json(j: &Json) -> Result<Design> {
    let top = j
        .at("top")
        .and_then(|t| t.as_str())
        .ok_or_else(|| anyhow!("design missing 'top'"))?;
    let mut d = Design::new(top);
    for (i, mj) in j
        .at("modules")
        .and_then(|m| m.as_arr())
        .ok_or_else(|| anyhow!("design missing 'modules'"))?
        .iter()
        .enumerate()
    {
        let m = module_from_json(mj).with_context(|| format!("modules[{i}]"))?;
        d.add(m);
    }
    if let Some(Json::Obj(meta)) = j.at("metadata") {
        d.metadata = meta.clone();
    }
    Ok(d)
}

/// Serialize one module: `module_name`, `module_ports`, then either
/// leaf fields (`source_format` + `module_source`) or grouped fields
/// (`module_wires` + `module_submodules`), plus interfaces and metadata.
///
/// ```
/// use rsir::ir::builder::LeafBuilder;
/// use rsir::ir::schema::module_to_json;
///
/// let m = LeafBuilder::verilog_stub("Leaf").clk_rst().build();
/// let j = module_to_json(&m);
/// assert_eq!(j.at("module_name").and_then(|n| n.as_str()), Some("Leaf"));
/// ```
pub fn module_to_json(m: &Module) -> Json {
    let mut o = JsonObj::new();
    o.insert("module_name", Json::str(&m.name));
    o.insert(
        "module_ports",
        Json::Arr(m.ports.iter().map(port_to_json).collect()),
    );
    match &m.body {
        Body::Leaf { format, source } => {
            o.insert("source_format", Json::str(format.as_str()));
            o.insert("module_source", Json::str(source));
        }
        Body::Grouped { wires, instances } => {
            o.insert(
                "module_wires",
                Json::Arr(
                    wires
                        .iter()
                        .map(|w| {
                            let mut wo = JsonObj::new();
                            wo.insert("name", Json::str(&w.name));
                            wo.insert("width", Json::num(w.width as f64));
                            Json::Obj(wo)
                        })
                        .collect(),
                ),
            );
            o.insert(
                "module_submodules",
                Json::Arr(instances.iter().map(instance_to_json).collect()),
            );
        }
    }
    if !m.interfaces.is_empty() {
        o.insert(
            "module_interfaces",
            Json::Arr(m.interfaces.iter().map(interface_to_json).collect()),
        );
    }
    if !m.metadata.is_empty() {
        o.insert("module_metadata", Json::Obj(m.metadata.clone()));
    }
    Json::Obj(o)
}

fn port_to_json(p: &Port) -> Json {
    let mut o = JsonObj::new();
    o.insert("name", Json::str(&p.name));
    o.insert("direction", Json::str(p.dir.as_str()));
    o.insert("width", Json::num(p.width as f64));
    Json::Obj(o)
}

fn instance_to_json(i: &Instance) -> Json {
    let mut o = JsonObj::new();
    o.insert("instance_name", Json::str(&i.instance_name));
    o.insert("module_name", Json::str(&i.module_name));
    o.insert(
        "connections",
        Json::Arr(
            i.connections
                .iter()
                .map(|c| {
                    let mut co = JsonObj::new();
                    co.insert("port", Json::str(&c.port));
                    match &c.value {
                        ConnExpr::Id(id) => co.insert("value", Json::str(id)),
                        ConnExpr::Const { width, value } => {
                            co.insert("const", Json::str(format!("{width}'d{value}")))
                        }
                        ConnExpr::Open => co.insert("open", Json::Bool(true)),
                    }
                    Json::Obj(co)
                })
                .collect(),
        ),
    );
    if !i.metadata.is_empty() {
        o.insert("metadata", Json::Obj(i.metadata.clone()));
    }
    Json::Obj(o)
}

fn interface_to_json(iface: &Interface) -> Json {
    let mut o = JsonObj::new();
    o.insert("iface_type", Json::str(iface.kind()));
    match iface {
        Interface::Handshake {
            name,
            data,
            valid,
            ready,
            clk,
        } => {
            o.insert("name", Json::str(name));
            let mut ports = JsonObj::new();
            ports.insert(
                "data",
                Json::Arr(data.iter().map(|d| Json::str(d)).collect()),
            );
            ports.insert("valid", Json::str(valid));
            ports.insert("ready", Json::str(ready));
            if let Some(c) = clk {
                ports.insert("clk", Json::str(c));
            }
            o.insert("iface_ports", Json::Obj(ports));
        }
        Interface::Feedforward { name, ports } | Interface::NonPipeline { name, ports } => {
            o.insert("name", Json::str(name));
            o.insert(
                "iface_ports",
                Json::Arr(ports.iter().map(|p| Json::str(p)).collect()),
            );
        }
        Interface::Clock { port } => {
            o.insert("port", Json::str(port));
        }
        Interface::Reset { port, active_high } => {
            o.insert("port", Json::str(port));
            o.insert("active_high", Json::Bool(*active_high));
        }
    }
    Json::Obj(o)
}

/// Deserialize one module. A `module_source` field makes it a leaf
/// (requiring a valid `source_format`); otherwise it is grouped.
///
/// ```
/// use rsir::ir::builder::LeafBuilder;
/// use rsir::ir::schema::{module_from_json, module_to_json};
///
/// let m = LeafBuilder::verilog_stub("Leaf").clk_rst().build();
/// let back = module_from_json(&module_to_json(&m)).unwrap();
/// assert_eq!(back.name, "Leaf");
/// assert_eq!(back.ports.len(), m.ports.len());
/// ```
pub fn module_from_json(j: &Json) -> Result<Module> {
    let name = j
        .at("module_name")
        .and_then(|n| n.as_str())
        .ok_or_else(|| anyhow!("module missing 'module_name'"))?
        .to_string();
    let mut ports = Vec::new();
    if let Some(parr) = j.at("module_ports").and_then(|p| p.as_arr()) {
        for pj in parr {
            ports.push(port_from_json(pj)?);
        }
    }
    let body = if let Some(src) = j.at("module_source").and_then(|s| s.as_str()) {
        let fmt = j
            .at("source_format")
            .and_then(|f| f.as_str())
            .and_then(SourceFormat::parse)
            .ok_or_else(|| anyhow!("module '{name}': bad source_format"))?;
        Body::Leaf {
            format: fmt,
            source: src.to_string(),
        }
    } else {
        let mut wires = Vec::new();
        if let Some(warr) = j.at("module_wires").and_then(|w| w.as_arr()) {
            for wj in warr {
                wires.push(Wire {
                    name: wj
                        .at("name")
                        .and_then(|n| n.as_str())
                        .ok_or_else(|| anyhow!("wire missing name"))?
                        .to_string(),
                    width: wj.at("width").and_then(|w| w.as_u64()).unwrap_or(1) as u32,
                });
            }
        }
        let mut instances = Vec::new();
        if let Some(iarr) = j.at("module_submodules").and_then(|i| i.as_arr()) {
            for ij in iarr {
                instances.push(instance_from_json(ij)?);
            }
        }
        Body::Grouped { wires, instances }
    };
    let mut interfaces = Vec::new();
    if let Some(iarr) = j.at("module_interfaces").and_then(|i| i.as_arr()) {
        for ij in iarr {
            interfaces.push(interface_from_json(ij)?);
        }
    }
    let metadata = match j.at("module_metadata") {
        Some(Json::Obj(o)) => o.clone(),
        _ => JsonObj::new(),
    };
    Ok(Module {
        name,
        ports,
        body,
        interfaces,
        metadata,
    })
}

fn port_from_json(j: &Json) -> Result<Port> {
    Ok(Port {
        name: j
            .at("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("port missing name"))?
            .to_string(),
        dir: j
            .at("direction")
            .and_then(|d| d.as_str())
            .and_then(Dir::parse)
            .ok_or_else(|| anyhow!("port missing/bad direction"))?,
        width: j.at("width").and_then(|w| w.as_u64()).unwrap_or(1) as u32,
    })
}

fn instance_from_json(j: &Json) -> Result<Instance> {
    let mut inst = Instance::new(
        j.at("instance_name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("instance missing instance_name"))?,
        j.at("module_name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("instance missing module_name"))?,
    );
    if let Some(carr) = j.at("connections").and_then(|c| c.as_arr()) {
        for cj in carr {
            let port = cj
                .at("port")
                .and_then(|p| p.as_str())
                .ok_or_else(|| anyhow!("connection missing port"))?
                .to_string();
            let value = if let Some(id) = cj.at("value").and_then(|v| v.as_str()) {
                ConnExpr::Id(id.to_string())
            } else if let Some(c) = cj.at("const").and_then(|c| c.as_str()) {
                parse_const(c)?
            } else if cj.at("open").is_some() {
                ConnExpr::Open
            } else {
                bail!("connection for port '{port}' has no value/const/open");
            };
            inst.connections.push(Connection { port, value });
        }
    }
    if let Some(Json::Obj(meta)) = j.at("metadata") {
        inst.metadata = meta.clone();
    }
    Ok(inst)
}

/// Parse `<width>'d<value>` constants, e.g. "8'd0".
///
/// ```
/// use rsir::ir::core::ConnExpr;
/// use rsir::ir::schema::parse_const;
///
/// assert!(matches!(
///     parse_const("8'd5").unwrap(),
///     ConnExpr::Const { width: 8, value: 5 }
/// ));
/// assert!(parse_const("not-a-const").is_err());
/// ```
pub fn parse_const(s: &str) -> Result<ConnExpr> {
    let (w, rest) = s
        .split_once("'d")
        .ok_or_else(|| anyhow!("bad const '{s}' (expect <w>'d<v>)"))?;
    Ok(ConnExpr::Const {
        width: w.parse().with_context(|| format!("const width in '{s}'"))?,
        value: rest.parse().with_context(|| format!("const value in '{s}'"))?,
    })
}

fn interface_from_json(j: &Json) -> Result<Interface> {
    let kind = j
        .at("iface_type")
        .and_then(|t| t.as_str())
        .ok_or_else(|| anyhow!("interface missing iface_type"))?;
    match kind {
        "handshake" => {
            let p = j
                .at("iface_ports")
                .ok_or_else(|| anyhow!("handshake missing iface_ports"))?;
            let data = p
                .at("data")
                .and_then(|d| d.as_arr())
                .ok_or_else(|| anyhow!("handshake missing data"))?
                .iter()
                .map(|d| d.as_str().unwrap_or_default().to_string())
                .collect();
            Ok(Interface::Handshake {
                name: j
                    .at("name")
                    .and_then(|n| n.as_str())
                    .unwrap_or("hs")
                    .to_string(),
                data,
                valid: p
                    .at("valid")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("handshake missing valid"))?
                    .to_string(),
                ready: p
                    .at("ready")
                    .and_then(|r| r.as_str())
                    .ok_or_else(|| anyhow!("handshake missing ready"))?
                    .to_string(),
                clk: p.at("clk").and_then(|c| c.as_str()).map(|s| s.to_string()),
            })
        }
        "feedforward" | "nonpipeline" => {
            let ports = j
                .at("iface_ports")
                .and_then(|p| p.as_arr())
                .ok_or_else(|| anyhow!("{kind} missing iface_ports"))?
                .iter()
                .map(|p| p.as_str().unwrap_or_default().to_string())
                .collect();
            let name = j
                .at("name")
                .and_then(|n| n.as_str())
                .unwrap_or(kind)
                .to_string();
            Ok(if kind == "feedforward" {
                Interface::Feedforward { name, ports }
            } else {
                Interface::NonPipeline { name, ports }
            })
        }
        "clock" => Ok(Interface::Clock {
            port: j
                .at("port")
                .and_then(|p| p.as_str())
                .ok_or_else(|| anyhow!("clock missing port"))?
                .to_string(),
        }),
        "reset" => Ok(Interface::Reset {
            port: j
                .at("port")
                .and_then(|p| p.as_str())
                .ok_or_else(|| anyhow!("reset missing port"))?
                .to_string(),
            active_high: j.at("active_high").and_then(|a| a.as_bool()).unwrap_or(true),
        }),
        other => bail!("unknown iface_type '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::core::*;

    fn sample_design() -> Design {
        let mut d = Design::new("LLM");
        let mut top = Module::grouped("LLM");
        top.ports = vec![
            Port::new("ap_clk", Dir::In, 1),
            Port::new("in_data", Dir::In, 64),
        ];
        top.wires_mut().push(Wire {
            name: "I_wire".into(),
            width: 64,
        });
        let mut fifo_inst = Instance::new("FIFO_inst", "FIFO");
        fifo_inst.connect("I", ConnExpr::id("I_wire"));
        fifo_inst.connect("rst", ConnExpr::Const { width: 1, value: 0 });
        fifo_inst.connect("dbg", ConnExpr::Open);
        top.instances_mut().push(fifo_inst);
        d.add(top);

        let mut fifo = Module::leaf("FIFO", SourceFormat::Verilog, "module FIFO(); endmodule");
        fifo.ports = vec![
            Port::new("I", Dir::In, 64),
            Port::new("I_vld", Dir::In, 1),
            Port::new("I_rdy", Dir::Out, 1),
        ];
        fifo.interfaces = vec![Interface::Handshake {
            name: "I".into(),
            data: vec!["I".into()],
            valid: "I_vld".into(),
            ready: "I_rdy".into(),
            clk: Some("ap_clk".into()),
        }];
        fifo.metadata.insert(
            "resource",
            crate::util::json::Json::parse(r#"{"FF":10,"LUT":39}"#).unwrap(),
        );
        d.add(fifo);
        d
    }

    #[test]
    fn design_roundtrip() {
        let d = sample_design();
        let j = design_to_json(&d);
        let d2 = design_from_json(&j).unwrap();
        assert_eq!(d, d2);
    }

    #[test]
    fn roundtrip_through_text() {
        let d = sample_design();
        let text = design_to_json(&d).pretty();
        let d2 = design_from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(d, d2);
    }

    #[test]
    fn schema_uses_paper_field_names() {
        let d = sample_design();
        let text = design_to_json(&d).dump();
        for field in [
            "module_name",
            "module_ports",
            "module_wires",
            "module_submodules",
            "module_interfaces",
            "module_metadata",
            "instance_name",
            "iface_type",
            "iface_ports",
        ] {
            assert!(text.contains(field), "missing field {field}");
        }
    }

    #[test]
    fn const_parse() {
        assert_eq!(
            parse_const("8'd42").unwrap(),
            ConnExpr::Const {
                width: 8,
                value: 42
            }
        );
        assert!(parse_const("42").is_err());
    }

    #[test]
    fn all_interface_kinds_roundtrip() {
        let mut m = Module::leaf("X", SourceFormat::Verilog, "");
        m.interfaces = vec![
            Interface::Feedforward {
                name: "ff".into(),
                ports: vec!["a".into(), "b".into()],
            },
            Interface::NonPipeline {
                name: "np".into(),
                ports: vec!["c".into()],
            },
            Interface::Clock { port: "clk".into() },
            Interface::Reset {
                port: "rst_n".into(),
                active_high: false,
            },
        ];
        let j = module_to_json(&m);
        let m2 = module_from_json(&j).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn error_on_missing_fields() {
        let j = crate::util::json::Json::parse(r#"{"module_ports":[]}"#).unwrap();
        assert!(module_from_json(&j).is_err());
    }
}

//! Content digests of IR subtrees — the cache keys of the incremental
//! re-flow engine.
//!
//! Two granularities:
//!
//! * [`design_digest`] — FNV-1a over the whole design's compact IR JSON
//!   (the key the daemon's whole-request memo has used since the serve
//!   PR; `designs::synthetic::digest` delegates here).
//! * [`module_subtree_digests`] — one digest per module, folding the
//!   module's own JSON with the subtree digests of every instantiated
//!   child **in instance order**. Two modules with byte-identical JSON
//!   and byte-identical reachable children share a digest, so the
//!   digest is a sound memo key for anything computed from a module's
//!   subtree alone (characterization, flattening, per-module pipeline
//!   results): an edit to one leaf changes only the digests on the path
//!   from that leaf to the top.
//!
//! Missing children (dangling `module_name`) and instantiation cycles
//! fold a distinct marker instead of recursing, so the map is total on
//! arbitrary (even DRC-dirty) designs and never diverges.

use crate::ir::core::{Design, Module};
use crate::ir::schema::module_to_json;
use std::collections::{BTreeMap, BTreeSet};

/// 64-bit FNV-1a. The canonical home; `designs::synthetic::fnv1a64`
/// re-exports it.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Incremental FNV-1a hasher for composite keys.
#[derive(Debug, Clone)]
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
}

impl Fnv {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write(&mut self, bytes: &[u8]) -> &mut Self {
        for b in bytes {
            self.0 ^= *b as u64;
            self.0 = self.0.wrapping_mul(0x1_0000_0000_01b3);
        }
        self
    }

    pub fn write_u64(&mut self, v: u64) -> &mut Self {
        self.write(&v.to_le_bytes())
    }

    pub fn write_u32(&mut self, v: u32) -> &mut Self {
        self.write(&v.to_le_bytes())
    }

    pub fn write_usize(&mut self, v: usize) -> &mut Self {
        self.write_u64(v as u64)
    }

    pub fn write_bool(&mut self, v: bool) -> &mut Self {
        self.write(&[v as u8])
    }

    /// Hashes the exact bit pattern — distinguishes `-0.0` from `0.0`
    /// and every NaN payload, which is what a byte-identity cache wants.
    pub fn write_f64(&mut self, v: f64) -> &mut Self {
        self.write_u64(v.to_bits())
    }

    /// Length-prefixed string write, so `("ab","c")` ≠ `("a","bc")`.
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write_usize(s.len());
        self.write(s.as_bytes())
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Digest of a design: FNV-1a over its compact IR JSON.
pub fn design_digest(d: &Design) -> u64 {
    fnv1a64(crate::ir::schema::design_to_json(d).dump().as_bytes())
}

/// Per-module subtree digests for every module in `d` (see module docs).
pub fn module_subtree_digests(d: &Design) -> BTreeMap<String, u64> {
    let mut memo = BTreeMap::new();
    let mut stack = BTreeSet::new();
    for name in d.modules.keys() {
        subtree(d, name, &mut memo, &mut stack);
    }
    memo
}

/// Subtree digest of one module by name (memoized in `memo`).
fn subtree(
    d: &Design,
    name: &str,
    memo: &mut BTreeMap<String, u64>,
    stack: &mut BTreeSet<String>,
) -> u64 {
    if let Some(&h) = memo.get(name) {
        return h;
    }
    let Some(m) = d.module(name) else {
        return fnv1a64(b"<missing-module>");
    };
    if !stack.insert(name.to_string()) {
        // Instantiation cycle: fold a marker for the back-edge. The
        // entry module of the cycle still digests deterministically.
        return fnv1a64(b"<module-cycle>");
    }
    let h = subtree_of(d, m, memo, stack);
    stack.remove(name);
    memo.insert(name.to_string(), h);
    h
}

fn subtree_of(
    d: &Design,
    m: &Module,
    memo: &mut BTreeMap<String, u64>,
    stack: &mut BTreeSet<String>,
) -> u64 {
    let mut f = Fnv::new();
    f.write(module_to_json(m).dump().as_bytes());
    if m.is_grouped() {
        for inst in m.instances() {
            f.write_u64(subtree(d, &inst.module_name, memo, stack));
        }
    }
    f.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::designs::cnn::{self, CnnConfig};
    use crate::designs::synthetic;

    #[test]
    fn fnv_matches_reference_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_fnv_matches_oneshot() {
        let mut f = Fnv::new();
        f.write(b"foo").write(b"bar");
        assert_eq!(f.finish(), fnv1a64(b"foobar"));
    }

    #[test]
    fn design_digest_matches_legacy_synthetic_digest() {
        let d = cnn::generate(&CnnConfig { rows: 2, cols: 2 }).unwrap().design;
        assert_eq!(design_digest(&d), synthetic::digest(&d));
    }

    #[test]
    fn leaf_edit_dirties_exactly_the_path_to_top() {
        let a = cnn::generate(&CnnConfig { rows: 2, cols: 2 }).unwrap().design;
        let mut b = a.clone();
        // Perturb one leaf's timing metadata.
        let leaf = b
            .modules
            .values()
            .find(|m| !m.is_grouped())
            .map(|m| m.name.clone())
            .expect("cnn has leaf modules");
        {
            let m = b.module_mut(&leaf).unwrap();
            let mut t = crate::util::json::JsonObj::new();
            t.insert("internal_ns", crate::util::json::Json::Num(9.87));
            m.metadata.insert("timing", crate::util::json::Json::Obj(t));
        }
        let da = module_subtree_digests(&a);
        let db = module_subtree_digests(&b);
        assert_eq!(da.len(), db.len());
        let mut changed: Vec<&str> = da
            .iter()
            .filter(|(k, v)| db.get(*k) != Some(v))
            .map(|(k, _)| k.as_str())
            .collect();
        changed.sort_unstable();
        // The edited leaf changed, the top changed (it reaches the leaf),
        // and nothing changed that does not reach the leaf.
        assert!(changed.contains(&leaf.as_str()), "edited leaf must be dirty");
        assert!(
            changed.contains(&b.top.as_str()),
            "top reaches every leaf in cnn"
        );
        for name in &changed {
            assert!(
                reaches(&b, name, &leaf),
                "{name} changed but does not reach {leaf}"
            );
        }
    }

    fn reaches(d: &crate::ir::core::Design, from: &str, to: &str) -> bool {
        if from == to {
            return true;
        }
        let Some(m) = d.module(from) else { return false };
        if !m.is_grouped() {
            return false;
        }
        m.instances().iter().any(|i| reaches(d, &i.module_name, to))
    }

    #[test]
    fn digests_are_total_on_dangling_refs() {
        let mut d = cnn::generate(&CnnConfig { rows: 2, cols: 2 }).unwrap().design;
        let top = d.top.clone();
        if let Some(m) = d.module_mut(&top) {
            if m.is_grouped() {
                if let Some(inst) = m.instances_mut().first_mut() {
                    inst.module_name = "no_such_module".into();
                }
            }
        }
        // Must not panic or diverge.
        let _ = module_subtree_digests(&d);
    }
}

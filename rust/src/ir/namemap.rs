//! Mapping between original design components and their transformed
//! counterparts, "maintained throughout the optimization process, enabling
//! human readability and debuggability" (§3, Design Principles).
//!
//! Each pass records renames/moves here; `trace` resolves a transformed
//! name back to its original hierarchical path.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct NameMap {
    /// transformed name -> immediate predecessor name.
    parent: BTreeMap<String, String>,
    /// pass that introduced each transformed name.
    origin_pass: BTreeMap<String, String>,
}

impl NameMap {
    pub fn new() -> NameMap {
        NameMap::default()
    }

    /// Record that `new_name` was derived from `old_name` by `pass`.
    pub fn record(&mut self, pass: &str, old_name: &str, new_name: &str) {
        if old_name == new_name {
            return;
        }
        self.parent.insert(new_name.to_string(), old_name.to_string());
        self.origin_pass.insert(new_name.to_string(), pass.to_string());
    }

    /// Resolve a (possibly multiply-) transformed name to its original.
    ///
    /// A cyclic record set (possible when passes rename back and forth)
    /// has no true origin: the walk detects the revisit with a seen-set
    /// and stops at the cycle entry — the first name encountered twice —
    /// rather than returning an arbitrary mid-chain name after a bounded
    /// number of hops.
    pub fn trace(&self, name: &str) -> String {
        let mut seen = std::collections::BTreeSet::new();
        let mut cur = name;
        seen.insert(cur);
        while let Some(prev) = self.parent.get(cur) {
            if seen.contains(prev.as_str()) {
                return prev.clone(); // cycle entry
            }
            seen.insert(prev);
            cur = prev;
        }
        cur.to_string()
    }

    /// Full derivation chain, most recent first. On a cyclic record set
    /// the chain ends at the cycle entry (each name appears once).
    pub fn chain(&self, name: &str) -> Vec<(String, Option<String>)> {
        let mut out = vec![(name.to_string(), None)];
        let mut seen = std::collections::BTreeSet::new();
        seen.insert(name.to_string());
        let mut cur = name.to_string();
        while let Some(prev) = self.parent.get(&cur) {
            let pass = self.origin_pass.get(&cur).cloned();
            out.last_mut().unwrap().1 = pass;
            if !seen.insert(prev.clone()) {
                break;
            }
            out.push((prev.clone(), None));
            cur = prev.clone();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_resolves_chain() {
        let mut nm = NameMap::new();
        nm.record("rebuild", "LLM", "LLM_grouped");
        nm.record("partition", "LLM_Aux", "LLM_Aux_split0");
        nm.record("flatten", "LLM_Aux_split0", "LLM_Aux_split0_flat");
        assert_eq!(nm.trace("LLM_Aux_split0_flat"), "LLM_Aux");
        assert_eq!(nm.trace("LLM_grouped"), "LLM");
        assert_eq!(nm.trace("untouched"), "untouched");
    }

    #[test]
    fn chain_records_passes() {
        let mut nm = NameMap::new();
        nm.record("rebuild", "A", "B");
        nm.record("flatten", "B", "C");
        let chain = nm.chain("C");
        assert_eq!(chain.len(), 3);
        assert_eq!(chain[0].0, "C");
        assert_eq!(chain[0].1.as_deref(), Some("flatten"));
        assert_eq!(chain[2].0, "A");
    }

    #[test]
    fn identity_record_ignored() {
        let mut nm = NameMap::new();
        nm.record("p", "X", "X");
        assert!(nm.is_empty());
    }

    #[test]
    fn trace_terminates_on_cycle_at_entry() {
        // A pass renames A -> B, a later one renames B back to A: the
        // parent chain is cyclic and has no true origin.
        let mut nm = NameMap::new();
        nm.record("p1", "A", "B");
        nm.record("p2", "B", "A");
        // Entering from outside the cycle: C -> A -> B -> A stops at the
        // first revisited name (the cycle entry), not a mid-chain hop.
        nm.record("p0", "A", "C");
        assert_eq!(nm.trace("C"), "A");
        // Entering on the cycle itself terminates too.
        assert_eq!(nm.trace("A"), "A");
        assert_eq!(nm.trace("B"), "B");
    }

    #[test]
    fn chain_lists_each_name_once_on_cycle() {
        let mut nm = NameMap::new();
        nm.record("p1", "A", "B");
        nm.record("p2", "B", "A");
        let chain = nm.chain("A");
        assert_eq!(chain.len(), 2);
        assert_eq!(chain[0].0, "A");
        assert_eq!(chain[0].1.as_deref(), Some("p2"));
        assert_eq!(chain[1].0, "B");
        assert_eq!(chain[1].1.as_deref(), Some("p1"));
    }
}

//! Core data model of the RapidStream IR (§3.1 of the paper).
//!
//! A [`Design`] is a library of [`Module`]s plus a designated top module.
//! Modules are either **leaf** modules — atomic units whose native source
//! (Verilog, netlist, XCI manifest, …) is embedded verbatim — or **grouped**
//! modules — pure containers holding wires and submodule instances with *no
//! logic of their own*.
//!
//! Invariant assumptions maintained by every transformation pass:
//! 1. each wire in a grouped module connects exactly two endpoints;
//! 2. each submodule port connects to a single identifier or a constant
//!    (no concatenation / bit-select);
//! 3. non-constant ports of an interface are fully connected — interfaces
//!    are never split across modules.
//!
//! These are checked by [`crate::ir::validate`] (the "DRC" passes).

use crate::util::json::JsonObj;
use std::collections::BTreeMap;

/// Port direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    In,
    Out,
    InOut,
}

impl Dir {
    pub fn as_str(&self) -> &'static str {
        match self {
            Dir::In => "in",
            Dir::Out => "out",
            Dir::InOut => "inout",
        }
    }

    pub fn parse(s: &str) -> Option<Dir> {
        match s {
            "in" | "input" => Some(Dir::In),
            "out" | "output" => Some(Dir::Out),
            "inout" => Some(Dir::InOut),
            _ => None,
        }
    }

    pub fn flipped(&self) -> Dir {
        match self {
            Dir::In => Dir::Out,
            Dir::Out => Dir::In,
            Dir::InOut => Dir::InOut,
        }
    }
}

/// A module port: name, direction, bit width.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Port {
    pub name: String,
    pub dir: Dir,
    pub width: u32,
}

impl Port {
    pub fn new(name: impl Into<String>, dir: Dir, width: u32) -> Port {
        Port {
            name: name.into(),
            dir,
            width,
        }
    }
}

/// A named wire inside a grouped module.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Wire {
    pub name: String,
    pub width: u32,
}

/// What a submodule port connects to: a single identifier (a wire or a
/// parent-port name) or a constant (invariant 2 prohibits expressions).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConnExpr {
    /// A wire or parent-port identifier.
    Id(String),
    /// A literal constant, e.g. `8'd0` → width 8, value 0.
    Const { width: u32, value: u64 },
    /// Explicitly unconnected (dangling output).
    Open,
}

impl ConnExpr {
    pub fn id(s: impl Into<String>) -> ConnExpr {
        ConnExpr::Id(s.into())
    }

    pub fn as_id(&self) -> Option<&str> {
        match self {
            ConnExpr::Id(s) => Some(s),
            _ => None,
        }
    }
}

/// A port-to-expression binding on an instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Connection {
    pub port: String,
    pub value: ConnExpr,
}

/// An instantiation of a module inside a grouped module.
#[derive(Debug, Clone, PartialEq)]
pub struct Instance {
    pub instance_name: String,
    pub module_name: String,
    pub connections: Vec<Connection>,
    pub metadata: JsonObj,
}

impl Instance {
    pub fn new(instance_name: impl Into<String>, module_name: impl Into<String>) -> Instance {
        Instance {
            instance_name: instance_name.into(),
            module_name: module_name.into(),
            connections: Vec::new(),
            metadata: JsonObj::new(),
        }
    }

    pub fn connect(&mut self, port: impl Into<String>, value: ConnExpr) {
        self.connections.push(Connection {
            port: port.into(),
            value,
        });
    }

    pub fn connection(&self, port: &str) -> Option<&ConnExpr> {
        self.connections
            .iter()
            .find(|c| c.port == port)
            .map(|c| &c.value)
    }

    pub fn connection_mut(&mut self, port: &str) -> Option<&mut ConnExpr> {
        self.connections
            .iter_mut()
            .find(|c| c.port == port)
            .map(|c| &mut c.value)
    }
}

/// Native source format of a leaf module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SourceFormat {
    Verilog,
    Vhdl,
    Netlist,
    /// Xilinx Compiled IP manifest (JSON surrogate of an .xci).
    Xci,
    /// Vitis Xilinx Object container manifest.
    Xo,
    /// Interface-only stub: ports known, implementation opaque.
    Blackbox,
}

impl SourceFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            SourceFormat::Verilog => "verilog",
            SourceFormat::Vhdl => "vhdl",
            SourceFormat::Netlist => "netlist",
            SourceFormat::Xci => "xci",
            SourceFormat::Xo => "xo",
            SourceFormat::Blackbox => "blackbox",
        }
    }

    pub fn parse(s: &str) -> Option<SourceFormat> {
        match s {
            "verilog" => Some(SourceFormat::Verilog),
            "vhdl" => Some(SourceFormat::Vhdl),
            "netlist" => Some(SourceFormat::Netlist),
            "xci" => Some(SourceFormat::Xci),
            "xo" => Some(SourceFormat::Xo),
            "blackbox" => Some(SourceFormat::Blackbox),
            _ => None,
        }
    }
}

/// Body of a module: leaf (native source kept verbatim) or grouped
/// (pure container of wires + instances).
#[derive(Debug, Clone, PartialEq)]
pub enum Body {
    Leaf {
        format: SourceFormat,
        /// Original source text / manifest, embedded to preserve integrity.
        source: String,
    },
    Grouped {
        wires: Vec<Wire>,
        instances: Vec<Instance>,
    },
}

/// A pipeline strategy applicable to a set of ports (§3.1 "Interface").
///
/// * `Handshake` — valid/ready/data; pipelined with a relay station or an
///   almost-full FIFO (Fig 6 right).
/// * `Feedforward` — scalar signals pipelined by inserting flip-flops
///   (Fig 6 left).
/// * `Clock` / `Reset` — broadcast nets, excluded from connectivity
///   analysis and never pipelined.
/// * `NonPipeline` — explicitly latency-sensitive ports; modules joined by
///   these must be grouped into the same partition.
#[derive(Debug, Clone, PartialEq)]
pub enum Interface {
    Handshake {
        /// Bundle name, e.g. "I" or "m_axi_AW".
        name: String,
        data: Vec<String>,
        valid: String,
        ready: String,
        /// Associated clock port, if known.
        clk: Option<String>,
    },
    Feedforward {
        name: String,
        ports: Vec<String>,
    },
    Clock {
        port: String,
    },
    Reset {
        port: String,
        active_high: bool,
    },
    NonPipeline {
        name: String,
        ports: Vec<String>,
    },
}

impl Interface {
    /// All ports covered by this interface (including valid/ready, and the
    /// clock only for `Clock` itself).
    pub fn ports(&self) -> Vec<&str> {
        match self {
            Interface::Handshake {
                data, valid, ready, ..
            } => {
                let mut v: Vec<&str> = data.iter().map(|s| s.as_str()).collect();
                v.push(valid);
                v.push(ready);
                v
            }
            Interface::Feedforward { ports, .. } | Interface::NonPipeline { ports, .. } => {
                ports.iter().map(|s| s.as_str()).collect()
            }
            Interface::Clock { port } | Interface::Reset { port, .. } => vec![port.as_str()],
        }
    }

    pub fn name(&self) -> &str {
        match self {
            Interface::Handshake { name, .. }
            | Interface::Feedforward { name, .. }
            | Interface::NonPipeline { name, .. } => name,
            Interface::Clock { port } | Interface::Reset { port, .. } => port,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Interface::Handshake { .. } => "handshake",
            Interface::Feedforward { .. } => "feedforward",
            Interface::Clock { .. } => "clock",
            Interface::Reset { .. } => "reset",
            Interface::NonPipeline { .. } => "nonpipeline",
        }
    }

    /// Whether pipeline stages may be inserted on this interface.
    pub fn pipelinable(&self) -> bool {
        matches!(
            self,
            Interface::Handshake { .. } | Interface::Feedforward { .. }
        )
    }
}

/// FPGA resource vector. Fractions of a unit are allowed because synthesis
/// estimation distributes shared logic across submodules.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    pub lut: f64,
    pub ff: f64,
    pub bram: f64,
    pub dsp: f64,
    pub uram: f64,
}

impl Resources {
    pub const ZERO: Resources = Resources {
        lut: 0.0,
        ff: 0.0,
        bram: 0.0,
        dsp: 0.0,
        uram: 0.0,
    };

    pub fn new(lut: f64, ff: f64, bram: f64, dsp: f64, uram: f64) -> Resources {
        Resources {
            lut,
            ff,
            bram,
            dsp,
            uram,
        }
    }

    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
            dsp: self.dsp + o.dsp,
            uram: self.uram + o.uram,
        }
    }

    pub fn scale(&self, k: f64) -> Resources {
        Resources {
            lut: self.lut * k,
            ff: self.ff * k,
            bram: self.bram * k,
            dsp: self.dsp * k,
            uram: self.uram * k,
        }
    }

    /// Max over all kinds of `self[kind] / cap[kind]` — the utilization
    /// ratio of the binding resource.
    pub fn max_util(&self, cap: &Resources) -> f64 {
        let r = |x: f64, c: f64| if c > 0.0 { x / c } else { 0.0 };
        r(self.lut, cap.lut)
            .max(r(self.ff, cap.ff))
            .max(r(self.bram, cap.bram))
            .max(r(self.dsp, cap.dsp))
            .max(r(self.uram, cap.uram))
    }

    pub fn fits(&self, cap: &Resources, limit: f64) -> bool {
        self.max_util(cap) <= limit
    }

    pub fn kinds() -> [&'static str; 5] {
        ["LUT", "FF", "BRAM", "DSP", "URAM"]
    }

    pub fn get(&self, kind: &str) -> f64 {
        match kind {
            "LUT" => self.lut,
            "FF" => self.ff,
            "BRAM" => self.bram,
            "DSP" => self.dsp,
            "URAM" => self.uram,
            _ => 0.0,
        }
    }
}

/// A design module.
#[derive(Debug, Clone, PartialEq)]
pub struct Module {
    pub name: String,
    pub ports: Vec<Port>,
    pub body: Body,
    pub interfaces: Vec<Interface>,
    /// Free-form metadata: `resource`, `floorplan`, `timing`, pass
    /// bookkeeping — anything an analysis pass wants to attach (§3.1
    /// "Additional Metadata").
    pub metadata: JsonObj,
}

impl Module {
    pub fn leaf(name: impl Into<String>, format: SourceFormat, source: impl Into<String>) -> Module {
        Module {
            name: name.into(),
            ports: Vec::new(),
            body: Body::Leaf {
                format,
                source: source.into(),
            },
            interfaces: Vec::new(),
            metadata: JsonObj::new(),
        }
    }

    pub fn grouped(name: impl Into<String>) -> Module {
        Module {
            name: name.into(),
            ports: Vec::new(),
            body: Body::Grouped {
                wires: Vec::new(),
                instances: Vec::new(),
            },
            interfaces: Vec::new(),
            metadata: JsonObj::new(),
        }
    }

    pub fn is_leaf(&self) -> bool {
        matches!(self.body, Body::Leaf { .. })
    }

    pub fn is_grouped(&self) -> bool {
        matches!(self.body, Body::Grouped { .. })
    }

    pub fn port(&self, name: &str) -> Option<&Port> {
        self.ports.iter().find(|p| p.name == name)
    }

    pub fn wires(&self) -> &[Wire] {
        match &self.body {
            Body::Grouped { wires, .. } => wires,
            _ => &[],
        }
    }

    pub fn instances(&self) -> &[Instance] {
        match &self.body {
            Body::Grouped { instances, .. } => instances,
            _ => &[],
        }
    }

    pub fn wires_mut(&mut self) -> &mut Vec<Wire> {
        match &mut self.body {
            Body::Grouped { wires, .. } => wires,
            _ => panic!("wires_mut on leaf module {}", self.name),
        }
    }

    pub fn instances_mut(&mut self) -> &mut Vec<Instance> {
        match &mut self.body {
            Body::Grouped { instances, .. } => instances,
            _ => panic!("instances_mut on leaf module {}", self.name),
        }
    }

    pub fn instance(&self, name: &str) -> Option<&Instance> {
        self.instances().iter().find(|i| i.instance_name == name)
    }

    /// The interface covering `port`, if any.
    pub fn interface_of(&self, port: &str) -> Option<&Interface> {
        self.interfaces
            .iter()
            .find(|i| i.ports().contains(&port))
    }

    /// Ports not covered by any interface.
    pub fn uncovered_ports(&self) -> Vec<&Port> {
        self.ports
            .iter()
            .filter(|p| self.interface_of(&p.name).is_none())
            .collect()
    }
}

/// The whole IR: a module library with a designated top.
#[derive(Debug, Clone, PartialEq)]
pub struct Design {
    pub top: String,
    pub modules: BTreeMap<String, Module>,
    pub metadata: JsonObj,
}

impl Design {
    pub fn new(top: impl Into<String>) -> Design {
        Design {
            top: top.into(),
            modules: BTreeMap::new(),
            metadata: JsonObj::new(),
        }
    }

    pub fn add(&mut self, module: Module) {
        self.modules.insert(module.name.clone(), module);
    }

    pub fn module(&self, name: &str) -> Option<&Module> {
        self.modules.get(name)
    }

    pub fn module_mut(&mut self, name: &str) -> Option<&mut Module> {
        self.modules.get_mut(name)
    }

    pub fn top_module(&self) -> &Module {
        self.modules
            .get(&self.top)
            .unwrap_or_else(|| panic!("top module '{}' not in design", self.top))
    }

    /// Generate a module name not already present, based on `base`.
    pub fn fresh_module_name(&self, base: &str) -> String {
        if !self.modules.contains_key(base) {
            return base.to_string();
        }
        for i in 1.. {
            let cand = format!("{base}_{i}");
            if !self.modules.contains_key(&cand) {
                return cand;
            }
        }
        unreachable!()
    }

    /// Remove modules unreachable from the top (after passthrough/flatten).
    pub fn gc(&mut self) {
        let mut live = std::collections::BTreeSet::new();
        let mut stack = vec![self.top.clone()];
        while let Some(name) = stack.pop() {
            if !live.insert(name.clone()) {
                continue;
            }
            if let Some(m) = self.modules.get(&name) {
                for inst in m.instances() {
                    stack.push(inst.module_name.clone());
                }
            }
        }
        self.modules.retain(|name, _| live.contains(name));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fifo_module() -> Module {
        let mut m = Module::leaf("FIFO", SourceFormat::Verilog, "module FIFO(); endmodule");
        m.ports = vec![
            Port::new("I", Dir::In, 64),
            Port::new("I_vld", Dir::In, 1),
            Port::new("I_rdy", Dir::Out, 1),
            Port::new("ap_clk", Dir::In, 1),
        ];
        m.interfaces = vec![
            Interface::Handshake {
                name: "I".into(),
                data: vec!["I".into()],
                valid: "I_vld".into(),
                ready: "I_rdy".into(),
                clk: Some("ap_clk".into()),
            },
            Interface::Clock {
                port: "ap_clk".into(),
            },
        ];
        m
    }

    #[test]
    fn interface_port_coverage() {
        let m = fifo_module();
        assert_eq!(m.interface_of("I_vld").unwrap().kind(), "handshake");
        // clk is an associated port, not a handshake member: Clock covers it.
        assert_eq!(m.interface_of("ap_clk").unwrap().kind(), "clock");
    }

    #[test]
    fn interface_ports_listing() {
        let m = fifo_module();
        let hs = &m.interfaces[0];
        let mut ps = hs.ports();
        ps.sort();
        assert_eq!(ps, vec!["I", "I_rdy", "I_vld"]);
        assert!(hs.pipelinable());
        assert!(!m.interfaces[1].pipelinable());
    }

    #[test]
    fn uncovered_ports_empty_when_fully_covered() {
        let m = fifo_module();
        assert!(m.uncovered_ports().is_empty());
    }

    #[test]
    fn design_gc_removes_unreachable() {
        let mut d = Design::new("Top");
        let mut top = Module::grouped("Top");
        let mut inst = Instance::new("a", "A");
        inst.connect("x", ConnExpr::id("w"));
        top.instances_mut().push(inst);
        d.add(top);
        d.add(Module::leaf("A", SourceFormat::Verilog, ""));
        d.add(Module::leaf("Orphan", SourceFormat::Verilog, ""));
        d.gc();
        assert!(d.module("A").is_some());
        assert!(d.module("Orphan").is_none());
    }

    #[test]
    fn fresh_module_name_avoids_collisions() {
        let mut d = Design::new("T");
        d.add(Module::grouped("T"));
        d.add(Module::grouped("T_1"));
        assert_eq!(d.fresh_module_name("T"), "T_2");
        assert_eq!(d.fresh_module_name("X"), "X");
    }

    #[test]
    fn resources_arithmetic() {
        let a = Resources::new(100.0, 200.0, 4.0, 8.0, 0.0);
        let cap = Resources::new(1000.0, 2000.0, 10.0, 10.0, 10.0);
        assert!((a.max_util(&cap) - 0.8).abs() < 1e-9);
        assert!(a.fits(&cap, 0.8));
        assert!(!a.fits(&cap, 0.7));
        let s = a.add(&a).scale(0.5);
        assert_eq!(s, a);
    }

    #[test]
    fn dir_roundtrip() {
        for d in [Dir::In, Dir::Out, Dir::InOut] {
            assert_eq!(Dir::parse(d.as_str()), Some(d));
        }
        assert_eq!(Dir::parse("input"), Some(Dir::In));
        assert_eq!(Dir::In.flipped(), Dir::Out);
    }
}

//! String interning for the IR core: a [`Symbol`] is a `u32` key into an
//! append-only string table, so hot paths compare and hash identifiers as
//! integers instead of re-hashing `String`s, and the connectivity caches
//! of [`crate::ir::index`] store nets and endpoints without cloning names.
//!
//! Symbols are assigned in first-intern order and stay valid for the
//! lifetime of their [`Interner`]. They are **not** ordered like the
//! strings they name — resolve before comparing lexicographically.

use std::collections::HashMap;

/// Interned string key: a `u32` index into the owning [`Interner`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Symbol(u32);

impl Symbol {
    /// The raw table index — usable as a dense array key.
    pub fn as_usize(self) -> usize {
        self.0 as usize
    }
}

/// Append-only string table. [`Interner::intern`] is idempotent: the same
/// string always yields the same [`Symbol`].
#[derive(Debug, Clone, Default)]
pub struct Interner {
    strings: Vec<String>,
    map: HashMap<String, Symbol>,
}

impl Interner {
    pub fn new() -> Interner {
        Interner::default()
    }

    /// Intern `s`, assigning a fresh [`Symbol`] on first sight.
    pub fn intern(&mut self, s: &str) -> Symbol {
        if let Some(&sym) = self.map.get(s) {
            return sym;
        }
        let sym = Symbol(self.strings.len() as u32);
        self.strings.push(s.to_string());
        self.map.insert(s.to_string(), sym);
        sym
    }

    /// Look a string up without interning it.
    pub fn get(&self, s: &str) -> Option<Symbol> {
        self.map.get(s).copied()
    }

    /// The string behind a symbol. Panics on a symbol minted by a
    /// different interner (index out of range).
    pub fn resolve(&self, sym: Symbol) -> &str {
        &self.strings[sym.as_usize()]
    }

    pub fn len(&self) -> usize {
        self.strings.len()
    }

    pub fn is_empty(&self) -> bool {
        self.strings.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut i = Interner::new();
        let a = i.intern("ap_clk");
        let b = i.intern("ap_clk");
        assert_eq!(a, b);
        assert_eq!(i.len(), 1);
    }

    #[test]
    fn distinct_strings_get_distinct_symbols() {
        let mut i = Interner::new();
        let a = i.intern("a");
        let b = i.intern("b");
        assert_ne!(a, b);
        assert_eq!(i.resolve(a), "a");
        assert_eq!(i.resolve(b), "b");
    }

    #[test]
    fn get_does_not_intern() {
        let mut i = Interner::new();
        assert!(i.get("x").is_none());
        let s = i.intern("x");
        assert_eq!(i.get("x"), Some(s));
        assert_eq!(i.len(), 1);
    }

    #[test]
    fn symbols_are_dense_indices() {
        let mut i = Interner::new();
        let a = i.intern("first");
        let b = i.intern("second");
        assert_eq!(a.as_usize(), 0);
        assert_eq!(b.as_usize(), 1);
    }
}

//! Fluent programmatic construction of IR designs.
//!
//! Used by the benchmark design generators (`designs/`), by tests, and by
//! users scripting design composition — the "write tools to modify the IR"
//! path of Figure 5.

use crate::ir::core::*;

/// Builder for a grouped module.
pub struct GroupedBuilder {
    module: Module,
}

impl GroupedBuilder {
    pub fn new(name: impl Into<String>) -> GroupedBuilder {
        GroupedBuilder {
            module: Module::grouped(name),
        }
    }

    pub fn port(mut self, name: &str, dir: Dir, width: u32) -> Self {
        self.module.ports.push(Port::new(name, dir, width));
        self
    }

    pub fn wire(mut self, name: &str, width: u32) -> Self {
        self.module.wires_mut().push(Wire {
            name: name.into(),
            width,
        });
        self
    }

    /// Declare an instance with `(port, identifier)` bindings.
    pub fn inst(mut self, inst_name: &str, module_name: &str, conns: &[(&str, &str)]) -> Self {
        let mut i = Instance::new(inst_name, module_name);
        for (p, v) in conns {
            i.connect(*p, ConnExpr::id(*v));
        }
        self.module.instances_mut().push(i);
        self
    }

    pub fn inst_full(mut self, inst: Instance) -> Self {
        self.module.instances_mut().push(inst);
        self
    }

    pub fn iface(mut self, iface: Interface) -> Self {
        self.module.interfaces.push(iface);
        self
    }

    pub fn meta(mut self, key: &str, value: crate::util::json::Json) -> Self {
        self.module.metadata.insert(key, value);
        self
    }

    pub fn build(self) -> Module {
        self.module
    }
}

/// Builder for a leaf module.
pub struct LeafBuilder {
    module: Module,
}

impl LeafBuilder {
    pub fn new(name: impl Into<String>, format: SourceFormat, source: impl Into<String>) -> Self {
        LeafBuilder {
            module: Module::leaf(name, format, source),
        }
    }

    /// Verilog leaf with auto-generated stub source matching the ports.
    pub fn verilog_stub(name: impl Into<String>) -> Self {
        LeafBuilder {
            module: Module::leaf(name, SourceFormat::Verilog, String::new()),
        }
    }

    pub fn port(mut self, name: &str, dir: Dir, width: u32) -> Self {
        self.module.ports.push(Port::new(name, dir, width));
        self
    }

    /// Add a handshake bundle `<name>`, `<name>_vld`, `<name>_rdy`
    /// (HLS-style naming) and the matching interface in one call.
    pub fn handshake(mut self, name: &str, dir: Dir, width: u32) -> Self {
        let (vld_dir, rdy_dir) = (dir, dir.flipped());
        self.module.ports.push(Port::new(name, dir, width));
        self.module
            .ports
            .push(Port::new(format!("{name}_vld"), vld_dir, 1));
        self.module
            .ports
            .push(Port::new(format!("{name}_rdy"), rdy_dir, 1));
        self.module.interfaces.push(Interface::Handshake {
            name: name.into(),
            data: vec![name.into()],
            valid: format!("{name}_vld"),
            ready: format!("{name}_rdy"),
            clk: Some("ap_clk".into()),
        });
        self
    }

    /// Add the standard ap_clk/ap_rst_n pair with interfaces.
    pub fn clk_rst(mut self) -> Self {
        self.module.ports.push(Port::new("ap_clk", Dir::In, 1));
        self.module.ports.push(Port::new("ap_rst_n", Dir::In, 1));
        self.module.interfaces.push(Interface::Clock {
            port: "ap_clk".into(),
        });
        self.module.interfaces.push(Interface::Reset {
            port: "ap_rst_n".into(),
            active_high: false,
        });
        self
    }

    pub fn iface(mut self, iface: Interface) -> Self {
        self.module.interfaces.push(iface);
        self
    }

    /// Attach a resource estimate in metadata (`resource: {LUT, FF, ...}`).
    pub fn resource(mut self, r: Resources) -> Self {
        self.module
            .metadata
            .insert("resource", resources_to_json(&r));
        self
    }

    pub fn meta(mut self, key: &str, value: crate::util::json::Json) -> Self {
        self.module.metadata.insert(key, value);
        self
    }

    pub fn build(mut self) -> Module {
        // Fill in a Verilog stub body if source is empty.
        if let Body::Leaf { format, source } = &mut self.module.body {
            if *format == SourceFormat::Verilog && source.is_empty() {
                *source = stub_verilog(&self.module.name, &self.module.ports);
            }
        }
        self.module
    }
}

/// Generate a synthesizable Verilog stub for a module signature.
pub fn stub_verilog(name: &str, ports: &[Port]) -> String {
    let mut s = format!("module {name} (\n");
    for (i, p) in ports.iter().enumerate() {
        let dir = match p.dir {
            Dir::In => "input  wire",
            Dir::Out => "output wire",
            Dir::InOut => "inout  wire",
        };
        let range = if p.width > 1 {
            format!("[{}:0] ", p.width - 1)
        } else {
            String::new()
        };
        let comma = if i + 1 < ports.len() { "," } else { "" };
        s.push_str(&format!("  {dir} {range}{}{comma}\n", p.name));
    }
    s.push_str(");\nendmodule\n");
    s
}

/// Serialize a [`Resources`] vector to the metadata JSON shape used in
/// Figure 8 (`{FF: 10, LUT: 39, DSP: 0, BRAM: 0, URAM: 0}`).
pub fn resources_to_json(r: &Resources) -> crate::util::json::Json {
    use crate::util::json::{Json, JsonObj};
    let mut o = JsonObj::new();
    o.insert("LUT", Json::num(r.lut));
    o.insert("FF", Json::num(r.ff));
    o.insert("BRAM", Json::num(r.bram));
    o.insert("DSP", Json::num(r.dsp));
    o.insert("URAM", Json::num(r.uram));
    Json::Obj(o)
}

/// Read a [`Resources`] vector back from metadata.
pub fn resources_from_json(j: &crate::util::json::Json) -> Resources {
    let g = |k: &str| j.at(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    Resources {
        lut: g("LUT"),
        ff: g("FF"),
        bram: g("BRAM"),
        dsp: g("DSP"),
        uram: g("URAM"),
    }
}

/// Convenience: resource metadata of a module, if present.
pub fn module_resources(m: &Module) -> Option<Resources> {
    m.metadata.get("resource").map(resources_from_json)
}

/// Set resource metadata on a module.
pub fn set_module_resources(m: &mut Module, r: Resources) {
    m.metadata.insert("resource", resources_to_json(&r));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::validate;

    #[test]
    fn build_clean_two_module_design() {
        let a = LeafBuilder::verilog_stub("A")
            .clk_rst()
            .handshake("o", Dir::Out, 32)
            .resource(Resources::new(100.0, 50.0, 0.0, 0.0, 0.0))
            .build();
        let b = LeafBuilder::verilog_stub("B")
            .clk_rst()
            .handshake("i", Dir::In, 32)
            .build();
        let top = GroupedBuilder::new("Top")
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .iface(Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            })
            .wire("d", 32)
            .wire("d_vld", 1)
            .wire("d_rdy", 1)
            .inst(
                "a0",
                "A",
                &[
                    ("o", "d"),
                    ("o_vld", "d_vld"),
                    ("o_rdy", "d_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .inst(
                "b0",
                "B",
                &[
                    ("i", "d"),
                    ("i_vld", "d_vld"),
                    ("i_rdy", "d_rdy"),
                    ("ap_clk", "ap_clk"),
                    ("ap_rst_n", "ap_rst_n"),
                ],
            )
            .build();
        let mut d = Design::new("Top");
        d.add(a);
        d.add(b);
        d.add(top);
        validate::assert_clean(&d);
    }

    #[test]
    fn stub_verilog_shape() {
        let s = stub_verilog(
            "M",
            &[Port::new("a", Dir::In, 8), Port::new("b", Dir::Out, 1)],
        );
        assert!(s.contains("module M ("));
        assert!(s.contains("input  wire [7:0] a,"));
        assert!(s.contains("output wire b\n"));
        assert!(s.ends_with("endmodule\n"));
    }

    #[test]
    fn resources_json_roundtrip() {
        let r = Resources::new(1.0, 2.0, 3.0, 4.0, 5.0);
        assert_eq!(resources_from_json(&resources_to_json(&r)), r);
    }

    #[test]
    fn handshake_builder_creates_bundle() {
        let m = LeafBuilder::verilog_stub("X").handshake("s", Dir::In, 64).build();
        assert!(m.port("s").is_some());
        assert!(m.port("s_vld").is_some());
        assert_eq!(m.port("s_rdy").unwrap().dir, Dir::Out);
        assert_eq!(m.interfaces.len(), 1);
    }
}

//! Block-graph view of a grouped module: wire endpoints, connectivity
//! queries, and the inter-instance edge list used by partitioning,
//! floorplanning, and pipeline insertion.
//!
//! Since the introduction of [`crate::ir::index`], `BlockGraph` is a thin
//! string-keyed *compatibility view* derived from the ID-based
//! [`ModuleConn`](crate::ir::index::ModuleConn): hot paths query the
//! cached index instead of rebuilding this structure per pass.

use crate::ir::core::*;
use crate::ir::index::ModuleConn;
use crate::ir::intern::Interner;
use std::collections::BTreeMap;
use std::fmt;

/// Typed failure of connectivity extraction ([`BlockGraph::try_build`],
/// [`crate::ir::index::DesignIndex::conn`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    /// Connectivity was requested on a leaf module (it has no wires or
    /// instances — only grouped modules have a block graph).
    Leaf { module: String },
    /// The named module is not in the design.
    Missing { module: String },
}

impl fmt::Display for GraphError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphError::Leaf { module } => {
                write!(f, "connectivity requested on leaf module '{module}'")
            }
            GraphError::Missing { module } => {
                write!(f, "module '{module}' not found in design")
            }
        }
    }
}

impl std::error::Error for GraphError {}

/// One endpoint of a wire inside a grouped module.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    /// A port on the grouped module itself (seen from inside).
    Parent { port: String },
    /// A port on instance `inst`.
    Inst { inst: String, port: String },
}

impl Endpoint {
    pub fn describe(&self) -> String {
        match self {
            Endpoint::Parent { port } => format!("<parent>.{port}"),
            Endpoint::Inst { inst, port } => format!("{inst}.{port}"),
        }
    }
}

/// Connectivity of one identifier (wire or parent-port name).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetInfo {
    pub endpoints: Vec<Endpoint>,
    pub width: u32,
}

/// The resolved connectivity of a grouped module (string-keyed
/// compatibility view over [`ModuleConn`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockGraph {
    /// identifier -> endpoints. Identifiers are wire names or parent ports.
    pub nets: BTreeMap<String, NetInfo>,
    /// instance names in declaration order.
    pub instances: Vec<String>,
}

impl BlockGraph {
    /// Build the graph for grouped module `m`; a leaf module yields a
    /// typed [`GraphError`] instead of a panic.
    pub fn try_build(m: &Module) -> Result<BlockGraph, GraphError> {
        let mut interner = Interner::new();
        let conn = ModuleConn::build(m, &mut interner)?;
        Ok(conn.to_block_graph(&interner))
    }

    /// Build the graph for grouped module `m` (panics on leaf modules —
    /// prefer [`BlockGraph::try_build`] in pass code).
    pub fn build(m: &Module) -> BlockGraph {
        Self::try_build(m).unwrap_or_else(|e| panic!("BlockGraph::build: {e}"))
    }

    /// The other endpoint of a 2-endpoint net, given one side.
    pub fn opposite(&self, net: &str, this: &Endpoint) -> Option<&Endpoint> {
        let info = self.nets.get(net)?;
        if info.endpoints.len() != 2 {
            return None;
        }
        info.endpoints.iter().find(|e| *e != this)
    }

    /// Inter-instance edges: (inst_a, inst_b, total bit width) aggregated
    /// over all nets joining the pair. Parent-port nets are excluded.
    /// Clock/reset nets can be excluded by passing their identifiers.
    pub fn instance_edges(&self, exclude_nets: &[String]) -> Vec<(String, String, u64)> {
        let mut acc: BTreeMap<(String, String), u64> = BTreeMap::new();
        for (name, info) in &self.nets {
            if exclude_nets.iter().any(|x| x == name) {
                continue;
            }
            let insts: Vec<&str> = info
                .endpoints
                .iter()
                .filter_map(|e| match e {
                    Endpoint::Inst { inst, .. } => Some(inst.as_str()),
                    _ => None,
                })
                .collect();
            if insts.len() == 2 && insts[0] != insts[1] {
                let (a, b) = if insts[0] < insts[1] {
                    (insts[0], insts[1])
                } else {
                    (insts[1], insts[0])
                };
                *acc.entry((a.to_string(), b.to_string())).or_default() += info.width as u64;
            }
        }
        acc.into_iter().map(|((a, b), w)| (a, b, w)).collect()
    }

    /// Nets whose endpoints include instance `inst`.
    pub fn nets_of_instance<'a>(&'a self, inst: &str) -> Vec<&'a str> {
        self.nets
            .iter()
            .filter(|(_, info)| {
                info.endpoints.iter().any(|e| matches!(e, Endpoint::Inst { inst: i, .. } if i == inst))
            })
            .map(|(n, _)| n.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::core::*;

    /// Top with two instances A, B joined by wire `w` (64b), A also tied to
    /// parent port `in_data`.
    fn sample() -> Module {
        let mut m = Module::grouped("Top");
        m.ports = vec![Port::new("in_data", Dir::In, 32)];
        m.wires_mut().push(Wire {
            name: "w".into(),
            width: 64,
        });
        let mut a = Instance::new("a", "A");
        a.connect("o", ConnExpr::id("w"));
        a.connect("i", ConnExpr::id("in_data"));
        let mut b = Instance::new("b", "B");
        b.connect("i", ConnExpr::id("w"));
        m.instances_mut().push(a);
        m.instances_mut().push(b);
        m
    }

    #[test]
    fn nets_resolve_endpoints() {
        let g = BlockGraph::build(&sample());
        assert_eq!(g.nets["w"].endpoints.len(), 2);
        assert_eq!(g.nets["in_data"].endpoints.len(), 2);
        assert_eq!(g.nets["w"].width, 64);
    }

    #[test]
    fn opposite_endpoint() {
        let g = BlockGraph::build(&sample());
        let from = Endpoint::Inst {
            inst: "a".into(),
            port: "o".into(),
        };
        let opp = g.opposite("w", &from).unwrap();
        assert_eq!(
            *opp,
            Endpoint::Inst {
                inst: "b".into(),
                port: "i".into()
            }
        );
    }

    #[test]
    fn instance_edges_aggregate_width() {
        let mut m = sample();
        // Add a second 8-bit wire between a and b.
        m.wires_mut().push(Wire {
            name: "w2".into(),
            width: 8,
        });
        m.instances_mut()[0].connect("o2", ConnExpr::id("w2"));
        m.instances_mut()[1].connect("i2", ConnExpr::id("w2"));
        let g = BlockGraph::build(&m);
        let edges = g.instance_edges(&[]);
        assert_eq!(edges, vec![("a".to_string(), "b".to_string(), 72)]);
    }

    #[test]
    fn excluded_nets_skipped() {
        let g = BlockGraph::build(&sample());
        let edges = g.instance_edges(&["w".to_string()]);
        assert!(edges.is_empty());
    }

    #[test]
    fn nets_of_instance_lists_all() {
        let g = BlockGraph::build(&sample());
        let mut nets = g.nets_of_instance("a");
        nets.sort();
        assert_eq!(nets, vec!["in_data", "w"]);
    }

    #[test]
    fn try_build_rejects_leaf_with_typed_error() {
        let leaf = Module::leaf("L", SourceFormat::Verilog, "");
        let err = BlockGraph::try_build(&leaf).unwrap_err();
        assert!(matches!(&err, GraphError::Leaf { module } if module == "L"));
        assert!(err.to_string().contains("leaf module 'L'"));
    }
}

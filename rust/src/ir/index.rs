//! Indexed, cached connectivity over a [`Design`] — the ID-based layer
//! that replaces per-pass string-keyed [`BlockGraph`] rebuilds.
//!
//! A [`DesignIndex`] assigns stable IDs ([`ModuleId`], [`InstId`],
//! [`NetId`], [`PortId`]) and memoizes one [`ModuleConn`] — the resolved
//! net/endpoint table of a grouped module — per module, so repeated
//! connectivity queries (DRC after every pass, interface-inference
//! fixpoints, channel discovery) are table lookups instead of whole-module
//! rebuilds. It also caches the inverse instance→parent map
//! ([`DesignIndex::parents`]).
//!
//! ## ID stability
//!
//! * A [`ModuleId`], once assigned to a name, keeps that name for the
//!   lifetime of the index; re-registering the same name returns the same
//!   id (a module replaced under its old name keeps its id, with the
//!   cache dirtied). Ids are never recycled.
//! * [`InstId`] / [`PortId`] are declaration indices *within* one
//!   [`ModuleConn`] snapshot; [`NetId`] is the net's position in the
//!   name-sorted net table. They are stable as long as the module is not
//!   edited.
//! * Two indexes populated over equal designs in the same order assign
//!   equal ids ([`DesignIndex::for_design`] registers in module-name
//!   order), which keeps every downstream result deterministic.
//!
//! ## Cache invalidation
//!
//! The design stays the source of truth; the index only caches derived
//! connectivity. Mutations must be announced:
//!
//! * [`DesignIndex::edit`] — the sanctioned way to mutate a module's
//!   wires, instances or connections: marks that module's cache dirty and
//!   hands out the `&mut Module`.
//! * [`DesignIndex::touch`] — after adding, replacing or removing a
//!   module outside `edit`.
//! * [`DesignIndex::invalidate_all`] — the pass pipeline calls this after
//!   any pass that does not track its own mutations (see
//!   `passes::manager::IndexPolicy`).
//!
//! Interface and metadata edits do not feed the connectivity tables and
//! need no invalidation. In debug builds every cache hit is cross-checked
//! against a fresh build and panics on divergence, so a pass that forgets
//! to invalidate fails loudly under `cargo test` instead of silently
//! serving stale nets.
//!
//! ```
//! use rsir::ir::core::{ConnExpr, Design, Dir, Instance, Module, Port, SourceFormat};
//! use rsir::ir::index::DesignIndex;
//!
//! let mut d = Design::new("Top");
//! d.add(Module::leaf("A", SourceFormat::Verilog, ""));
//! let mut top = Module::grouped("Top");
//! top.ports = vec![Port::new("x", Dir::In, 8)];
//! let mut a = Instance::new("a0", "A");
//! a.connect("i", ConnExpr::id("x"));
//! top.instances_mut().push(a);
//! d.add(top);
//!
//! let mut index = DesignIndex::for_design(&d);
//! let (conn, interner) = index.conn(&d, "Top").unwrap();
//! assert_eq!(conn.nets.len(), 1); // the identifier "x"
//! assert_eq!(conn.nets[0].endpoints.len(), 2); // parent port + a0.i
//! assert_eq!(interner.resolve(conn.insts[0].module), "A");
//! // The second query is a cached table lookup, not a rebuild.
//! let _ = index.conn(&d, "Top").unwrap();
//! assert_eq!(index.cache_stats(), (1, 1)); // one hit, one miss
//! ```

use crate::ir::core::{ConnExpr, Design, Module};
use crate::ir::graph::{BlockGraph, Endpoint, GraphError, NetInfo};
use crate::ir::intern::{Interner, Symbol};
use std::collections::BTreeMap;

/// Stable id of a module name within one [`DesignIndex`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ModuleId(pub u32);

/// Declaration index of an instance within its grouped module.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InstId(pub u32);

/// Position of a net in a module's name-sorted net table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NetId(pub u32);

/// Declaration index of a port within its module.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PortId(pub u32);

impl ModuleId {
    pub fn as_usize(self) -> usize {
        self.0 as usize
    }
}

impl InstId {
    pub fn as_usize(self) -> usize {
        self.0 as usize
    }
}

impl NetId {
    pub fn as_usize(self) -> usize {
        self.0 as usize
    }
}

impl PortId {
    pub fn as_usize(self) -> usize {
        self.0 as usize
    }
}

/// One endpoint of a net, in ID form (compare [`Endpoint`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnEndpoint {
    /// A port on the grouped module itself (seen from inside).
    Parent { port: PortId },
    /// Port `port` on the instance with declaration index `inst`.
    Inst { inst: InstId, port: Symbol },
}

/// One net: an identifier (wire or parent-port name) with its endpoints.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetConn {
    pub name: Symbol,
    pub width: u32,
    pub endpoints: Vec<ConnEndpoint>,
}

/// One instance: declaration-ordered name + instantiated module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InstConn {
    pub name: Symbol,
    pub module: Symbol,
}

/// One port of the grouped module, in declaration order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PortConn {
    pub name: Symbol,
    pub width: u32,
}

/// The resolved connectivity of one grouped module, ID-based: the same
/// information as [`BlockGraph`] (which is now a view over this), but
/// with interned names and dense indices.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModuleConn {
    /// The grouped module's own name.
    pub module: Symbol,
    /// Nets sorted by identifier string ([`NetId`] = position).
    pub nets: Vec<NetConn>,
    /// Ports in declaration order ([`PortId`] = position).
    pub ports: Vec<PortConn>,
    /// Instances in declaration order ([`InstId`] = position).
    pub insts: Vec<InstConn>,
}

impl ModuleConn {
    /// Extract the connectivity of grouped module `m`, interning every
    /// identifier. Mirrors the historical `BlockGraph::build` exactly:
    /// wires seed widths, ports overwrite widths and add parent
    /// endpoints, instance connections append endpoints in declaration
    /// order, and nets come out sorted by name.
    pub fn build(m: &Module, interner: &mut Interner) -> Result<ModuleConn, GraphError> {
        if !m.is_grouped() {
            return Err(GraphError::Leaf {
                module: m.name.clone(),
            });
        }
        let mut acc: BTreeMap<&str, (u32, Vec<ConnEndpoint>)> = BTreeMap::new();
        for w in m.wires() {
            acc.entry(&w.name).or_default().0 = w.width;
        }
        let mut ports = Vec::with_capacity(m.ports.len());
        for (pi, p) in m.ports.iter().enumerate() {
            let e = acc.entry(&p.name).or_default();
            e.0 = p.width;
            e.1.push(ConnEndpoint::Parent {
                port: PortId(pi as u32),
            });
            ports.push(PortConn {
                name: interner.intern(&p.name),
                width: p.width,
            });
        }
        let mut insts = Vec::with_capacity(m.instances().len());
        for (ii, inst) in m.instances().iter().enumerate() {
            insts.push(InstConn {
                name: interner.intern(&inst.instance_name),
                module: interner.intern(&inst.module_name),
            });
            for conn in &inst.connections {
                if let ConnExpr::Id(id) = &conn.value {
                    acc.entry(id).or_default().1.push(ConnEndpoint::Inst {
                        inst: InstId(ii as u32),
                        port: interner.intern(&conn.port),
                    });
                }
            }
        }
        let nets = acc
            .into_iter()
            .map(|(name, (width, endpoints))| NetConn {
                name: interner.intern(name),
                width,
                endpoints,
            })
            .collect();
        Ok(ModuleConn {
            module: interner.intern(&m.name),
            nets,
            ports,
            insts,
        })
    }

    /// Net id of an identifier, by binary search over the sorted table.
    pub fn net_id(&self, interner: &Interner, name: &str) -> Option<NetId> {
        self.nets
            .binary_search_by(|n| interner.resolve(n.name).cmp(name))
            .ok()
            .map(|i| NetId(i as u32))
    }

    pub fn net(&self, id: NetId) -> &NetConn {
        &self.nets[id.as_usize()]
    }

    /// Instance id by name (declaration-order position).
    pub fn inst_id(&self, interner: &Interner, name: &str) -> Option<InstId> {
        let sym = interner.get(name)?;
        self.insts
            .iter()
            .position(|i| i.name == sym)
            .map(|i| InstId(i as u32))
    }

    /// The other endpoint of a 2-endpoint net, given one side.
    pub fn opposite(&self, net: NetId, this: &ConnEndpoint) -> Option<&ConnEndpoint> {
        let info = self.net(net);
        if info.endpoints.len() != 2 {
            return None;
        }
        info.endpoints.iter().find(|e| *e != this)
    }

    /// Human-readable endpoint, matching `Endpoint::describe` exactly.
    pub fn describe_endpoint(&self, e: &ConnEndpoint, interner: &Interner) -> String {
        match e {
            ConnEndpoint::Parent { port } => {
                format!(
                    "<parent>.{}",
                    interner.resolve(self.ports[port.as_usize()].name)
                )
            }
            ConnEndpoint::Inst { inst, port } => {
                format!(
                    "{}.{}",
                    interner.resolve(self.insts[inst.as_usize()].name),
                    interner.resolve(*port)
                )
            }
        }
    }

    /// Materialize the legacy string-keyed [`BlockGraph`] view.
    pub fn to_block_graph(&self, interner: &Interner) -> BlockGraph {
        let mut nets = BTreeMap::new();
        for n in &self.nets {
            nets.insert(
                interner.resolve(n.name).to_string(),
                NetInfo {
                    endpoints: n
                        .endpoints
                        .iter()
                        .map(|e| self.legacy_endpoint(e, interner))
                        .collect(),
                    width: n.width,
                },
            );
        }
        BlockGraph {
            nets,
            instances: self
                .insts
                .iter()
                .map(|i| interner.resolve(i.name).to_string())
                .collect(),
        }
    }

    fn legacy_endpoint(&self, e: &ConnEndpoint, interner: &Interner) -> Endpoint {
        match e {
            ConnEndpoint::Parent { port } => {
                let name = self.ports[port.as_usize()].name;
                Endpoint::Parent {
                    port: interner.resolve(name).to_string(),
                }
            }
            ConnEndpoint::Inst { inst, port } => {
                let name = self.insts[inst.as_usize()].name;
                Endpoint::Inst {
                    inst: interner.resolve(name).to_string(),
                    port: interner.resolve(*port).to_string(),
                }
            }
        }
    }
}

/// One instantiation site of a module: which parent instantiates it, as
/// which instance, at which declaration position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParentSite {
    pub parent: Symbol,
    pub instance: Symbol,
    pub decl: usize,
}

/// The interning + indexing layer over one [`Design`]: stable module ids,
/// per-module cached connectivity, and the inverse instance→parent map.
/// See the module docs for the invalidation contract.
#[derive(Debug, Clone)]
pub struct DesignIndex {
    interner: Interner,
    ids: BTreeMap<String, ModuleId>,
    names: Vec<Symbol>,
    conns: Vec<Option<ModuleConn>>,
    parents: Option<BTreeMap<Symbol, Vec<ParentSite>>>,
    caching: bool,
    hits: u64,
    misses: u64,
}

impl Default for DesignIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl DesignIndex {
    pub fn new() -> DesignIndex {
        DesignIndex {
            interner: Interner::new(),
            ids: BTreeMap::new(),
            names: Vec::new(),
            conns: Vec::new(),
            parents: None,
            caching: true,
            hits: 0,
            misses: 0,
        }
    }

    /// Index every module of `design` up front. Ids are assigned in
    /// module-name order, so two indexes built over equal designs assign
    /// equal ids.
    pub fn for_design(design: &Design) -> DesignIndex {
        let mut ix = DesignIndex::new();
        for name in design.modules.keys() {
            ix.module_id(name);
        }
        ix
    }

    /// The interner backing every symbol this index hands out.
    pub fn interner(&self) -> &Interner {
        &self.interner
    }

    /// Stable id for a module name, assigned on first sight.
    pub fn module_id(&mut self, name: &str) -> ModuleId {
        if let Some(&id) = self.ids.get(name) {
            return id;
        }
        let id = ModuleId(self.names.len() as u32);
        self.names.push(self.interner.intern(name));
        self.conns.push(None);
        self.ids.insert(name.to_string(), id);
        id
    }

    /// The name a [`ModuleId`] was assigned to.
    pub fn module_name(&self, id: ModuleId) -> &str {
        self.interner.resolve(self.names[id.as_usize()])
    }

    /// Cached connectivity of grouped module `name` (built on first query
    /// or after invalidation). Returns the interner alongside so callers
    /// can resolve the symbols without a second borrow of the index.
    pub fn conn(
        &mut self,
        design: &Design,
        name: &str,
    ) -> Result<(&ModuleConn, &Interner), GraphError> {
        let id = self.module_id(name).as_usize();
        let m = design.module(name).ok_or_else(|| GraphError::Missing {
            module: name.to_string(),
        })?;
        if !m.is_grouped() {
            return Err(GraphError::Leaf {
                module: name.to_string(),
            });
        }
        if self.conns[id].is_none() || !self.caching {
            self.conns[id] = Some(ModuleConn::build(m, &mut self.interner)?);
            self.misses += 1;
        } else {
            self.hits += 1;
            // In debug builds, cross-check the cache against a fresh
            // build: a mismatch means something mutated the module
            // without `edit`/`touch` (or a pass wrongly declared
            // `IndexPolicy::Tracked`).
            #[cfg(debug_assertions)]
            {
                let fresh = ModuleConn::build(m, &mut self.interner)?;
                assert!(
                    self.conns[id].as_ref() == Some(&fresh),
                    "stale connectivity cache for module '{name}': \
                     mutated without DesignIndex::edit/touch"
                );
            }
        }
        Ok((self.conns[id].as_ref().unwrap(), &self.interner))
    }

    /// Like [`conn`](Self::conn), addressed by id.
    pub fn conn_by_id(
        &mut self,
        design: &Design,
        id: ModuleId,
    ) -> Result<(&ModuleConn, &Interner), GraphError> {
        let name = self.module_name(id).to_string();
        self.conn(design, &name)
    }

    /// Mutable access to a module for a connectivity-changing edit: marks
    /// only this module's cache dirty (plus the parent map, in case
    /// instances changed) before handing out the borrow. This is the one
    /// sanctioned mutation path for an `IndexPolicy::Tracked` pass.
    pub fn edit<'d>(&mut self, design: &'d mut Design, name: &str) -> Option<&'d mut Module> {
        self.touch(name);
        design.module_mut(name)
    }

    /// Like [`edit`](Self::edit), addressed by id.
    pub fn edit_by_id<'d>(
        &mut self,
        design: &'d mut Design,
        id: ModuleId,
    ) -> Option<&'d mut Module> {
        let name = self.module_name(id).to_string();
        self.edit(design, &name)
    }

    /// Mark one module's cached connectivity dirty — call after adding,
    /// replacing or removing the module named `name` outside [`edit`](Self::edit).
    pub fn touch(&mut self, name: &str) {
        let id = self.module_id(name);
        self.conns[id.as_usize()] = None;
        self.parents = None;
    }

    /// Drop every cached artifact (connectivity + parent map), keeping
    /// the interner and the stable name→id assignment. The pass pipeline
    /// calls this after any pass that does not track its own mutations.
    pub fn invalidate_all(&mut self) {
        for c in &mut self.conns {
            *c = None;
        }
        self.parents = None;
    }

    /// Drop only the cached parent map — call after module *removals*
    /// (e.g. [`Design::gc`]). Connectivity caches self-guard against
    /// deleted modules ([`conn`](Self::conn) checks the design first),
    /// but the parents map would otherwise keep listing the removed
    /// instantiation sites.
    pub fn invalidate_parents(&mut self) {
        self.parents = None;
    }

    /// Disable (or re-enable) connectivity caching — every [`conn`](Self::conn)
    /// query then rebuilds from the design. The equivalence tests use this
    /// to prove cached and uncached runs are byte-identical.
    pub fn set_caching(&mut self, on: bool) {
        self.caching = on;
    }

    /// `(hits, misses)` of the connectivity cache so far.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// The inverse instance→parent map: for each instantiated module,
    /// every site that instantiates it, ordered by (parent module name,
    /// declaration index). Cached until the next `edit`/`touch`/
    /// `invalidate_all`.
    pub fn parents(&mut self, design: &Design) -> (&BTreeMap<Symbol, Vec<ParentSite>>, &Interner) {
        if self.parents.is_none() {
            let mut map: BTreeMap<Symbol, Vec<ParentSite>> = BTreeMap::new();
            for m in design.modules.values() {
                let parent = self.interner.intern(&m.name);
                for (decl, inst) in m.instances().iter().enumerate() {
                    let child = self.interner.intern(&inst.module_name);
                    map.entry(child).or_default().push(ParentSite {
                        parent,
                        instance: self.interner.intern(&inst.instance_name),
                        decl,
                    });
                }
            }
            self.parents = Some(map);
        }
        (self.parents.as_ref().unwrap(), &self.interner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::core::*;

    /// Top with two instances A, B joined by wire `w`, A tied to parent
    /// port `in_data` (same shape as the graph.rs sample).
    fn sample_design() -> Design {
        let mut d = Design::new("Top");
        d.add(Module::leaf("A", SourceFormat::Verilog, ""));
        d.add(Module::leaf("B", SourceFormat::Verilog, ""));
        let mut m = Module::grouped("Top");
        m.ports = vec![Port::new("in_data", Dir::In, 32)];
        m.wires_mut().push(Wire {
            name: "w".into(),
            width: 64,
        });
        let mut a = Instance::new("a", "A");
        a.connect("o", ConnExpr::id("w"));
        a.connect("i", ConnExpr::id("in_data"));
        let mut b = Instance::new("b", "B");
        b.connect("i", ConnExpr::id("w"));
        m.instances_mut().push(a);
        m.instances_mut().push(b);
        d.add(m);
        d
    }

    #[test]
    fn conn_matches_legacy_block_graph() {
        let d = sample_design();
        let mut ix = DesignIndex::for_design(&d);
        let (conn, interner) = ix.conn(&d, "Top").unwrap();
        let view = conn.to_block_graph(interner);
        assert_eq!(view, BlockGraph::build(d.module("Top").unwrap()));
    }

    #[test]
    fn conn_is_cached_until_edit() {
        let mut d = sample_design();
        let mut ix = DesignIndex::for_design(&d);
        ix.conn(&d, "Top").unwrap();
        ix.conn(&d, "Top").unwrap();
        assert_eq!(ix.cache_stats(), (1, 1));
        // Edit through the index: cache dirtied, next query rebuilds and
        // sees the new wire.
        let top = ix.edit(&mut d, "Top").unwrap();
        top.wires_mut().push(Wire {
            name: "extra".into(),
            width: 1,
        });
        let (conn, interner) = ix.conn(&d, "Top").unwrap();
        assert!(conn.net_id(interner, "extra").is_some());
        assert_eq!(ix.cache_stats(), (1, 2));
    }

    #[test]
    fn module_ids_are_stable() {
        let mut d = sample_design();
        let mut ix = DesignIndex::for_design(&d);
        let id = ix.module_id("Top");
        ix.touch("Top");
        ix.invalidate_all();
        d.add(Module::grouped("Late"));
        ix.touch("Late");
        assert_eq!(ix.module_id("Top"), id);
        assert_eq!(ix.module_name(id), "Top");
        assert_ne!(ix.module_id("Late"), id);
    }

    #[test]
    fn leaf_and_missing_are_typed_errors() {
        let d = sample_design();
        let mut ix = DesignIndex::for_design(&d);
        assert!(matches!(
            ix.conn(&d, "A"),
            Err(GraphError::Leaf { module }) if module == "A"
        ));
        assert!(matches!(
            ix.conn(&d, "Ghost"),
            Err(GraphError::Missing { module }) if module == "Ghost"
        ));
    }

    #[test]
    fn opposite_and_lookups() {
        let d = sample_design();
        let mut ix = DesignIndex::for_design(&d);
        let (conn, interner) = ix.conn(&d, "Top").unwrap();
        let w = conn.net_id(interner, "w").unwrap();
        let a = conn.inst_id(interner, "a").unwrap();
        let this = ConnEndpoint::Inst {
            inst: a,
            port: interner.get("o").unwrap(),
        };
        let opp = conn.opposite(w, &this).unwrap();
        assert_eq!(conn.describe_endpoint(opp, interner), "b.i");
        // in_data has two endpoints (parent + a.i): opposite works there
        // too; a 1-endpoint net would yield None.
        let ind = conn.net_id(interner, "in_data").unwrap();
        assert_eq!(conn.net(ind).endpoints.len(), 2);
    }

    #[test]
    fn parents_invalidation_after_removal() {
        let mut d = sample_design();
        let mut ix = DesignIndex::for_design(&d);
        {
            let (map, interner) = ix.parents(&d);
            assert!(map.contains_key(&interner.get("A").unwrap()));
        }
        // Remove Top (the only module with instances), as gc would.
        d.modules.remove("Top");
        ix.invalidate_parents();
        let (map, _) = ix.parents(&d);
        assert!(map.is_empty(), "stale sites survived: {map:?}");
    }

    #[test]
    fn parents_map_lists_sites_in_order() {
        let d = sample_design();
        let mut ix = DesignIndex::for_design(&d);
        let (map, interner) = ix.parents(&d);
        let a = interner.get("A").unwrap();
        let sites = &map[&a];
        assert_eq!(sites.len(), 1);
        assert_eq!(interner.resolve(sites[0].parent), "Top");
        assert_eq!(interner.resolve(sites[0].instance), "a");
        assert_eq!(sites[0].decl, 0);
        let b = interner.get("B").unwrap();
        assert_eq!(map[&b][0].decl, 1);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "stale connectivity cache")]
    fn untracked_mutation_panics_in_debug() {
        let mut d = sample_design();
        let mut ix = DesignIndex::for_design(&d);
        ix.conn(&d, "Top").unwrap();
        // Bypass the index: mutate the module directly.
        d.module_mut("Top").unwrap().wires_mut().push(Wire {
            name: "sneaky".into(),
            width: 1,
        });
        let _ = ix.conn(&d, "Top");
    }

    #[test]
    fn uncached_mode_always_rebuilds() {
        let mut d = sample_design();
        let mut ix = DesignIndex::for_design(&d);
        ix.set_caching(false);
        ix.conn(&d, "Top").unwrap();
        // Mutate WITHOUT announcing: with caching off this is still
        // served fresh (the mode the equivalence tests compare against).
        d.module_mut("Top").unwrap().wires_mut().push(Wire {
            name: "late".into(),
            width: 1,
        });
        let (conn, interner) = ix.conn(&d, "Top").unwrap();
        assert!(conn.net_id(interner, "late").is_some());
        assert_eq!(ix.cache_stats().0, 0);
    }
}

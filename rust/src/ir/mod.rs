//! The RapidStream intermediate representation (§3.1): a progressively
//! refined, coarse-grained IR of a hybrid-source FPGA design.

pub mod builder;
pub mod core;
pub mod digest;
pub mod graph;
pub mod index;
pub mod intern;
pub mod namemap;
pub mod schema;
pub mod validate;

pub use core::{
    Body, ConnExpr, Connection, Design, Dir, Instance, Interface, Module, Port, Resources,
    SourceFormat, Wire,
};

//! Synthesis wall-time model for the parallel-synthesis case study
//! (§4.3 / Figure 13).
//!
//! Vendor logic synthesis scales super-linearly with design size; the
//! per-slot divide-and-conquer flow wins by (a) smaller problems and
//! (b) parallelism across slots, at the price of a final assembly step
//! over black-box netlists. The model below reproduces that shape: the
//! paper reports 2.49× mean wall-time speedup on CNN 13×4…13×12, growing
//! with array size.

use crate::ir::core::Resources;

/// Wall-time model constants (seconds).
#[derive(Debug, Clone)]
pub struct SynthTimeModel {
    /// Fixed tool start-up per invocation.
    pub startup_s: f64,
    /// Seconds per kLUT (linear term).
    pub per_klut_s: f64,
    /// Super-linear exponent on total size.
    pub exponent: f64,
    /// Final assembly base cost (open netlists, stitch top).
    pub assembly_base_s: f64,
    /// Assembly cost per kLUT of the whole design (netlist linking).
    pub assembly_per_klut_s: f64,
}

impl Default for SynthTimeModel {
    fn default() -> Self {
        SynthTimeModel {
            startup_s: 45.0,
            per_klut_s: 7.0,
            exponent: 1.10,
            assembly_base_s: 60.0,
            assembly_per_klut_s: 1.5,
        }
    }
}

impl SynthTimeModel {
    /// Modeled wall time to synthesize one blob of logic.
    pub fn synth_s(&self, r: &Resources) -> f64 {
        let klut = (r.lut / 1000.0).max(0.1);
        self.startup_s + self.per_klut_s * klut.powf(self.exponent)
    }

    /// Monolithic flow: one synthesis of everything.
    pub fn monolithic_s(&self, total: &Resources) -> f64 {
        self.synth_s(total)
    }

    /// Parallel flow: synthesize each slot's group concurrently on
    /// `workers` parallel jobs (the top wrapper with black boxes counts as
    /// one more job), then assemble.
    pub fn parallel_s(&self, groups: &[Resources], workers: usize) -> f64 {
        assert!(workers > 0);
        // List-scheduling (LPT) of jobs onto workers.
        let mut jobs: Vec<f64> = groups.iter().map(|g| self.synth_s(g)).collect();
        // Top-level wrapper job: tiny.
        jobs.push(self.startup_s);
        jobs.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut load = vec![0.0f64; workers];
        for j in jobs {
            let w = load
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            load[w] += j;
        }
        let makespan = load.iter().cloned().fold(0.0, f64::max);
        let total_klut: f64 = groups.iter().map(|g| g.lut / 1000.0).sum();
        makespan + self.assembly_base_s + self.assembly_per_klut_s * total_klut
    }

    /// Speedup of the parallel flow.
    pub fn speedup(&self, groups: &[Resources], workers: usize) -> f64 {
        let total = groups.iter().fold(Resources::ZERO, |a, g| a.add(g));
        self.monolithic_s(&total) / self.parallel_s(groups, workers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn groups(n: usize, klut_each: f64) -> Vec<Resources> {
        (0..n)
            .map(|_| Resources::new(klut_each * 1000.0, 0.0, 0.0, 0.0, 0.0))
            .collect()
    }

    #[test]
    fn parallel_beats_monolithic_for_large_designs() {
        let m = SynthTimeModel::default();
        let g = groups(8, 30.0); // 240 kLUT total across 8 slots
        let s = m.speedup(&g, 8);
        assert!(s > 1.5, "speedup {s}");
    }

    #[test]
    fn speedup_grows_with_design_size() {
        let m = SynthTimeModel::default();
        let small = m.speedup(&groups(8, 5.0), 8);
        let large = m.speedup(&groups(8, 40.0), 8);
        assert!(large > small, "small={small} large={large}");
    }

    #[test]
    fn tiny_designs_not_worth_splitting() {
        let m = SynthTimeModel::default();
        // 8 × 0.5 kLUT: startup + assembly dominate.
        let s = m.speedup(&groups(8, 0.5), 8);
        assert!(s < 1.2, "{s}");
    }

    #[test]
    fn worker_limit_respected() {
        let m = SynthTimeModel::default();
        let g = groups(8, 30.0);
        let s1 = m.parallel_s(&g, 1);
        let s8 = m.parallel_s(&g, 8);
        assert!(s1 > s8 * 3.0);
        // Single worker ≈ sum of all jobs + assembly.
        let total_klut: f64 = g.iter().map(|r| r.lut / 1000.0).sum();
        let sum: f64 = g.iter().map(|r| m.synth_s(r)).sum::<f64>() + m.startup_s
            + m.assembly_base_s + m.assembly_per_klut_s * total_klut;
        assert!((s1 - sum).abs() < 1e-6);
    }

    #[test]
    fn shape_matches_paper_range() {
        // CNN-like: arrays from ~50 to ~150 kLUT over 8 slots on U250;
        // mean speedup should land in the 2–3× band (paper: 2.49×).
        let m = SynthTimeModel::default();
        let mut speedups = Vec::new();
        for total_klut in [50.0, 75.0, 100.0, 125.0, 150.0] {
            let g = groups(8, total_klut / 8.0);
            speedups.push(m.speedup(&g, 8));
        }
        let mean: f64 = speedups.iter().sum::<f64>() / speedups.len() as f64;
        assert!(mean > 1.8 && mean < 3.5, "mean speedup {mean}");
        // Monotone growth with size.
        assert!(speedups.windows(2).all(|w| w[1] >= w[0] - 1e-9));
    }
}

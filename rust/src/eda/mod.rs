//! Simulated EDA backend ("VivadoSim"): synthesis characterization,
//! baseline placement, routing/congestion, STA, and the synthesis
//! wall-time model.

pub mod place;
pub mod synth;
pub mod synthtime;
pub mod vivado;

pub use place::{place, PlacerConfig};
pub use synth::SynthEstimator;
pub use synthtime::SynthTimeModel;
pub use vivado::{elaborate, implement, implement_netlist, ImplReport};

//! Synthesis surrogate: per-module resource and timing characterization.
//!
//! The paper's platform analyzer "interfaces with vendor tools to collect
//! data" (§3.2) — here the vendor synthesizer is replaced by (a) metadata
//! already attached to the module (the HLS-report path: benchmark
//! generators attach exact `resource` / `timing` entries, as Vitis HLS
//! reports would provide), and (b) an AST-based estimator for handwritten
//! Verilog aux logic where no report exists.

use crate::ir::core::*;
use crate::timing::netlist::ModuleCharacteristics;
use crate::util::lru::{CacheStats, Lru};
use crate::verilog::ast::{VItem, VModule};
use crate::verilog::parser::parse_file;
use std::fmt;
use std::sync::Mutex;

/// Characteristics provider: metadata first, AST estimation fallback.
pub struct SynthEstimator {
    /// Default internal delay when nothing else is known (ns).
    pub default_internal_ns: f64,
}

impl Default for SynthEstimator {
    fn default() -> Self {
        SynthEstimator {
            default_internal_ns: 2.2,
        }
    }
}

impl ModuleCharacteristics for SynthEstimator {
    fn resources(&self, m: &Module) -> Resources {
        if let Some(r) = crate::ir::builder::module_resources(m) {
            return r;
        }
        match &m.body {
            Body::Leaf {
                format: SourceFormat::Verilog,
                source,
            } => estimate_verilog(source).unwrap_or_else(|| estimate_from_ports(m)),
            _ => estimate_from_ports(m),
        }
    }

    fn internal_ns(&self, m: &Module) -> f64 {
        if let Some(t) = m
            .metadata
            .get("timing")
            .and_then(|t| t.at("internal_ns"))
            .and_then(|v| v.as_f64())
        {
            return t;
        }
        // Logic-depth heuristic: larger modules have longer internal paths.
        let r = self.resources(m);
        let lut = r.lut.max(1.0);
        // 1.6 ns base + ~0.09 ns per doubling of LUT count beyond 100.
        let depth = (lut / 100.0).max(1.0).log2();
        (1.6 + 0.09 * depth).min(3.4).max(0.8)
    }
}

/// Digest-keyed memo over [`SynthEstimator`] characterization — the
/// stage-1 tier of the incremental re-flow engine. Keyed by the FNV-1a
/// digest of the module's own JSON (characterization never looks at
/// children), so re-analyzing a design after a one-leaf edit recomputes
/// exactly one entry. Interior-mutable: a shared memo serves concurrent
/// flows, and a panicking job cannot wedge it (poison recovery, same
/// policy as the daemon caches).
pub struct CharMemo {
    est: SynthEstimator,
    inner: Mutex<Lru<u64, (Resources, f64)>>,
}

impl fmt::Debug for CharMemo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CharMemo").field("stats", &self.stats()).finish()
    }
}

impl CharMemo {
    pub fn new(cap: usize) -> Self {
        CharMemo {
            est: SynthEstimator::default(),
            inner: Mutex::new(Lru::new(cap)),
        }
    }

    /// `(resources, internal_ns)` of `m`, memoized by module digest.
    pub fn characterize(&self, m: &Module) -> (Resources, f64) {
        let key = crate::ir::digest::fnv1a64(
            crate::ir::schema::module_to_json(m).dump().as_bytes(),
        );
        if let Some(hit) = self.lock().get(&key) {
            return hit;
        }
        let value = (self.est.resources(m), self.est.internal_ns(m));
        self.lock().put(key, value);
        value
    }

    pub fn stats(&self) -> CacheStats {
        self.lock().stats()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Lru<u64, (Resources, f64)>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }
}

impl ModuleCharacteristics for CharMemo {
    fn resources(&self, m: &Module) -> Resources {
        self.characterize(m).0
    }

    fn internal_ns(&self, m: &Module) -> f64 {
        self.characterize(m).1
    }
}

/// AST-based resource estimation for handwritten Verilog.
///
/// Deliberately coarse — the quantities that matter downstream are
/// relative module sizes, not gate-accurate counts:
/// * FF  ≈ Σ widths of `reg` declarations (+ per always block overhead);
/// * LUT ≈ Σ expression operator costs in assigns + always blocks;
/// * DSP ≈ wide multiplications;
/// * BRAM ≈ memory arrays (captured raw; detected textually).
pub fn estimate_verilog(source: &str) -> Option<Resources> {
    let file = parse_file(source).ok()?;
    let mut total = Resources::ZERO;
    for m in &file.modules {
        total = total.add(&estimate_vmodule(m));
    }
    Some(total)
}

pub fn estimate_vmodule(m: &VModule) -> Resources {
    let mut r = Resources::ZERO;
    for item in &m.items {
        match item {
            VItem::Net(n) => {
                if n.kind == "reg" {
                    r.ff += (n.width as f64) * n.names.len() as f64;
                }
            }
            VItem::Assign(a) => {
                r.lut += expr_lut_cost(&a.rhs, m);
                let (dsp, bram) = expr_hard_blocks(&a.rhs, m);
                r.dsp += dsp;
                r.bram += bram;
            }
            VItem::Raw(raw) => {
                // Heuristics over verbatim logic.
                let ops = raw.matches("<=").count() + raw.matches('=').count();
                r.lut += 4.0 * ops as f64;
                let (dsp, bram) = expr_hard_blocks(raw, m);
                r.dsp += dsp;
                r.bram += bram;
                // Memory arrays: `reg [..] name [0:N]`.
                if raw.contains("reg") && raw.matches('[').count() >= 2 {
                    r.bram += 1.0;
                }
                if raw.trim_start().starts_with("always") {
                    r.ff += 8.0;
                }
            }
            VItem::Instance(_) => {}
        }
    }
    // Port registering overhead.
    let port_bits: u32 = m.ports.iter().map(|p| p.width).sum();
    r.ff += port_bits as f64 * 0.5;
    r.lut += port_bits as f64 * 0.25;
    r
}

fn expr_lut_cost(expr: &str, m: &VModule) -> f64 {
    let width_guess = crate::verilog::ast::expr_identifiers(expr)
        .iter()
        .filter_map(|id| m.width_of(id))
        .max()
        .unwrap_or(1) as f64;
    let ops = expr.matches(|c| "&|^~+-<>?".contains(c)).count().max(1);
    ops as f64 * width_guess * 0.5
}

fn expr_hard_blocks(expr: &str, m: &VModule) -> (f64, f64) {
    let mut dsp = 0.0;
    // Count '*' not part of comments/power.
    let muls = expr
        .as_bytes()
        .windows(2)
        .filter(|w| w[0] == b'*' && w[1] != b'*' && w[1] != b'/' && w[1] != b')')
        .count();
    if muls > 0 {
        let w = crate::verilog::ast::expr_identifiers(expr)
            .iter()
            .filter_map(|id| m.width_of(id))
            .max()
            .unwrap_or(18) as f64;
        dsp += muls as f64 * (w / 18.0).ceil();
    }
    (dsp, 0.0)
}

/// Port-sum fallback when no source is parseable (XCI/XO/blackbox leaves
/// without metadata).
fn estimate_from_ports(m: &Module) -> Resources {
    let bits: u32 = m.ports.iter().map(|p| p.width).sum();
    Resources::new(bits as f64 * 2.0, bits as f64 * 2.0, 0.0, 0.0, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::LeafBuilder;

    #[test]
    fn metadata_takes_priority() {
        let est = SynthEstimator::default();
        let m = LeafBuilder::verilog_stub("M")
            .port("a", Dir::In, 64)
            .resource(Resources::new(1234.0, 10.0, 1.0, 2.0, 3.0))
            .build();
        assert_eq!(est.resources(&m).lut, 1234.0);
    }

    #[test]
    fn verilog_reg_counted_as_ff() {
        let src = "module M(input clk);\nreg [31:0] acc;\nreg flag;\nendmodule";
        let r = estimate_verilog(src).unwrap();
        assert!(r.ff >= 33.0, "{r:?}");
    }

    #[test]
    fn multiplication_uses_dsp() {
        let src =
            "module M(input [26:0] a, input [17:0] b, output [44:0] y);\nassign y = a * b;\nendmodule";
        let r = estimate_verilog(src).unwrap();
        assert!(r.dsp >= 1.0, "{r:?}");
    }

    #[test]
    fn memory_array_uses_bram() {
        let src = "module M(input clk);\nreg [63:0] mem [0:511];\nendmodule";
        let r = estimate_verilog(src).unwrap();
        assert!(r.bram >= 1.0, "{r:?}");
    }

    #[test]
    fn internal_delay_grows_with_size() {
        let est = SynthEstimator::default();
        let small = LeafBuilder::verilog_stub("S")
            .resource(Resources::new(100.0, 0.0, 0.0, 0.0, 0.0))
            .build();
        let big = LeafBuilder::verilog_stub("B")
            .resource(Resources::new(100_000.0, 0.0, 0.0, 0.0, 0.0))
            .build();
        assert!(est.internal_ns(&big) > est.internal_ns(&small));
        assert!(est.internal_ns(&big) <= 3.4);
    }

    #[test]
    fn char_memo_matches_estimator_and_counts_hits() {
        let est = SynthEstimator::default();
        let memo = CharMemo::new(8);
        let m = LeafBuilder::verilog_stub("M")
            .port("a", Dir::In, 64)
            .resource(Resources::new(1234.0, 10.0, 1.0, 2.0, 3.0))
            .build();
        use crate::timing::netlist::ModuleCharacteristics;
        assert_eq!(memo.resources(&m).lut, est.resources(&m).lut);
        assert_eq!(memo.internal_ns(&m), est.internal_ns(&m));
        let s = memo.stats();
        assert_eq!(s.misses, 1, "{s:?}");
        assert!(s.hits >= 1, "{s:?}");
    }

    #[test]
    fn timing_metadata_respected() {
        let est = SynthEstimator::default();
        let mut m = LeafBuilder::verilog_stub("T").build();
        let mut t = crate::util::json::JsonObj::new();
        t.insert("internal_ns", crate::util::json::Json::num(3.14));
        m.metadata.insert("timing", crate::util::json::Json::Obj(t));
        assert_eq!(est.internal_ns(&m), 3.14);
    }
}

//! Baseline "vendor" placer.
//!
//! Models what Vivado does *without* HLPS guidance (§1: "This forces
//! downstream tools to place these blocks closer together to minimize
//! total wire length, which in turn causes local routing congestion"):
//! a deterministic seeded simulated-annealing placement that minimizes
//! **wirelength only**, packing connected logic tightly — ignoring
//! latency tolerance, die crossings-as-pipelining-opportunities, and the
//! congestion cliff. Floorplan-constrained nodes (from RIR) stay fixed.

use crate::device::model::VirtualDevice;
use crate::ir::core::Resources;
use crate::timing::netlist::FlatNetlist;
use crate::timing::sta::Placement;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct PlacerConfig {
    pub seed: u64,
    pub iterations: usize,
    /// Initial temperature as a fraction of initial cost.
    pub t0_frac: f64,
    /// Hard capacity: the placer refuses to overfill a slot beyond this.
    pub capacity_limit: f64,
    /// Weight of die crossings relative to manhattan distance in the
    /// wirelength objective (vendor placers do weigh SLLs).
    pub die_weight: f64,
}

impl Default for PlacerConfig {
    fn default() -> Self {
        PlacerConfig {
            seed: 0xF1A6,
            iterations: 40_000,
            t0_frac: 0.08,
            capacity_limit: 1.0,
            die_weight: 2.0,
        }
    }
}

/// Wirelength of a placement (Σ width × weighted distance).
pub fn wirelength(
    nl: &FlatNetlist,
    slot_of_node: &[usize],
    dev: &VirtualDevice,
    die_weight: f64,
) -> f64 {
    nl.edges
        .iter()
        .map(|e| {
            let (man, dies) = dev.slot_dist(slot_of_node[e.src], slot_of_node[e.dst]);
            e.width as f64 * (man as f64 + die_weight * dies as f64)
        })
        .sum()
}

/// Place the netlist. Returns None if total demand cannot fit the device
/// at all (placer "fails to place").
pub fn place(nl: &FlatNetlist, dev: &VirtualDevice, cfg: &PlacerConfig) -> Option<Placement> {
    let ns = dev.num_slots();
    if nl.nodes.is_empty() {
        return Some(Placement::new(Vec::new()));
    }

    // Resolve fixed slots from pblock names.
    let fixed: Vec<Option<usize>> = nl
        .nodes
        .iter()
        .map(|n| {
            n.fixed_slot
                .as_ref()
                .and_then(|pb| dev.slots.iter().position(|s| &s.pblock == pb))
        })
        .collect();

    // Initial placement: BFS over the connectivity graph (what a
    // wirelength-driven analytic placer converges to) packing nodes into
    // slots in row-major adjacency order up to the capacity limit, so
    // connected clusters land together before annealing refines.
    let mut used = vec![Resources::ZERO; ns];
    let mut slot_of_node = vec![usize::MAX; nl.nodes.len()];
    for n in 0..nl.nodes.len() {
        if let Some(s) = fixed[n] {
            slot_of_node[n] = s;
            used[s] = used[s].add(&nl.nodes[n].resources);
        }
    }
    // BFS order seeded from the highest-degree unplaced node.
    let mut degree = vec![0u64; nl.nodes.len()];
    let mut neigh: Vec<Vec<usize>> = vec![Vec::new(); nl.nodes.len()];
    for e in &nl.edges {
        degree[e.src] += e.width;
        degree[e.dst] += e.width;
        neigh[e.src].push(e.dst);
        neigh[e.dst].push(e.src);
    }
    let mut order: Vec<usize> = Vec::with_capacity(nl.nodes.len());
    let mut seen = vec![false; nl.nodes.len()];
    let mut seeds: Vec<usize> = (0..nl.nodes.len()).collect();
    seeds.sort_by_key(|&n| std::cmp::Reverse(degree[n]));
    for seed in seeds {
        if seen[seed] {
            continue;
        }
        let mut queue = std::collections::VecDeque::from([seed]);
        seen[seed] = true;
        while let Some(n) = queue.pop_front() {
            order.push(n);
            for &m in &neigh[n] {
                if !seen[m] {
                    seen[m] = true;
                    queue.push_back(m);
                }
            }
        }
    }
    let mut cursor = 0usize; // current slot in row-major order
    for &n in &order {
        if slot_of_node[n] != usize::MAX {
            continue;
        }
        let mut placed = false;
        for k in 0..ns {
            let s = (cursor + k) % ns;
            let u = used[s]
                .add(&nl.nodes[n].resources)
                .max_util(&dev.slots[s].capacity);
            if u <= cfg.capacity_limit {
                slot_of_node[n] = s;
                used[s] = used[s].add(&nl.nodes[n].resources);
                cursor = s;
                placed = true;
                break;
            }
        }
        if !placed {
            // Overfull device: fall back to the least-loaded slot.
            let s = (0..ns)
                .min_by(|&a, &b| {
                    let ua = used[a]
                        .add(&nl.nodes[n].resources)
                        .max_util(&dev.slots[a].capacity);
                    let ub = used[b]
                        .add(&nl.nodes[n].resources)
                        .max_util(&dev.slots[b].capacity);
                    ua.partial_cmp(&ub).unwrap()
                })
                .unwrap();
            slot_of_node[n] = s;
            used[s] = used[s].add(&nl.nodes[n].resources);
        }
    }
    // Fixed nodes may legitimately exceed the limit (RIR decides); only
    // movable nodes respect the placer's own capacity limit during SA.

    // Simulated annealing on single-node moves, wirelength objective.
    // Iteration budget scales with design size so large flat netlists
    // converge (~2000 proposed moves per movable node).
    let movable: Vec<usize> = (0..nl.nodes.len()).filter(|&n| fixed[n].is_none()).collect();
    let iterations = cfg.iterations.max(movable.len() * 2000);
    if !movable.is_empty() {
        let mut rng = Rng::new(cfg.seed);
        // Per-node edge adjacency for incremental cost.
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); nl.nodes.len()];
        for (ei, e) in nl.edges.iter().enumerate() {
            adj[e.src].push(ei);
            adj[e.dst].push(ei);
        }
        let edge_cost = |e: &crate::timing::netlist::FlatEdge, slots: &[usize]| {
            let (man, dies) = dev.slot_dist(slots[e.src], slots[e.dst]);
            e.width as f64 * (man as f64 + cfg.die_weight * dies as f64)
        };
        let init_cost = wirelength(nl, &slot_of_node, dev, cfg.die_weight);
        let mut temp = (init_cost * cfg.t0_frac).max(1.0);
        let cooling = 0.999965f64.powf(40_000.0 / iterations.max(1) as f64);
        for it in 0..iterations {
            temp *= cooling;
            if it % 10 < 3 && movable.len() >= 2 {
                // Swap move: exchanges two nodes — escapes tight-capacity
                // local minima single-node moves cannot leave.
                let a = *rng.pick(&movable);
                let b = *rng.pick(&movable);
                let (sa, sb) = (slot_of_node[a], slot_of_node[b]);
                if a == b || sa == sb {
                    continue;
                }
                let ua = sub(used[sa], &nl.nodes[a].resources)
                    .add(&nl.nodes[b].resources)
                    .max_util(&dev.slots[sa].capacity);
                let ub = sub(used[sb], &nl.nodes[b].resources)
                    .add(&nl.nodes[a].resources)
                    .max_util(&dev.slots[sb].capacity);
                if ua > cfg.capacity_limit || ub > cfg.capacity_limit {
                    continue;
                }
                let edges: std::collections::BTreeSet<usize> =
                    adj[a].iter().chain(adj[b].iter()).copied().collect();
                let before: f64 = edges.iter().map(|&ei| edge_cost(&nl.edges[ei], &slot_of_node)).sum();
                slot_of_node[a] = sb;
                slot_of_node[b] = sa;
                let after: f64 = edges.iter().map(|&ei| edge_cost(&nl.edges[ei], &slot_of_node)).sum();
                let delta = after - before;
                if delta <= 0.0 || rng.f64() < (-delta / temp).exp() {
                    let ra = nl.nodes[a].resources;
                    let rb = nl.nodes[b].resources;
                    used[sa] = sub(used[sa], &ra).add(&rb);
                    used[sb] = sub(used[sb], &rb).add(&ra);
                } else {
                    slot_of_node[a] = sa;
                    slot_of_node[b] = sb;
                }
                continue;
            }
            let n = *rng.pick(&movable);
            let old_slot = slot_of_node[n];
            let new_slot = rng.below(ns);
            if new_slot == old_slot {
                continue;
            }
            // Capacity check.
            let nu = used[new_slot]
                .add(&nl.nodes[n].resources)
                .max_util(&dev.slots[new_slot].capacity);
            if nu > cfg.capacity_limit {
                continue;
            }
            let before: f64 = adj[n].iter().map(|&ei| edge_cost(&nl.edges[ei], &slot_of_node)).sum();
            slot_of_node[n] = new_slot;
            let after: f64 = adj[n].iter().map(|&ei| edge_cost(&nl.edges[ei], &slot_of_node)).sum();
            let delta = after - before;
            if delta <= 0.0 || rng.f64() < (-delta / temp).exp() {
                // accept
                used[old_slot] = sub(used[old_slot], &nl.nodes[n].resources);
                used[new_slot] = used[new_slot].add(&nl.nodes[n].resources);
            } else {
                slot_of_node[n] = old_slot;
            }
        }
    }

    Some(Placement::new(slot_of_node))
}

fn sub(a: Resources, b: &Resources) -> Resources {
    Resources {
        lut: a.lut - b.lut,
        ff: a.ff - b.ff,
        bram: a.bram - b.bram,
        dsp: a.dsp - b.dsp,
        uram: a.uram - b.uram,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::timing::netlist::{FlatEdge, FlatNode};

    fn node(path: &str, lut: f64) -> FlatNode {
        FlatNode {
            path: path.into(),
            module: "M".into(),
            resources: Resources::new(lut, lut, 0.0, 0.0, 0.0),
            internal_ns: 2.0,
            is_pipeline: false,
            fixed_slot: None,
        }
    }

    #[test]
    fn chain_gets_colocated() {
        // 6 small nodes in a chain fit one slot; vendor placer should pull
        // them close (wirelength near zero).
        let dev = builtin::by_name("u250").unwrap();
        let nl = FlatNetlist {
            nodes: (0..6).map(|i| node(&format!("n{i}"), 1000.0)).collect(),
            edges: (0..5)
                .map(|i| FlatEdge {
                    src: i,
                    dst: i + 1,
                    width: 64,
                    pipelinable: true,
                })
                .collect(),
        };
        let p = place(&nl, &dev, &PlacerConfig::default()).unwrap();
        let wl = wirelength(&nl, &p.slot_of_node, &dev, 2.0);
        assert!(wl <= 64.0 * 2.0, "wl={wl} placement={:?}", p.slot_of_node);
    }

    #[test]
    fn respects_capacity() {
        let dev = builtin::by_name("u280").unwrap();
        // Two nodes each ~60% of a slot: cannot share one slot.
        let cap = dev.slots[0].capacity.lut;
        let nl = FlatNetlist {
            nodes: vec![node("a", cap * 0.6), node("b", cap * 0.6)],
            edges: vec![FlatEdge {
                src: 0,
                dst: 1,
                width: 8,
                pipelinable: true,
            }],
        };
        let p = place(&nl, &dev, &PlacerConfig::default()).unwrap();
        assert_ne!(p.slot_of_node[0], p.slot_of_node[1]);
    }

    #[test]
    fn fixed_slots_honored() {
        let dev = builtin::by_name("u250").unwrap();
        let mut nl = FlatNetlist {
            nodes: vec![node("a", 100.0), node("b", 100.0)],
            edges: vec![],
        };
        nl.nodes[0].fixed_slot = Some("SLOT_X1Y3".into());
        let p = place(&nl, &dev, &PlacerConfig::default()).unwrap();
        assert_eq!(p.slot_of_node[0], dev.slot_index(1, 3));
    }

    #[test]
    fn deterministic_for_seed() {
        let dev = builtin::by_name("u280").unwrap();
        let nl = FlatNetlist {
            nodes: (0..10).map(|i| node(&format!("n{i}"), 5000.0)).collect(),
            edges: (0..9)
                .map(|i| FlatEdge {
                    src: i,
                    dst: i + 1,
                    width: 32,
                    pipelinable: true,
                })
                .collect(),
        };
        let p1 = place(&nl, &dev, &PlacerConfig::default()).unwrap();
        let p2 = place(&nl, &dev, &PlacerConfig::default()).unwrap();
        assert_eq!(p1, p2);
    }
}

//! Vendor-tool facade ("VivadoSim"): synthesize → place → route → STA.
//!
//! `implement` is what both the baseline flow (no HLPS) and the RIR flow
//! call at the very end. The only difference between them is what they
//! hand over: the baseline passes the raw design (placer free to pack),
//! RIR passes a design whose instances carry `floorplan` metadata and
//! whose long nets have been broken with pipeline elements.

use crate::device::model::VirtualDevice;
use crate::eda::place::{place, PlacerConfig};
use crate::eda::synth::SynthEstimator;
use crate::ir::core::Design;
use crate::timing::delay::DelayModel;
use crate::timing::netlist::{flatten, FlatNetlist};
use crate::timing::sta::{Placement, TimingReport};
use anyhow::Result;

/// Result of a full implementation run.
#[derive(Debug, Clone)]
pub struct ImplReport {
    pub timing: TimingReport,
    pub placement: Placement,
    pub netlist_nodes: usize,
    pub netlist_edges: usize,
    /// Total resources as fraction of device capacity (LUT/FF/BRAM/DSP/URAM %).
    pub util_pct: [f64; 5],
}

impl ImplReport {
    pub fn fmax_mhz(&self) -> f64 {
        self.timing.fmax_mhz
    }

    pub fn routable(&self) -> bool {
        self.timing.routable
    }
}

/// Flatten a design with the standard estimator.
pub fn elaborate(design: &Design) -> FlatNetlist {
    flatten(design, &SynthEstimator::default())
}

/// Run the full backend on an elaborated netlist.
pub fn implement_netlist(
    nl: &FlatNetlist,
    dev: &VirtualDevice,
    placer: &PlacerConfig,
    dm: &DelayModel,
) -> Result<ImplReport> {
    implement_netlist_with(nl, dev, placer, dm, crate::timing::sta::StaOptions::default())
}

/// Backend with explicit STA options (`unguided: true` = vendor baseline
/// without floorplan guidance).
pub fn implement_netlist_with(
    nl: &FlatNetlist,
    dev: &VirtualDevice,
    placer: &PlacerConfig,
    dm: &DelayModel,
    opts: crate::timing::sta::StaOptions,
) -> Result<ImplReport> {
    let placement = place(nl, dev, placer).ok_or_else(|| {
        // Typed infeasibility (legacy message bytes): the design simply
        // does not fit, which sweeps record rather than propagate.
        anyhow::Error::new(crate::floorplan::Infeasible::new(
            "placement failed: design does not fit",
        ))
    })?;
    let timing = crate::timing::sta::analyze_with(nl, &placement, dev, dm, opts);
    Ok(assemble_report(nl, dev, placement, timing))
}

/// Assemble an [`ImplReport`] from an already-computed placement and
/// timing report — the shared tail of [`implement_netlist_with`] and the
/// memoized backend (`coordinator::memo::StageMemo::implement`), so both
/// paths produce identical bytes by construction.
pub fn assemble_report(
    nl: &FlatNetlist,
    dev: &VirtualDevice,
    placement: Placement,
    timing: TimingReport,
) -> ImplReport {
    let total = nl.total_resources();
    let cap = dev.total_capacity();
    let pct = |x: f64, c: f64| if c > 0.0 { 100.0 * x / c } else { 0.0 };
    ImplReport {
        util_pct: [
            pct(total.lut, cap.lut),
            pct(total.ff, cap.ff),
            pct(total.bram, cap.bram),
            pct(total.dsp, cap.dsp),
            pct(total.uram, cap.uram),
        ],
        netlist_nodes: nl.nodes.len(),
        netlist_edges: nl.edges.len(),
        placement,
        timing,
    }
}

/// One-call flow: elaborate + place + analyze.
pub fn implement(design: &Design, dev: &VirtualDevice) -> Result<ImplReport> {
    let nl = elaborate(design);
    implement_netlist(&nl, dev, &PlacerConfig::default(), &DelayModel::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::ir::builder::*;
    use crate::ir::core::*;

    fn pipeline_design(n: usize, lut_each: f64) -> Design {
        let mut d = Design::new("Top");
        let mut top = GroupedBuilder::new("Top")
            .port("ap_clk", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            });
        for i in 0..n {
            let m = LeafBuilder::verilog_stub(format!("Stage{i}"))
                .clk_rst()
                .handshake("i", Dir::In, 64)
                .handshake("o", Dir::Out, 64)
                .resource(Resources::new(lut_each, lut_each, 8.0, 32.0, 0.0))
                .build();
            d.add(m);
        }
        for i in 0..n.saturating_sub(1) {
            top = top
                .wire(&format!("w{i}"), 64)
                .wire(&format!("w{i}_vld"), 1)
                .wire(&format!("w{i}_rdy"), 1);
        }
        for i in 0..n {
            let mut inst = Instance::new(format!("s{i}"), format!("Stage{i}"));
            inst.connect("ap_clk", ConnExpr::id("ap_clk"));
            if i > 0 {
                inst.connect("i", ConnExpr::id(&format!("w{}", i - 1)));
                inst.connect("i_vld", ConnExpr::id(&format!("w{}_vld", i - 1)));
                inst.connect("i_rdy", ConnExpr::id(&format!("w{}_rdy", i - 1)));
            }
            if i + 1 < n {
                inst.connect("o", ConnExpr::id(&format!("w{i}")));
                inst.connect("o_vld", ConnExpr::id(&format!("w{i}_vld")));
                inst.connect("o_rdy", ConnExpr::id(&format!("w{i}_rdy")));
            }
            top = top.inst_full(inst);
        }
        d.add(top.build());
        d
    }

    #[test]
    fn small_design_implements_routable() {
        let d = pipeline_design(4, 2000.0);
        let dev = builtin::by_name("u280").unwrap();
        let r = implement(&d, &dev).unwrap();
        assert!(r.routable());
        assert!(r.fmax_mhz() > 250.0, "{}", r.fmax_mhz());
        assert_eq!(r.netlist_nodes, 4);
    }

    #[test]
    fn oversized_design_fails_placement_or_routing() {
        // Each stage ~80% of a slot, 12 stages on a 6-slot device.
        let dev = builtin::by_name("u280").unwrap();
        let cap = dev.slots[5].capacity.lut;
        let d = pipeline_design(12, cap * 0.8);
        match implement(&d, &dev) {
            Ok(r) => assert!(!r.routable(), "should be congested"),
            Err(_) => {} // placement failure also acceptable
        }
    }

    #[test]
    fn utilization_percentages_reported() {
        let d = pipeline_design(4, 10_000.0);
        let dev = builtin::by_name("u250").unwrap();
        let r = implement(&d, &dev).unwrap();
        let total_lut = 4.0 * 10_000.0;
        let expect = 100.0 * total_lut / dev.total_capacity().lut;
        assert!((r.util_pct[0] - expect).abs() < 0.1);
    }
}

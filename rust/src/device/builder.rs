//! User-customizable device definition API — the Rust equivalent of the
//! Python snippet in Figure 7 of the paper:
//!
//! ```text
//! device = VirtualDevice.from_part("xcvp1552")
//!     .grid(cols=2, rows=4)
//!     .die_boundary_after_row(1)
//!     ...
//! ```
//!
//! "Users can also customize the virtual device by specifying parameters
//! such as the FPGA device part number and the slot shapes. RIR then uses
//! vendor tools to extract the necessary resource information" — our
//! vendor-tool surrogate is the per-part resource database in
//! [`crate::device::builtin`]; custom parts specify capacities directly.

use crate::device::model::{Slot, VirtualDevice};
use crate::ir::core::Resources;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

pub struct DeviceBuilder {
    name: String,
    part: String,
    cols: usize,
    rows: usize,
    die_rows: Vec<usize>,
    uniform: Option<Resources>,
    /// (x, y) -> capacity override (e.g. shell/gap/HBM-adjacent slots).
    overrides: BTreeMap<(usize, usize), Resources>,
    /// (x, y) -> fraction of capacity removed (shell, gaps, hard IPs).
    derates: BTreeMap<(usize, usize), f64>,
    sll_per_column: u64,
    hwire: u64,
    vwire: u64,
}

impl DeviceBuilder {
    pub fn new(name: impl Into<String>, part: impl Into<String>) -> DeviceBuilder {
        DeviceBuilder {
            name: name.into(),
            part: part.into(),
            cols: 1,
            rows: 1,
            die_rows: Vec::new(),
            uniform: None,
            overrides: BTreeMap::new(),
            derates: BTreeMap::new(),
            sll_per_column: 7680,
            hwire: 20_000,
            vwire: 20_000,
        }
    }

    /// Slot grid: `cols` × `rows` pblocks.
    pub fn grid(mut self, cols: usize, rows: usize) -> Self {
        self.cols = cols;
        self.rows = rows;
        self
    }

    /// Declare a die boundary between `row` and `row + 1`.
    pub fn die_boundary_after_row(mut self, row: usize) -> Self {
        if !self.die_rows.contains(&row) {
            self.die_rows.push(row);
            self.die_rows.sort();
        }
        self
    }

    /// Same capacity in every slot.
    pub fn uniform_slot_capacity(mut self, r: Resources) -> Self {
        self.uniform = Some(r);
        self
    }

    /// Override one slot's capacity.
    pub fn slot_capacity(mut self, x: usize, y: usize, r: Resources) -> Self {
        self.overrides.insert((x, y), r);
        self
    }

    /// Remove a fraction of a slot's capacity (Vitis shell, gap regions,
    /// NoC columns, integrated IPs — the "unprogrammable" areas of Fig 2).
    pub fn derate_slot(mut self, x: usize, y: usize, fraction: f64) -> Self {
        self.derates.insert((x, y), fraction);
        self
    }

    /// Die-crossing wires per column per boundary (SLLs).
    pub fn sll_per_column(mut self, n: u64) -> Self {
        self.sll_per_column = n;
        self
    }

    pub fn wire_capacity(mut self, horizontal: u64, vertical: u64) -> Self {
        self.hwire = horizontal;
        self.vwire = vertical;
        self
    }

    pub fn build(self) -> Result<VirtualDevice> {
        if self.cols == 0 || self.rows == 0 {
            bail!("device grid must be at least 1x1");
        }
        let uniform = match self.uniform {
            Some(u) => u,
            None if !self.overrides.is_empty() => Resources::ZERO,
            None => bail!("no slot capacity specified"),
        };
        if let Some(&r) = self.die_rows.iter().find(|&&r| r + 1 >= self.rows) {
            bail!("die boundary after row {r} is outside the {}-row grid", self.rows);
        }
        let mut slots = Vec::with_capacity(self.cols * self.rows);
        for y in 0..self.rows {
            let die = self.die_rows.iter().filter(|&&r| r < y).count();
            for x in 0..self.cols {
                let mut cap = *self.overrides.get(&(x, y)).unwrap_or(&uniform);
                if let Some(d) = self.derates.get(&(x, y)) {
                    cap = cap.scale(1.0 - d.clamp(0.0, 1.0));
                }
                slots.push(Slot {
                    x,
                    y,
                    pblock: format!("SLOT_X{x}Y{y}"),
                    capacity: cap,
                    die,
                });
            }
        }
        Ok(VirtualDevice {
            name: self.name,
            part: self.part,
            cols: self.cols,
            rows: self.rows,
            slots,
            die_rows: self.die_rows,
            sll_per_column: self.sll_per_column,
            hwire_capacity: self.hwire,
            vwire_capacity: self.vwire,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_basic() {
        let d = DeviceBuilder::new("mini", "xcmini")
            .grid(2, 2)
            .uniform_slot_capacity(Resources::new(1000.0, 2000.0, 10.0, 20.0, 5.0))
            .build()
            .unwrap();
        assert_eq!(d.num_slots(), 4);
        assert_eq!(d.num_dies(), 1);
    }

    #[test]
    fn derate_applies() {
        let d = DeviceBuilder::new("m", "x")
            .grid(1, 2)
            .uniform_slot_capacity(Resources::new(1000.0, 0.0, 0.0, 0.0, 0.0))
            .derate_slot(0, 0, 0.25)
            .build()
            .unwrap();
        assert_eq!(d.slot(0, 0).capacity.lut, 750.0);
        assert_eq!(d.slot(0, 1).capacity.lut, 1000.0);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(DeviceBuilder::new("x", "y").grid(0, 1).build().is_err());
        assert!(DeviceBuilder::new("x", "y").grid(1, 1).build().is_err()); // no capacity
        assert!(DeviceBuilder::new("x", "y")
            .grid(1, 2)
            .uniform_slot_capacity(Resources::ZERO)
            .die_boundary_after_row(1) // would be outside grid
            .build()
            .is_err());
    }

    #[test]
    fn die_assignment() {
        let d = DeviceBuilder::new("x", "y")
            .grid(1, 4)
            .uniform_slot_capacity(Resources::new(1.0, 1.0, 1.0, 1.0, 1.0))
            .die_boundary_after_row(0)
            .die_boundary_after_row(2)
            .build()
            .unwrap();
        let dies: Vec<usize> = (0..4).map(|y| d.slot(0, y).die).collect();
        assert_eq!(dies, vec![0, 1, 1, 2]);
    }
}

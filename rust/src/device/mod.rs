//! Virtual device descriptions of multi-die FPGAs (§3.1) plus the
//! user-customizable builder API of Figure 7.

pub mod builder;
pub mod builtin;
pub mod model;

pub use builder::DeviceBuilder;
pub use builtin::by_name;
pub use model::{Slot, VirtualDevice};

//! Predefined virtual devices "for UltraScale+ and Versal, based on
//! empirical data" (§3.1). Capacities follow the public data sheets,
//! divided across the slot grid; shell/HBM/NoC regions are derated the way
//! AutoBridge/RapidStream model them. These feed the floorplanner and the
//! EDA simulator — absolute numbers matter less than the relative shape
//! (die counts, SLL limits, unusable regions).

use crate::device::builder::DeviceBuilder;
use crate::device::model::VirtualDevice;
use crate::ir::core::Resources;
use anyhow::{bail, Result};

/// All built-in device names, in the order used by Table 2.
pub const BUILTIN_NAMES: [&str; 6] = ["u250", "u280", "u55c", "vu9p", "vp1552", "vhk158"];

/// Look up a built-in device by (case-insensitive) name.
pub fn by_name(name: &str) -> Result<VirtualDevice> {
    match name.to_ascii_lowercase().as_str() {
        "u250" => u250(),
        "u280" => u280(),
        "u55c" => u55c(),
        "vu9p" => vu9p(),
        "vp1552" => vp1552(),
        "vhk158" => vhk158(),
        other => bail!(
            "unknown device '{other}' (builtins: {})",
            BUILTIN_NAMES.join(", ")
        ),
    }
}

/// AMD Alveo U250 — four SLRs (dies), no HBM. The Vitis shell occupies a
/// part of SLR1's right column.
pub fn u250() -> Result<VirtualDevice> {
    DeviceBuilder::new("u250", "xcu250-figd2104-2L-e")
        .grid(2, 4)
        .die_boundary_after_row(0)
        .die_boundary_after_row(1)
        .die_boundary_after_row(2)
        .uniform_slot_capacity(Resources::new(216e3, 432e3, 336.0, 1536.0, 160.0))
        .derate_slot(1, 1, 0.30) // static region / shell
        .sll_per_column(11520)
        .wire_capacity(22_000, 22_000)
        .build()
}

/// AMD Alveo U280 — three SLRs, HBM2 on the bottom edge, gap regions in
/// the centre columns (Fig 2 shows the U55C sibling).
pub fn u280() -> Result<VirtualDevice> {
    DeviceBuilder::new("u280", "xcu280-fsvh2892-2L-e")
        .grid(2, 3)
        .die_boundary_after_row(0)
        .die_boundary_after_row(1)
        .uniform_slot_capacity(Resources::new(217e3, 434e3, 336.0, 1504.0, 160.0))
        .derate_slot(0, 0, 0.15) // HBM controller columns
        .derate_slot(1, 0, 0.35) // HBM + shell
        .sll_per_column(11520)
        .wire_capacity(21_000, 21_000)
        .build()
}

/// AMD Alveo U55C — same fabric family as U280, 32 HBM channels at the
/// bottom, unprogrammable gap regions in the centre (Fig 2(1)).
pub fn u55c() -> Result<VirtualDevice> {
    DeviceBuilder::new("u55c", "xcu55c-fsvh2892-2L-e")
        .grid(2, 3)
        .die_boundary_after_row(0)
        .die_boundary_after_row(1)
        .uniform_slot_capacity(Resources::new(217e3, 434e3, 336.0, 1504.0, 160.0))
        .derate_slot(0, 0, 0.20) // 32-channel HBM switch
        .derate_slot(1, 0, 0.30) // HBM + shell
        .derate_slot(0, 1, 0.05) // centre gap columns
        .derate_slot(1, 1, 0.05)
        .sll_per_column(11520)
        .wire_capacity(21_000, 21_000)
        .build()
}

/// AMD Virtex UltraScale+ VU9P — three SLRs, no HBM (classic F1-style
/// part used by Minimap2's original target).
pub fn vu9p() -> Result<VirtualDevice> {
    DeviceBuilder::new("vu9p", "xcvu9p-flga2104-2L-e")
        .grid(2, 3)
        .die_boundary_after_row(0)
        .die_boundary_after_row(1)
        .uniform_slot_capacity(Resources::new(197e3, 394e3, 360.0, 1140.0, 160.0))
        .derate_slot(1, 1, 0.20) // shell
        .sll_per_column(11520)
        .wire_capacity(20_000, 20_000)
        .build()
}

/// AMD Versal Premium VP1552 — two dies; the paper's Figure 7 virtual
/// device: two columns × four rows, each slot one quarter of a die.
/// NoC columns and the integrated ARM/PCIe blocks cut into the fabric.
pub fn vp1552() -> Result<VirtualDevice> {
    DeviceBuilder::new("vp1552", "xcvp1552-vsva3340-2MHP-i-S")
        .grid(2, 4)
        .die_boundary_after_row(1)
        .uniform_slot_capacity(Resources::new(175e3, 350e3, 336.0, 788.0, 116.0))
        .derate_slot(0, 0, 0.15) // CPM/PCIe + NoC entry
        .derate_slot(1, 0, 0.10) // ARM PS + NoC
        .derate_slot(0, 2, 0.05) // NoC column discontinuity
        .derate_slot(1, 2, 0.05)
        .sll_per_column(15360) // Versal interposer is wider than US+ SLLs
        .wire_capacity(24_000, 24_000)
        .build()
}

/// AMD Versal HBM VHK158 — two dies with HBM2e stacks on the bottom edge.
pub fn vhk158() -> Result<VirtualDevice> {
    DeviceBuilder::new("vhk158", "xcvh1582-vsva3697-2MP-e-S")
        .grid(2, 4)
        .die_boundary_after_row(1)
        .uniform_slot_capacity(Resources::new(203e3, 406e3, 335.0, 976.0, 139.0))
        .derate_slot(0, 0, 0.25) // HBM controllers
        .derate_slot(1, 0, 0.25)
        .derate_slot(0, 2, 0.05) // NoC columns
        .derate_slot(1, 2, 0.05)
        .sll_per_column(15360)
        .wire_capacity(24_000, 24_000)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_builtins_construct() {
        for name in BUILTIN_NAMES {
            let d = by_name(name).unwrap();
            assert_eq!(d.name, name);
            assert!(d.num_slots() >= 6);
            assert!(d.total_capacity().lut > 1e6, "{name} too small");
        }
    }

    #[test]
    fn die_counts_match_paper() {
        assert_eq!(by_name("u250").unwrap().num_dies(), 4);
        assert_eq!(by_name("u280").unwrap().num_dies(), 3);
        assert_eq!(by_name("u55c").unwrap().num_dies(), 3);
        assert_eq!(by_name("vu9p").unwrap().num_dies(), 3);
        assert_eq!(by_name("vp1552").unwrap().num_dies(), 2);
        assert_eq!(by_name("vhk158").unwrap().num_dies(), 2);
    }

    #[test]
    fn unknown_device_rejected() {
        assert!(by_name("u9000").is_err());
    }

    #[test]
    fn derates_reduce_capacity() {
        let d = u280().unwrap();
        // Bottom-right (HBM+shell) strictly smaller than top-left.
        assert!(d.slot(1, 0).capacity.lut < d.slot(0, 2).capacity.lut);
    }

    #[test]
    fn json_roundtrip_all() {
        for name in BUILTIN_NAMES {
            let d = by_name(name).unwrap();
            let d2 = VirtualDevice::from_json(&d.to_json()).unwrap();
            assert_eq!(d, d2);
        }
    }
}

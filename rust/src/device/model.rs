//! Virtual device model (§3.1 "Virtual Device Definition").
//!
//! A [`VirtualDevice`] divides a physical FPGA into a grid of **slots**
//! (Vivado pblocks). It records per-slot resource capacity, unusable
//! regions (Vitis shell, gap columns, hard IPs), die boundaries with their
//! limited die-crossing wire capacity (SLLs on UltraScale+, SLR bridges on
//! Versal), and slot geometry for distance computation.

use crate::ir::core::Resources;
use crate::util::json::{Json, JsonObj};
use anyhow::{anyhow, Result};

/// One floorplanning slot (a pblock).
#[derive(Debug, Clone, PartialEq)]
pub struct Slot {
    /// Grid position, x = column, y = row (row 0 at the bottom).
    pub x: usize,
    pub y: usize,
    /// Vivado-style pblock name, e.g. "SLOT_X1Y2".
    pub pblock: String,
    /// Usable resource capacity (already net of shell/gap regions).
    pub capacity: Resources,
    /// Die index this slot belongs to.
    pub die: usize,
}

/// A multi-die FPGA as seen by the floorplanner.
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualDevice {
    pub name: String,
    /// Vendor part number, e.g. "xcu280-fsvh2892-2L-e".
    pub part: String,
    pub cols: usize,
    pub rows: usize,
    /// Row-major (y * cols + x).
    pub slots: Vec<Slot>,
    /// Rows r such that a die boundary lies between row r and row r+1.
    pub die_rows: Vec<usize>,
    /// Die-crossing wires available per (column, boundary) pair.
    pub sll_per_column: u64,
    /// Routing wires available between horizontally adjacent slots.
    pub hwire_capacity: u64,
    /// Routing wires available between vertically adjacent slots
    /// (same die).
    pub vwire_capacity: u64,
}

impl VirtualDevice {
    pub fn slot(&self, x: usize, y: usize) -> &Slot {
        &self.slots[y * self.cols + x]
    }

    pub fn slot_index(&self, x: usize, y: usize) -> usize {
        y * self.cols + x
    }

    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn num_dies(&self) -> usize {
        self.die_rows.len() + 1
    }

    /// Number of die boundaries crossed moving from row y0 to row y1.
    pub fn die_crossings(&self, y0: usize, y1: usize) -> usize {
        let (lo, hi) = if y0 <= y1 { (y0, y1) } else { (y1, y0) };
        self.die_rows.iter().filter(|&&r| lo <= r && r < hi).count()
    }

    /// Manhattan slot distance plus the number of die crossings — the unit
    /// used by the wirelength objective and the delay model.
    pub fn slot_dist(&self, a: usize, b: usize) -> (usize, usize) {
        let (ax, ay) = (self.slots[a].x, self.slots[a].y);
        let (bx, by) = (self.slots[b].x, self.slots[b].y);
        let manhattan = ax.abs_diff(bx) + ay.abs_diff(by);
        (manhattan, self.die_crossings(ay, by))
    }

    /// Total device capacity.
    pub fn total_capacity(&self) -> Resources {
        self.slots
            .iter()
            .fold(Resources::ZERO, |acc, s| acc.add(&s.capacity))
    }

    /// FNV-1a fingerprint over every field that influences placement,
    /// timing, or routability — the device component of the incremental
    /// re-flow memo keys. Floats enter by bit pattern.
    pub fn fingerprint(&self) -> u64 {
        let mut f = crate::ir::digest::Fnv::new();
        f.write_str(&self.name).write_str(&self.part);
        f.write_usize(self.cols).write_usize(self.rows);
        for s in &self.slots {
            f.write_usize(s.x).write_usize(s.y).write_str(&s.pblock);
            f.write_f64(s.capacity.lut)
                .write_f64(s.capacity.ff)
                .write_f64(s.capacity.bram)
                .write_f64(s.capacity.dsp)
                .write_f64(s.capacity.uram);
            f.write_usize(s.die);
        }
        for &r in &self.die_rows {
            f.write_usize(r);
        }
        f.write_u64(self.sll_per_column)
            .write_u64(self.hwire_capacity)
            .write_u64(self.vwire_capacity);
        f.finish()
    }

    /// Coarsen the slot grid by merging groups of `factor` horizontally
    /// adjacent columns into one slot each — the DSE's pblock-granularity
    /// knob. Capacities sum; inter-slot wire capacities scale by `factor`
    /// (each merged boundary aggregates `factor` old columns' wires).
    /// Die boundaries are row-based, so column merging never crosses a
    /// die. `factor == 1` returns the device unchanged (same name, same
    /// [`fingerprint`](Self::fingerprint)); any coarser grid gets a
    /// `-g{factor}` name suffix so memo keys never collide across grids.
    pub fn coarsen_columns(&self, factor: usize) -> Result<VirtualDevice> {
        if factor == 0 {
            return Err(anyhow!("grid factor must be >= 1"));
        }
        if factor == 1 {
            return Ok(self.clone());
        }
        if self.cols % factor != 0 {
            return Err(anyhow!(
                "grid factor {factor} does not divide {} columns of '{}'",
                self.cols,
                self.name
            ));
        }
        let cols = self.cols / factor;
        let mut slots = Vec::with_capacity(cols * self.rows);
        for y in 0..self.rows {
            for x in 0..cols {
                let mut capacity = Resources::ZERO;
                for dx in 0..factor {
                    capacity = capacity.add(&self.slot(x * factor + dx, y).capacity);
                }
                slots.push(Slot {
                    x,
                    y,
                    pblock: format!("SLOT_X{x}Y{y}"),
                    capacity,
                    die: self.slot(x * factor, y).die,
                });
            }
        }
        Ok(VirtualDevice {
            name: format!("{}-g{factor}", self.name),
            part: self.part.clone(),
            cols,
            rows: self.rows,
            slots,
            die_rows: self.die_rows.clone(),
            sll_per_column: self.sll_per_column * factor as u64,
            hwire_capacity: self.hwire_capacity * factor as u64,
            vwire_capacity: self.vwire_capacity * factor as u64,
        })
    }

    /// Flattened f32 distance matrix (S×S) in row-major order, where
    /// dist = manhattan + `die_weight` × die_crossings. Fed to the
    /// PJRT-compiled floorplan-cost kernel.
    pub fn distance_matrix(&self, die_weight: f32) -> Vec<f32> {
        let s = self.num_slots();
        let mut m = vec![0f32; s * s];
        for a in 0..s {
            for b in 0..s {
                let (man, dies) = self.slot_dist(a, b);
                m[a * s + b] = man as f32 + die_weight * dies as f32;
            }
        }
        m
    }

    /// Per-slot capacity matrix (S×5) row-major [LUT, FF, BRAM, DSP, URAM].
    pub fn capacity_matrix(&self) -> Vec<f32> {
        let mut m = Vec::with_capacity(self.num_slots() * 5);
        for s in &self.slots {
            m.extend_from_slice(&[
                s.capacity.lut as f32,
                s.capacity.ff as f32,
                s.capacity.bram as f32,
                s.capacity.dsp as f32,
                s.capacity.uram as f32,
            ]);
        }
        m
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("name", Json::str(&self.name));
        o.insert("part", Json::str(&self.part));
        o.insert("cols", Json::num(self.cols as f64));
        o.insert("rows", Json::num(self.rows as f64));
        o.insert(
            "die_rows",
            Json::Arr(self.die_rows.iter().map(|r| Json::num(*r as f64)).collect()),
        );
        o.insert("sll_per_column", Json::num(self.sll_per_column as f64));
        o.insert("hwire_capacity", Json::num(self.hwire_capacity as f64));
        o.insert("vwire_capacity", Json::num(self.vwire_capacity as f64));
        o.insert(
            "slots",
            Json::Arr(
                self.slots
                    .iter()
                    .map(|s| {
                        let mut so = JsonObj::new();
                        so.insert("x", Json::num(s.x as f64));
                        so.insert("y", Json::num(s.y as f64));
                        so.insert("pblock", Json::str(&s.pblock));
                        so.insert("die", Json::num(s.die as f64));
                        so.insert(
                            "capacity",
                            crate::ir::builder::resources_to_json(&s.capacity),
                        );
                        Json::Obj(so)
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<VirtualDevice> {
        let gs = |k: &str| {
            j.at(k)
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow!("device missing '{k}'"))
        };
        let gn = |k: &str| {
            j.at(k)
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("device missing '{k}'"))
        };
        let mut slots = Vec::new();
        for sj in j
            .at("slots")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("device missing slots"))?
        {
            slots.push(Slot {
                x: sj.at("x").and_then(|v| v.as_usize()).unwrap_or(0),
                y: sj.at("y").and_then(|v| v.as_usize()).unwrap_or(0),
                pblock: sj
                    .at("pblock")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                die: sj.at("die").and_then(|v| v.as_usize()).unwrap_or(0),
                capacity: sj
                    .at("capacity")
                    .map(crate::ir::builder::resources_from_json)
                    .unwrap_or(Resources::ZERO),
            });
        }
        Ok(VirtualDevice {
            name: gs("name")?,
            part: gs("part")?,
            cols: gn("cols")? as usize,
            rows: gn("rows")? as usize,
            slots,
            die_rows: j
                .at("die_rows")
                .and_then(|d| d.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                .unwrap_or_default(),
            sll_per_column: gn("sll_per_column")?,
            hwire_capacity: gn("hwire_capacity")?,
            vwire_capacity: gn("vwire_capacity")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builder::DeviceBuilder;

    fn dev() -> VirtualDevice {
        DeviceBuilder::new("test", "xctest")
            .grid(2, 4)
            .die_boundary_after_row(1)
            .die_boundary_after_row(2)
            .uniform_slot_capacity(Resources::new(100e3, 200e3, 300.0, 1500.0, 100.0))
            .sll_per_column(5000)
            .build()
            .unwrap()
    }

    #[test]
    fn grid_layout() {
        let d = dev();
        assert_eq!(d.num_slots(), 8);
        assert_eq!(d.num_dies(), 3);
        assert_eq!(d.slot(1, 3).pblock, "SLOT_X1Y3");
        assert_eq!(d.slot(0, 0).die, 0);
        assert_eq!(d.slot(0, 2).die, 1);
        assert_eq!(d.slot(0, 3).die, 2);
    }

    #[test]
    fn die_crossings_counted() {
        let d = dev();
        assert_eq!(d.die_crossings(0, 0), 0);
        assert_eq!(d.die_crossings(0, 1), 0); // boundary after row 1
        assert_eq!(d.die_crossings(1, 2), 1);
        assert_eq!(d.die_crossings(0, 3), 2);
        assert_eq!(d.die_crossings(3, 0), 2); // symmetric
    }

    #[test]
    fn slot_distance() {
        let d = dev();
        let a = d.slot_index(0, 0);
        let b = d.slot_index(1, 3);
        assert_eq!(d.slot_dist(a, b), (4, 2));
        assert_eq!(d.slot_dist(a, a), (0, 0));
    }

    #[test]
    fn distance_matrix_symmetry() {
        let d = dev();
        let m = d.distance_matrix(3.0);
        let s = d.num_slots();
        for a in 0..s {
            assert_eq!(m[a * s + a], 0.0);
            for b in 0..s {
                assert_eq!(m[a * s + b], m[b * s + a]);
            }
        }
        // (0,0) -> (0,2): manhattan 2 + 1 die crossing * 3.0
        let a = d.slot_index(0, 0);
        let b = d.slot_index(0, 2);
        assert_eq!(m[a * s + b], 5.0);
    }

    #[test]
    fn json_roundtrip() {
        let d = dev();
        let j = d.to_json();
        let d2 = VirtualDevice::from_json(&j).unwrap();
        assert_eq!(d, d2);
    }

    #[test]
    fn total_capacity_sums() {
        let d = dev();
        let t = d.total_capacity();
        assert_eq!(t.lut, 800e3);
        assert_eq!(t.dsp, 12000.0);
    }

    #[test]
    fn coarsen_columns_merges_capacity_and_scales_wires() {
        let d = dev();
        let c = d.coarsen_columns(2).unwrap();
        assert_eq!(c.name, "test-g2");
        assert_eq!((c.cols, c.rows), (1, 4));
        assert_eq!(c.num_slots(), 4);
        assert_eq!(c.slot(0, 3).pblock, "SLOT_X0Y3");
        // Capacities sum; the device total is preserved exactly.
        assert_eq!(c.slot(0, 0).capacity.lut, 200e3);
        assert_eq!(c.total_capacity(), d.total_capacity());
        // Die structure is row-based and survives column merging.
        assert_eq!(c.die_rows, d.die_rows);
        assert_eq!(c.slot(0, 2).die, 1);
        // Merged boundaries aggregate the old columns' wires.
        assert_eq!(c.sll_per_column, 2 * d.sll_per_column);
        assert_eq!(c.hwire_capacity, 2 * d.hwire_capacity);
        assert_eq!(c.vwire_capacity, 2 * d.vwire_capacity);
        // Memo keys must never collide across grids.
        assert_ne!(c.fingerprint(), d.fingerprint());
    }

    #[test]
    fn coarsen_columns_identity_and_errors() {
        let d = dev();
        let same = d.coarsen_columns(1).unwrap();
        assert_eq!(same, d);
        assert_eq!(same.fingerprint(), d.fingerprint());
        assert!(d.coarsen_columns(0).is_err());
        // 2 columns don't split into groups of 3.
        assert!(d.coarsen_columns(3).is_err());
    }
}

//! # RapidStream IR
//!
//! A from-scratch reproduction of *RapidStream IR: Infrastructure for FPGA
//! High-Level Physical Synthesis* (ICCAD '24). The crate provides:
//!
//! * [`ir`] — the coarse-grained intermediate representation: leaf/grouped
//!   modules, ports, wires, interfaces (handshake / feedforward), metadata,
//!   JSON schema round-trip and DRC validation;
//! * [`verilog`] — a Verilog-subset lexer/parser/printer/rewriter used by
//!   the importers and the hierarchy-rebuild pass;
//! * [`plugins`] — importers (Verilog, XCI/XO surrogates, HLS reports,
//!   pragma + regex interface rules), exporters (Verilog + constraints),
//!   and the platform analyzer;
//! * [`passes`] — the composable transformation passes of §3.3;
//! * [`device`] — virtual device descriptions of multi-die FPGAs;
//! * [`ilp`] — an exact ILP solver (simplex + branch & bound);
//! * [`floorplan`] — the AutoBridge-style ILP floorplanner and the batched
//!   simulated-annealing explorer (PJRT-accelerated);
//! * [`timing`] / [`eda`] — the simulated vendor backend: synthesis
//!   resource estimation, placement, routing congestion, and STA;
//! * [`interconnect`] — pipeline element templates (relay station,
//!   almost-full FIFO, FF chains);
//! * [`designs`] — benchmark design generators (CNN systolic arrays,
//!   LLaMA2 hybrid accelerator, Minimap2, KNN, Dynamatic / Catapult /
//!   Intel-HLS style RTL) plus the seeded synthetic-design generator;
//! * [`testing`] — the differential oracle suite and the seeded fuzz
//!   driver behind `rsir fuzz` and the scheduled CI fuzz job;
//! * [`coordinator`] — the four-stage HLPS flow of §3.4 and the parallel
//!   synthesis driver of §4.3;
//! * [`server`] — `rsir serve`, the resident compilation daemon: a
//!   line-delimited JSON protocol, a bounded deterministic job queue,
//!   and warm cross-request caches whose results are byte-identical to
//!   the one-shot CLI;
//! * [`runtime`] — the PJRT loader executing AOT-compiled JAX/Pallas
//!   artifacts from the floorplan hot path.

pub mod coordinator;
pub mod designs;
pub mod device;
pub mod eda;
pub mod floorplan;
pub mod ilp;
pub mod interconnect;
pub mod ir;
pub mod passes;
pub mod plugins;
pub mod runtime;
pub mod server;
pub mod testing;
pub mod timing;
pub mod util;
pub mod verilog;

//! Verilog lexer.
//!
//! Produces a token stream with byte spans so the parser can recover the
//! *exact original text* of any region — essential because RIR keeps
//! residual logic (always blocks, assigns it does not understand) verbatim
//! (§3.1: "It keeps the original fine-grained logic intact if it is unused
//! in the passes").

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (Verilog keywords are contextual here).
    Id(String),
    /// Numeric literal, raw text (e.g. `8'd255`, `32'hDEAD_BEEF`, `42`).
    Num(String),
    /// String literal, raw text including quotes.
    Str(String),
    /// Operator / punctuation, one to three chars (`<=`, `===`, `(`, …).
    Sym(String),
}

impl Tok {
    pub fn id(&self) -> Option<&str> {
        match self {
            Tok::Id(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_sym(&self, s: &str) -> bool {
        matches!(self, Tok::Sym(x) if x == s)
    }

    pub fn is_id(&self, s: &str) -> bool {
        matches!(self, Tok::Id(x) if x == s)
    }
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Id(s) | Tok::Num(s) | Tok::Str(s) | Tok::Sym(s) => f.write_str(s),
        }
    }
}

/// A token plus its byte span in the source.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpannedTok {
    pub tok: Tok,
    pub start: usize,
    pub end: usize,
    pub line: usize,
    /// 1-based column of the token's first character on `line`.
    pub col: usize,
}

/// Lexer error (unterminated string/comment).
#[derive(Debug, Clone)]
pub struct LexError {
    pub msg: String,
    pub line: usize,
    /// 1-based column where the offending construct starts.
    pub col: usize,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lex error at line {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for LexError {}

/// Tokenize Verilog source. Comments and whitespace are skipped; comments
/// carrying `pragma` directives are handled separately by scanning the raw
/// source (see `plugins::pragma`).
pub fn lex(src: &str) -> Result<Vec<SpannedTok>, LexError> {
    let b = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    let mut line = 1;
    let mut line_start = 0usize;
    let n = b.len();
    while i < n {
        let c = b[i];
        let col = i - line_start + 1;
        match c {
            b'\n' => {
                line += 1;
                i += 1;
                line_start = i;
            }
            c if c.is_ascii_whitespace() => i += 1,
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                while i < n && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let start_line = line;
                i += 2;
                loop {
                    if i + 1 >= n {
                        return Err(LexError {
                            msg: "unterminated block comment".into(),
                            line: start_line,
                            col,
                        });
                    }
                    if b[i] == b'\n' {
                        line += 1;
                        line_start = i + 1;
                    }
                    if b[i] == b'*' && b[i + 1] == b'/' {
                        i += 2;
                        break;
                    }
                    i += 1;
                }
            }
            b'"' => {
                let start = i;
                let start_line = line;
                i += 1;
                while i < n && b[i] != b'"' {
                    if b[i] == b'\\' {
                        i += 1;
                    }
                    if i < n && b[i] == b'\n' {
                        line += 1;
                        line_start = i + 1;
                    }
                    i += 1;
                }
                if i >= n {
                    return Err(LexError {
                        msg: "unterminated string".into(),
                        line: start_line,
                        col,
                    });
                }
                i += 1; // closing quote
                out.push(SpannedTok {
                    tok: Tok::Str(src[start..i].to_string()),
                    start,
                    end: i,
                    line: start_line,
                    col,
                });
            }
            c if c.is_ascii_alphabetic() || c == b'_' || c == b'\\' => {
                let start = i;
                if c == b'\\' {
                    // Escaped identifier: up to whitespace.
                    i += 1;
                    while i < n && !b[i].is_ascii_whitespace() {
                        i += 1;
                    }
                } else {
                    while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_' || b[i] == b'$') {
                        i += 1;
                    }
                }
                out.push(SpannedTok {
                    tok: Tok::Id(src[start..i].to_string()),
                    start,
                    end: i,
                    line,
                    col,
                });
            }
            c if c.is_ascii_digit() => {
                let start = i;
                // number: [size]['base]digits with _ allowed; also plain ints
                // and reals. We scan greedily over number-ish chars.
                while i < n
                    && (b[i].is_ascii_alphanumeric()
                        || b[i] == b'_'
                        || b[i] == b'\''
                        || (b[i] == b'.' && i + 1 < n && b[i + 1].is_ascii_digit()))
                {
                    i += 1;
                }
                out.push(SpannedTok {
                    tok: Tok::Num(src[start..i].to_string()),
                    start,
                    end: i,
                    line,
                    col,
                });
            }
            b'\'' => {
                // unsized based literal like 'd0 / '0 / 'b1
                let start = i;
                i += 1;
                while i < n && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                out.push(SpannedTok {
                    tok: Tok::Num(src[start..i].to_string()),
                    start,
                    end: i,
                    line,
                    col,
                });
            }
            b'`' => {
                // compiler directive — treat the whole line as a symbol token
                let start = i;
                while i < n && b[i] != b'\n' {
                    i += 1;
                }
                out.push(SpannedTok {
                    tok: Tok::Sym(src[start..i].to_string()),
                    start,
                    end: i,
                    line,
                    col,
                });
            }
            _ => {
                let start = i;
                // Multi-char operators, longest first.
                let rest = &src[i..];
                let ops3 = ["===", "!==", "<<<", ">>>", "<->"];
                let ops2 = [
                    "==", "!=", "<=", ">=", "&&", "||", "<<", ">>", "**", "+:", "-:", "::", "->",
                ];
                let len = ops3
                    .iter()
                    .find(|o| rest.starts_with(**o))
                    .map(|_| 3)
                    .or_else(|| ops2.iter().find(|o| rest.starts_with(**o)).map(|_| 2))
                    .unwrap_or(1);
                i += len;
                out.push(SpannedTok {
                    tok: Tok::Sym(src[start..i].to_string()),
                    start,
                    end: i,
                    line,
                    col,
                });
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lex_module_header() {
        let t = toks("module FIFO (input wire [63:0] I);");
        assert_eq!(t[0], Tok::Id("module".into()));
        assert_eq!(t[1], Tok::Id("FIFO".into()));
        assert!(t.iter().any(|x| x.is_sym("[")));
        assert!(t.contains(&Tok::Num("63".into())));
    }

    #[test]
    fn lex_skips_comments() {
        let t = toks("a // line comment\nb /* block\ncomment */ c");
        assert_eq!(
            t,
            vec![
                Tok::Id("a".into()),
                Tok::Id("b".into()),
                Tok::Id("c".into())
            ]
        );
    }

    #[test]
    fn lex_sized_literals() {
        let t = toks("assign x = 8'd255 + 32'hDEAD_BEEF;");
        assert!(t.contains(&Tok::Num("8'd255".into())));
        assert!(t.contains(&Tok::Num("32'hDEAD_BEEF".into())));
    }

    #[test]
    fn lex_multichar_ops() {
        let t = toks("a <= b == c <<< 2");
        assert!(t.iter().any(|x| x.is_sym("<=")));
        assert!(t.iter().any(|x| x.is_sym("==")));
        assert!(t.iter().any(|x| x.is_sym("<<<")));
    }

    #[test]
    fn lex_strings_and_lines() {
        let st = lex("x \"he // llo\" y").unwrap();
        assert_eq!(st[1].tok, Tok::Str("\"he // llo\"".into()));
        let st2 = lex("a\nb\nc").unwrap();
        assert_eq!(st2[2].line, 3);
    }

    #[test]
    fn lex_errors() {
        assert!(lex("\"unterminated").is_err());
        assert!(lex("/* unterminated").is_err());
    }

    #[test]
    fn columns_tracked_per_line() {
        let st = lex("ab cd\n  efg \"s\" hi").unwrap();
        assert_eq!((st[0].line, st[0].col), (1, 1)); // ab
        assert_eq!((st[1].line, st[1].col), (1, 4)); // cd
        assert_eq!((st[2].line, st[2].col), (2, 3)); // efg
        assert_eq!((st[3].line, st[3].col), (2, 7)); // "s"
        assert_eq!((st[4].line, st[4].col), (2, 11)); // hi
        let e = lex("x\n  \"oops").unwrap_err();
        assert_eq!((e.line, e.col), (2, 3));
        assert!(e.to_string().contains("2:3"));
    }

    #[test]
    fn spans_recover_source() {
        let src = "module  Foo   (a, b);";
        let st = lex(src).unwrap();
        let foo = &st[1];
        assert_eq!(&src[foo.start..foo.end], "Foo");
    }
}

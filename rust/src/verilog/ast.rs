//! Verilog AST — deliberately *structural*, not elaborated.
//!
//! Mirroring the paper's design principle ("Directly analyzing LLM's
//! interconnect is challenging due to the complexity of its source format
//! … requiring a full elaborator. Maintaining and updating such an
//! elaborator … would be labor-intensive"), the AST models precisely what
//! the RIR passes need — module signatures, net declarations, `assign`
//! statements, and submodule instantiations — and preserves everything
//! else (always blocks, functions, generate regions) as verbatim
//! [`VItem::Raw`] text.

use crate::ir::core::Dir;

/// A parameter declaration: `parameter WIDTH = 64`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VParam {
    pub name: String,
    /// Raw default-value text.
    pub default: String,
}

/// A port in the module signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VPort {
    pub name: String,
    pub dir: Dir,
    pub width: u32,
    /// `wire` or `reg` (output reg).
    pub net: String,
}

/// A net declaration: `wire [63:0] a, b;`
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VNet {
    pub kind: String,
    pub width: u32,
    pub names: Vec<String>,
}

/// A continuous assignment, raw expression text on both sides.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VAssign {
    pub lhs: String,
    pub rhs: String,
}

/// A submodule instantiation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VInst {
    pub module: String,
    pub name: String,
    /// `#(.P(V))` parameter overrides, raw value text.
    pub params: Vec<(String, String)>,
    /// Named connections `.port(expr)`; `expr` is raw text, empty for
    /// explicitly open `.port()`. Positional connections get port `""`.
    pub conns: Vec<(String, String)>,
}

impl VInst {
    pub fn conn(&self, port: &str) -> Option<&str> {
        self.conns
            .iter()
            .find(|(p, _)| p == port)
            .map(|(_, e)| e.as_str())
    }
}

/// A module item.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VItem {
    Net(VNet),
    Assign(VAssign),
    Instance(VInst),
    /// Verbatim source text for anything the structural parser does not
    /// model: always/initial blocks, functions, tasks, generate regions,
    /// localparams, arrayed nets, etc.
    Raw(String),
}

/// A parsed Verilog module.
#[derive(Debug, Clone)]
pub struct VModule {
    pub name: String,
    pub params: Vec<VParam>,
    pub ports: Vec<VPort>,
    pub items: Vec<VItem>,
    /// Byte span `[start, end)` of this module in the original source,
    /// from the `module` keyword through `endmodule` inclusive. `(0, 0)`
    /// for synthesized (non-parsed) modules. Ignored by equality so that
    /// print→parse round trips compare structurally.
    pub span: (usize, usize),
}

impl PartialEq for VModule {
    fn eq(&self, other: &Self) -> bool {
        self.name == other.name
            && self.params == other.params
            && self.ports == other.ports
            && self.items == other.items
    }
}

impl Eq for VModule {}

impl VModule {
    pub fn new(name: impl Into<String>) -> VModule {
        VModule {
            name: name.into(),
            params: Vec::new(),
            ports: Vec::new(),
            items: Vec::new(),
            span: (0, 0),
        }
    }

    /// The module's own source text: the `span` slice of `src` when the
    /// module was parsed from it, or the whole string as a fallback for
    /// spans that are absent or out of bounds.
    pub fn source_slice<'s>(&self, src: &'s str) -> &'s str {
        let (a, b) = self.span;
        if a < b && b <= src.len() && src.is_char_boundary(a) && src.is_char_boundary(b) {
            &src[a..b]
        } else {
            src
        }
    }

    pub fn port(&self, name: &str) -> Option<&VPort> {
        self.ports.iter().find(|p| p.name == name)
    }

    pub fn instances(&self) -> impl Iterator<Item = &VInst> {
        self.items.iter().filter_map(|i| match i {
            VItem::Instance(inst) => Some(inst),
            _ => None,
        })
    }

    pub fn nets(&self) -> impl Iterator<Item = &VNet> {
        self.items.iter().filter_map(|i| match i {
            VItem::Net(n) => Some(n),
            _ => None,
        })
    }

    pub fn assigns(&self) -> impl Iterator<Item = &VAssign> {
        self.items.iter().filter_map(|i| match i {
            VItem::Assign(a) => Some(a),
            _ => None,
        })
    }

    /// Width of an identifier if declared as a net or port here.
    pub fn width_of(&self, id: &str) -> Option<u32> {
        if let Some(p) = self.port(id) {
            return Some(p.width);
        }
        self.nets()
            .find(|n| n.names.iter().any(|x| x == id))
            .map(|n| n.width)
    }
}

/// A parsed source file: one or more modules.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct VFile {
    pub modules: Vec<VModule>,
}

impl VFile {
    pub fn module(&self, name: &str) -> Option<&VModule> {
        self.modules.iter().find(|m| m.name == name)
    }
}

/// Extract the identifiers referenced in a raw expression string.
/// Used for connectivity analysis of residual logic: identifiers that
/// co-occur in one statement are conservatively considered connected.
pub fn expr_identifiers(expr: &str) -> Vec<String> {
    let mut out = Vec::new();
    let b = expr.as_bytes();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c.is_ascii_alphabetic() || c == b'_' {
            let start = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_' || b[i] == b'$') {
                i += 1;
            }
            let id = &expr[start..i];
            // Skip sized-literal bases like 8'd0 handled below, and keywords
            // that appear inside expressions.
            if !matches!(
                id,
                "posedge" | "negedge" | "or" | "and" | "begin" | "end" | "if" | "else"
            ) {
                out.push(id.to_string());
            }
        } else if c.is_ascii_digit() {
            // skip numbers incl. sized literals (8'hFF)
            while i < b.len()
                && (b[i].is_ascii_alphanumeric() || b[i] == b'_' || b[i] == b'\'')
            {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out.dedup();
    out
}

/// True if the expression is a single plain identifier.
pub fn is_single_identifier(expr: &str) -> bool {
    let t = expr.trim();
    !t.is_empty()
        && t.chars().next().map(|c| c.is_ascii_alphabetic() || c == '_').unwrap_or(false)
        && t.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '$')
}

/// Parse a Verilog constant literal like `8'd42`, `1'b0`, `42`.
pub fn parse_literal(expr: &str) -> Option<(u32, u64)> {
    let t = expr.trim().replace('_', "");
    if let Some(apos) = t.find('\'') {
        let width: u32 = t[..apos].parse().ok()?;
        let rest = &t[apos + 1..];
        let (base, digits) = rest.split_at(1);
        let radix = match base {
            "d" | "D" => 10,
            "h" | "H" => 16,
            "b" | "B" => 2,
            "o" | "O" => 8,
            _ => return None,
        };
        let value = u64::from_str_radix(digits, radix).ok()?;
        Some((width, value))
    } else {
        t.parse::<u64>().ok().map(|v| (32, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expr_identifier_extraction() {
        let ids = expr_identifiers("(a & b_2) | {c, 8'hFF} + d$x");
        assert_eq!(ids, vec!["a", "b_2", "c", "d$x"]);
    }

    #[test]
    fn single_identifier_detection() {
        assert!(is_single_identifier(" foo_bar "));
        assert!(!is_single_identifier("a + b"));
        assert!(!is_single_identifier("a[3]"));
        assert!(!is_single_identifier("8'd0"));
        assert!(!is_single_identifier(""));
    }

    #[test]
    fn literal_parsing() {
        assert_eq!(parse_literal("8'd42"), Some((8, 42)));
        assert_eq!(parse_literal("1'b1"), Some((1, 1)));
        assert_eq!(parse_literal("16'hBEEF"), Some((16, 0xBEEF)));
        assert_eq!(parse_literal("32'hDEAD_BEEF"), Some((32, 0xDEADBEEF)));
        assert_eq!(parse_literal("7"), Some((32, 7)));
        assert_eq!(parse_literal("a"), None);
    }

    #[test]
    fn width_of_checks_ports_and_nets() {
        let mut m = VModule::new("M");
        m.ports.push(VPort {
            name: "p".into(),
            dir: Dir::In,
            width: 8,
            net: "wire".into(),
        });
        m.items.push(VItem::Net(VNet {
            kind: "wire".into(),
            width: 16,
            names: vec!["w".into()],
        }));
        assert_eq!(m.width_of("p"), Some(8));
        assert_eq!(m.width_of("w"), Some(16));
        assert_eq!(m.width_of("nope"), None);
    }
}

//! Structural Verilog parser.
//!
//! Parses module signatures, net declarations, `assign` statements, and
//! submodule instantiations precisely; captures everything else verbatim
//! as raw text (see [`crate::verilog::ast`]). Width expressions over
//! parameters are folded with a small constant evaluator.

use crate::ir::core::Dir;
use crate::verilog::ast::*;
use crate::verilog::lexer::{lex, SpannedTok, Tok};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

pub fn parse_file(src: &str) -> Result<VFile> {
    let toks = lex(src).map_err(|e| anyhow!("{e}"))?;
    let mut p = P {
        src,
        toks: &toks,
        i: 0,
        params: BTreeMap::new(),
    };
    let mut file = VFile::default();
    while !p.eof() {
        if p.peek_id("module") || p.peek_id("macromodule") {
            file.modules.push(p.module()?);
        } else {
            p.i += 1; // skip directives/junk between modules
        }
    }
    Ok(file)
}

/// Parse a source expected to contain exactly one module.
pub fn parse_module(src: &str) -> Result<VModule> {
    let f = parse_file(src)?;
    match f.modules.len() {
        1 => Ok(f.modules.into_iter().next().unwrap()),
        n => bail!("expected exactly 1 module, found {n}"),
    }
}

struct P<'a> {
    src: &'a str,
    toks: &'a [SpannedTok],
    i: usize,
    /// parameter environment for width folding.
    params: BTreeMap<String, i64>,
}

impl<'a> P<'a> {
    fn eof(&self) -> bool {
        self.i >= self.toks.len()
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i).map(|t| &t.tok)
    }

    fn peek_at(&self, k: usize) -> Option<&Tok> {
        self.toks.get(self.i + k).map(|t| &t.tok)
    }

    fn peek_id(&self, s: &str) -> bool {
        matches!(self.peek(), Some(t) if t.is_id(s))
    }

    fn peek_sym(&self, s: &str) -> bool {
        matches!(self.peek(), Some(t) if t.is_sym(s))
    }

    fn bump(&mut self) -> Result<&'a SpannedTok> {
        let t = self.toks.get(self.i).ok_or_else(|| anyhow!("unexpected EOF"))?;
        self.i += 1;
        Ok(t)
    }

    fn expect_sym(&mut self, s: &str) -> Result<()> {
        let t = self.bump()?;
        if t.tok.is_sym(s) {
            Ok(())
        } else {
            bail!(
                "line {}:{}: expected '{}', found '{}'",
                t.line,
                t.col,
                s,
                t.tok
            )
        }
    }

    fn expect_id(&mut self) -> Result<String> {
        let t = self.bump()?;
        t.tok.id().map(|s| s.to_string()).ok_or_else(|| {
            anyhow!(
                "line {}:{}: expected identifier, found '{}'",
                t.line,
                t.col,
                t.tok
            )
        })
    }

    /// Raw source text between token indices [from, to).
    fn text(&self, from: usize, to: usize) -> String {
        if from >= to {
            return String::new();
        }
        let s = self.toks[from].start;
        let e = self.toks[to - 1].end;
        self.src[s..e].to_string()
    }

    /// Advance past a balanced `(...)` (cursor must be on `(`); returns the
    /// token range inside the parens.
    fn balanced_parens(&mut self) -> Result<(usize, usize)> {
        self.expect_sym("(")?;
        let start = self.i;
        let mut depth = 1usize;
        while depth > 0 {
            let t = self.bump()?;
            match &t.tok {
                Tok::Sym(s) if s == "(" => depth += 1,
                Tok::Sym(s) if s == ")" => depth -= 1,
                _ => {}
            }
        }
        Ok((start, self.i - 1))
    }

    fn module(&mut self) -> Result<VModule> {
        let kw = self.bump()?; // module
        let span_start = kw.start;
        let name = self.expect_id()?;
        let mut m = VModule::new(&name);
        self.params.clear();

        // #(parameter ...) header
        if self.peek_sym("#") {
            self.bump()?;
            self.param_header(&mut m)?;
        }
        // port list
        if self.peek_sym("(") {
            self.port_list(&mut m)?;
        }
        self.expect_sym(";")?;

        // body items until endmodule
        while !self.peek_id("endmodule") {
            if self.eof() {
                bail!("module '{name}': missing endmodule");
            }
            self.item(&mut m)?;
        }
        let end = self.bump()?; // endmodule
        m.span = (span_start, end.end);
        Ok(m)
    }

    fn param_header(&mut self, m: &mut VModule) -> Result<()> {
        self.expect_sym("(")?;
        loop {
            if self.peek_sym(")") {
                self.bump()?;
                break;
            }
            if self.peek_id("parameter") || self.peek_id("localparam") {
                self.bump()?;
            }
            // optional type keywords
            while self.peek_id("integer") || self.peek_id("int") || self.peek_id("signed") {
                self.bump()?;
            }
            if self.peek_sym("[") {
                self.skip_range()?;
            }
            let pname = self.expect_id()?;
            let mut default = String::new();
            if self.peek_sym("=") {
                self.bump()?;
                let start = self.i;
                let mut depth = 0usize;
                while !self.eof() {
                    match self.peek() {
                        Some(t) if t.is_sym("(") || t.is_sym("[") || t.is_sym("{") => depth += 1,
                        Some(t) if t.is_sym(")") && depth == 0 => break,
                        Some(t) if (t.is_sym(")") || t.is_sym("]") || t.is_sym("}")) => {
                            depth = depth.saturating_sub(1)
                        }
                        Some(t) if t.is_sym(",") && depth == 0 => break,
                        _ => {}
                    }
                    self.i += 1;
                }
                default = self.text(start, self.i);
            }
            if let Some(v) = self.eval_const(&default) {
                self.params.insert(pname.clone(), v);
            }
            m.params.push(VParam {
                name: pname,
                default,
            });
            if self.peek_sym(",") {
                self.bump()?;
            }
        }
        Ok(())
    }

    fn port_list(&mut self, m: &mut VModule) -> Result<()> {
        self.expect_sym("(")?;
        if self.peek_sym(")") {
            self.bump()?;
            return Ok(());
        }
        // Two styles: ANSI (`input wire [7:0] a, output b`) or non-ANSI
        // (bare names, directions declared in the body).
        let mut cur_dir: Option<Dir> = None;
        let mut cur_width = 1u32;
        let mut cur_net = "wire".to_string();
        loop {
            if self.peek_id("input") || self.peek_id("output") || self.peek_id("inout") {
                let d = self.expect_id()?;
                cur_dir = Dir::parse(&d);
                cur_net = "wire".into();
                cur_width = 1;
                if self.peek_id("wire") || self.peek_id("reg") || self.peek_id("logic") {
                    cur_net = self.expect_id()?;
                    if cur_net == "logic" {
                        cur_net = "wire".into();
                    }
                }
                if self.peek_id("signed") {
                    self.bump()?;
                }
                if self.peek_sym("[") {
                    cur_width = self.range_width()?;
                }
            }
            let pname = self.expect_id()?;
            m.ports.push(VPort {
                name: pname,
                dir: cur_dir.unwrap_or(Dir::In),
                width: cur_width,
                net: cur_net.clone(),
            });
            // Mark non-ANSI ports: dir unknown until body declarations.
            if cur_dir.is_none() {
                m.ports.last_mut().unwrap().net = "undeclared".into();
            }
            let t = self.bump()?;
            match &t.tok {
                Tok::Sym(s) if s == "," => continue,
                Tok::Sym(s) if s == ")" => break,
                tok => bail!(
                    "line {}:{}: port list: unexpected '{}'",
                    t.line,
                    t.col,
                    tok
                ),
            }
        }
        Ok(())
    }

    /// Parse `[msb:lsb]` returning the width; cursor on `[`.
    fn range_width(&mut self) -> Result<u32> {
        self.expect_sym("[")?;
        let start = self.i;
        let mut depth = 0usize;
        let mut colon = None;
        while !self.eof() {
            match self.peek() {
                Some(t) if t.is_sym("[") || t.is_sym("(") => depth += 1,
                Some(t) if t.is_sym("]") && depth == 0 => break,
                Some(t) if t.is_sym("]") || t.is_sym(")") => depth = depth.saturating_sub(1),
                Some(t) if t.is_sym(":") && depth == 0 && colon.is_none() => colon = Some(self.i),
                _ => {}
            }
            self.i += 1;
        }
        let end = self.i;
        self.expect_sym("]")?;
        let colon = colon.ok_or_else(|| anyhow!("range without ':'"))?;
        let msb_txt = self.text(start, colon);
        let lsb_txt = self.text(colon + 1, end);
        let msb = self
            .eval_const(&msb_txt)
            .ok_or_else(|| anyhow!("cannot fold range msb '{msb_txt}'"))?;
        let lsb = self
            .eval_const(&lsb_txt)
            .ok_or_else(|| anyhow!("cannot fold range lsb '{lsb_txt}'"))?;
        Ok(((msb - lsb).unsigned_abs() + 1) as u32)
    }

    fn skip_range(&mut self) -> Result<()> {
        self.expect_sym("[")?;
        let mut depth = 1usize;
        while depth > 0 {
            let t = self.bump()?;
            match &t.tok {
                Tok::Sym(s) if s == "[" => depth += 1,
                Tok::Sym(s) if s == "]" => depth -= 1,
                _ => {}
            }
        }
        Ok(())
    }

    /// Fold a constant expression: integers, parameters, + - * / ( ).
    fn eval_const(&self, text: &str) -> Option<i64> {
        let toks = lex(text).ok()?;
        let mut ev = ConstEval {
            toks: &toks,
            i: 0,
            params: &self.params,
        };
        let v = ev.expr()?;
        if ev.i == toks.len() {
            Some(v)
        } else {
            None
        }
    }

    fn item(&mut self, m: &mut VModule) -> Result<()> {
        let t = self.peek().cloned().ok_or_else(|| anyhow!("EOF in module body"))?;
        match &t {
            Tok::Id(kw) => match kw.as_str() {
                "wire" | "reg" | "logic" => self.net_decl(m),
                "assign" => self.assign_item(m),
                "input" | "output" | "inout" => self.nonansi_port_decl(m),
                "always" | "always_ff" | "always_comb" | "always_latch" | "initial" => {
                    let raw = self.capture_always()?;
                    m.items.push(VItem::Raw(raw));
                    Ok(())
                }
                "function" => self.capture_until_kw(m, "endfunction"),
                "task" => self.capture_until_kw(m, "endtask"),
                "generate" => self.capture_until_kw(m, "endgenerate"),
                "parameter" => {
                    // body parameter decl: record then keep raw
                    let raw = self.capture_stmt_raw()?;
                    self.record_body_param(&raw);
                    m.items.push(VItem::Raw(raw));
                    Ok(())
                }
                "localparam" | "genvar" | "integer" | "real" | "time" | "event"
                | "specify" | "defparam" => {
                    if kw == "specify" {
                        return self.capture_until_kw(m, "endspecify");
                    }
                    let raw = self.capture_stmt_raw()?;
                    if kw == "localparam" {
                        self.record_body_param(&raw);
                    }
                    m.items.push(VItem::Raw(raw));
                    Ok(())
                }
                _ => {
                    // Likely an instantiation: Ident [#(...)] Ident ( ... ) ;
                    if self.looks_like_instance() {
                        let inst = self.instance()?;
                        m.items.push(VItem::Instance(inst));
                        Ok(())
                    } else {
                        let raw = self.capture_stmt_raw()?;
                        m.items.push(VItem::Raw(raw));
                        Ok(())
                    }
                }
            },
            _ => {
                let raw = self.capture_stmt_raw()?;
                m.items.push(VItem::Raw(raw));
                Ok(())
            }
        }
    }

    fn record_body_param(&mut self, raw: &str) {
        // parameter NAME = <const>; (possibly multiple comma-separated)
        let body = raw
            .trim_start_matches("parameter")
            .trim_start_matches("localparam")
            .trim_end_matches(';');
        for part in body.split(',') {
            if let Some((name, val)) = part.split_once('=') {
                let name = name
                    .trim()
                    .rsplit(|c: char| c.is_whitespace() || c == ']')
                    .next()
                    .unwrap_or("")
                    .to_string();
                if let Some(v) = self.eval_const(val.trim()) {
                    self.params.insert(name, v);
                }
            }
        }
    }

    fn net_decl(&mut self, m: &mut VModule) -> Result<()> {
        let start_tok = self.i;
        let mut kind = self.expect_id()?;
        if kind == "logic" {
            kind = "wire".into();
        }
        if self.peek_id("signed") {
            self.bump()?;
        }
        let mut width = 1u32;
        if self.peek_sym("[") {
            match self.range_width() {
                Ok(w) => width = w,
                Err(_) => {
                    // Unfoldable range: keep raw.
                    return self.raw_from(start_tok, m);
                }
            }
        }
        let mut names = Vec::new();
        loop {
            if self.peek().map(|t| t.id().is_some()) != Some(true) {
                return self.raw_from(start_tok, m);
            }
            let n = self.expect_id()?;
            // Array dims or initializer → raw.
            if self.peek_sym("[") || self.peek_sym("=") {
                return self.raw_from(start_tok, m);
            }
            names.push(n);
            match self.bump()?.tok.clone() {
                Tok::Sym(s) if s == "," => continue,
                Tok::Sym(s) if s == ";" => break,
                _ => return self.raw_from(start_tok, m),
            }
        }
        m.items.push(VItem::Net(VNet { kind, width, names }));
        Ok(())
    }

    /// Rewind to `start_tok` and capture the statement as raw text.
    fn raw_from(&mut self, start_tok: usize, m: &mut VModule) -> Result<()> {
        self.i = start_tok;
        let raw = self.capture_stmt_raw()?;
        m.items.push(VItem::Raw(raw));
        Ok(())
    }

    fn nonansi_port_decl(&mut self, m: &mut VModule) -> Result<()> {
        let t = self.bump()?;
        let dir = t.tok.id().and_then(Dir::parse).ok_or_else(|| {
            anyhow!(
                "line {}:{}: expected port direction, found '{}'",
                t.line,
                t.col,
                t.tok
            )
        })?;
        let mut net = "wire".to_string();
        if self.peek_id("wire") || self.peek_id("reg") || self.peek_id("logic") {
            net = self.expect_id()?;
        }
        if self.peek_id("signed") {
            self.bump()?;
        }
        let mut width = 1u32;
        if self.peek_sym("[") {
            width = self.range_width()?;
        }
        loop {
            let name = self.expect_id()?;
            if let Some(p) = m.ports.iter_mut().find(|p| p.name == name) {
                p.dir = dir;
                p.width = width;
                p.net = net.clone();
            } else {
                m.ports.push(VPort {
                    name,
                    dir,
                    width,
                    net: net.clone(),
                });
            }
            let t = self.bump()?;
            match &t.tok {
                Tok::Sym(s) if s == "," => continue,
                Tok::Sym(s) if s == ";" => break,
                tok => bail!(
                    "line {}:{}: port decl: unexpected '{}'",
                    t.line,
                    t.col,
                    tok
                ),
            }
        }
        Ok(())
    }

    fn assign_item(&mut self, m: &mut VModule) -> Result<()> {
        self.bump()?; // assign
        // optional drive strength / delay: #1, (strong0, ...)
        if self.peek_sym("#") {
            self.bump()?;
            self.bump()?; // delay value
        }
        let lhs_start = self.i;
        let mut depth = 0usize;
        while !self.eof() {
            match self.peek() {
                Some(t) if t.is_sym("{") || t.is_sym("[") || t.is_sym("(") => depth += 1,
                Some(t) if t.is_sym("}") || t.is_sym("]") || t.is_sym(")") => {
                    depth = depth.saturating_sub(1)
                }
                Some(t) if t.is_sym("=") && depth == 0 => break,
                _ => {}
            }
            self.i += 1;
        }
        let lhs = self.text(lhs_start, self.i);
        self.expect_sym("=")?;
        let rhs_start = self.i;
        let mut depth = 0usize;
        while !self.eof() {
            match self.peek() {
                Some(t) if t.is_sym("{") || t.is_sym("[") || t.is_sym("(") => depth += 1,
                Some(t) if t.is_sym("}") || t.is_sym("]") || t.is_sym(")") => {
                    depth = depth.saturating_sub(1)
                }
                Some(t) if t.is_sym(";") && depth == 0 => break,
                _ => {}
            }
            self.i += 1;
        }
        let rhs = self.text(rhs_start, self.i);
        self.expect_sym(";")?;
        m.items.push(VItem::Assign(VAssign { lhs, rhs }));
        Ok(())
    }

    fn looks_like_instance(&self) -> bool {
        // Ident Ident (   OR   Ident #( ... ) Ident (
        let id0 = matches!(self.peek(), Some(Tok::Id(_)));
        if !id0 {
            return false;
        }
        if matches!(self.peek_at(1), Some(Tok::Id(_)))
            && matches!(self.peek_at(2), Some(t) if t.is_sym("("))
        {
            return true;
        }
        matches!(self.peek_at(1), Some(t) if t.is_sym("#"))
    }

    fn instance(&mut self) -> Result<VInst> {
        let module = self.expect_id()?;
        let mut params = Vec::new();
        if self.peek_sym("#") {
            self.bump()?;
            let (s, e) = self.balanced_parens()?;
            params = self.parse_named_bindings(s, e);
        }
        let name = self.expect_id()?;
        // optional instance array range — unsupported, treat as error
        if self.peek_sym("[") {
            bail!("instance arrays not supported: {module} {name}[..]");
        }
        let (s, e) = self.balanced_parens()?;
        let conns = self.parse_named_bindings(s, e);
        self.expect_sym(";")?;
        Ok(VInst {
            module,
            name,
            params,
            conns,
        })
    }

    /// Parse `.name(expr), .name(), expr, ...` inside token range [s, e).
    fn parse_named_bindings(&self, s: usize, e: usize) -> Vec<(String, String)> {
        let mut out = Vec::new();
        let mut i = s;
        while i < e {
            if self.toks[i].tok.is_sym(".") && i + 1 < e {
                let port = self.toks[i + 1].tok.id().unwrap_or("").to_string();
                // expect ( expr )
                let mut j = i + 2;
                if j < e && self.toks[j].tok.is_sym("(") {
                    let mut depth = 1usize;
                    let estart = j + 1;
                    j += 1;
                    while j < e && depth > 0 {
                        if self.toks[j].tok.is_sym("(") {
                            depth += 1;
                        } else if self.toks[j].tok.is_sym(")") {
                            depth -= 1;
                        }
                        j += 1;
                    }
                    let expr = self.text(estart, j - 1);
                    out.push((port, expr));
                    i = j;
                } else {
                    // .port shorthand (SystemVerilog .name) — expr = name
                    out.push((port.clone(), port));
                    i += 2;
                }
                // skip comma
                while i < e && self.toks[i].tok.is_sym(",") {
                    i += 1;
                }
            } else {
                // positional: capture until comma at depth 0
                let start = i;
                let mut depth = 0usize;
                while i < e {
                    let t = &self.toks[i].tok;
                    if t.is_sym("(") || t.is_sym("[") || t.is_sym("{") {
                        depth += 1;
                    } else if t.is_sym(")") || t.is_sym("]") || t.is_sym("}") {
                        depth = depth.saturating_sub(1);
                    } else if t.is_sym(",") && depth == 0 {
                        break;
                    }
                    i += 1;
                }
                let expr = self.text(start, i);
                if !expr.trim().is_empty() {
                    out.push((String::new(), expr));
                }
                if i < e {
                    i += 1; // comma
                }
            }
        }
        out
    }

    /// Capture `always …` / `initial …` including its statement, verbatim.
    fn capture_always(&mut self) -> Result<String> {
        let start = self.i;
        self.bump()?; // always/initial
        // optional event control @(...) or @*
        if self.peek_sym("@") {
            self.bump()?;
            if self.peek_sym("(") {
                self.balanced_parens()?;
            } else {
                self.bump()?; // @* or @ident
            }
        }
        self.scan_stmt()?;
        Ok(self.text(start, self.i))
    }

    /// Skip one behavioural statement (begin/end blocks, if/else, case,
    /// for/while, or simple `…;`).
    fn scan_stmt(&mut self) -> Result<()> {
        match self.peek() {
            Some(t) if t.is_id("begin") => {
                self.bump()?;
                // optional : label
                if self.peek_sym(":") {
                    self.bump()?;
                    self.bump()?;
                }
                let mut depth = 1usize;
                while depth > 0 {
                    let t = self.bump()?;
                    match &t.tok {
                        Tok::Id(s) if s == "begin" || s == "case" || s == "casex"
                            || s == "casez" || s == "fork" => depth += 1,
                        Tok::Id(s) if s == "end" || s == "endcase" || s == "join" => depth -= 1,
                        _ => {}
                    }
                }
                Ok(())
            }
            Some(t) if t.is_id("if") => {
                self.bump()?;
                self.balanced_parens()?;
                self.scan_stmt()?;
                if self.peek_id("else") {
                    self.bump()?;
                    self.scan_stmt()?;
                }
                Ok(())
            }
            Some(t) if t.is_id("case") || t.is_id("casex") || t.is_id("casez") => {
                let mut depth = 1usize;
                self.bump()?;
                while depth > 0 {
                    let t = self.bump()?;
                    match &t.tok {
                        Tok::Id(s) if s == "case" || s == "casex" || s == "casez"
                            || s == "begin" || s == "fork" => depth += 1,
                        Tok::Id(s) if s == "endcase" || s == "end" || s == "join" => depth -= 1,
                        _ => {}
                    }
                }
                Ok(())
            }
            Some(t) if t.is_id("for") || t.is_id("while") || t.is_id("repeat") => {
                self.bump()?;
                self.balanced_parens()?;
                self.scan_stmt()
            }
            Some(t) if t.is_sym("@") || t.is_sym("#") => {
                self.bump()?;
                if self.peek_sym("(") {
                    self.balanced_parens()?;
                } else {
                    self.bump()?;
                }
                self.scan_stmt()
            }
            _ => {
                // simple statement up to `;` at depth 0
                let mut depth = 0usize;
                loop {
                    let t = self.bump()?;
                    match &t.tok {
                        Tok::Sym(s) if s == "(" || s == "[" || s == "{" => depth += 1,
                        Tok::Sym(s) if s == ")" || s == "]" || s == "}" => {
                            depth = depth.saturating_sub(1)
                        }
                        Tok::Sym(s) if s == ";" && depth == 0 => return Ok(()),
                        _ => {}
                    }
                }
            }
        }
    }

    /// Capture raw text up to and including the next `;` at bracket depth 0.
    fn capture_stmt_raw(&mut self) -> Result<String> {
        let start = self.i;
        let mut depth = 0usize;
        loop {
            let t = self.bump()?;
            match &t.tok {
                Tok::Sym(s) if s == "(" || s == "[" || s == "{" => depth += 1,
                Tok::Sym(s) if s == ")" || s == "]" || s == "}" => depth = depth.saturating_sub(1),
                Tok::Sym(s) if s == ";" && depth == 0 => break,
                _ => {}
            }
        }
        Ok(self.text(start, self.i))
    }

    /// Capture raw from current token through the closing keyword `endkw`.
    fn capture_until_kw(&mut self, m: &mut VModule, endkw: &str) -> Result<()> {
        let start = self.i;
        loop {
            let t = self.bump()?;
            if t.tok.is_id(endkw) {
                break;
            }
        }
        m.items.push(VItem::Raw(self.text(start, self.i)));
        Ok(())
    }
}

struct ConstEval<'a> {
    toks: &'a [SpannedTok],
    i: usize,
    params: &'a BTreeMap<String, i64>,
}

impl<'a> ConstEval<'a> {
    fn expr(&mut self) -> Option<i64> {
        let mut v = self.term()?;
        while let Some(t) = self.toks.get(self.i) {
            match &t.tok {
                Tok::Sym(s) if s == "+" => {
                    self.i += 1;
                    v += self.term()?;
                }
                Tok::Sym(s) if s == "-" => {
                    self.i += 1;
                    v -= self.term()?;
                }
                _ => break,
            }
        }
        Some(v)
    }

    fn term(&mut self) -> Option<i64> {
        let mut v = self.atom()?;
        while let Some(t) = self.toks.get(self.i) {
            match &t.tok {
                Tok::Sym(s) if s == "*" => {
                    self.i += 1;
                    v *= self.atom()?;
                }
                Tok::Sym(s) if s == "/" => {
                    self.i += 1;
                    let d = self.atom()?;
                    if d == 0 {
                        return None;
                    }
                    v /= d;
                }
                _ => break,
            }
        }
        Some(v)
    }

    fn atom(&mut self) -> Option<i64> {
        let t = self.toks.get(self.i)?;
        self.i += 1;
        match &t.tok {
            Tok::Num(n) => {
                if let Some((_, val)) = crate::verilog::ast::parse_literal(n) {
                    Some(val as i64)
                } else {
                    n.replace('_', "").parse().ok()
                }
            }
            Tok::Id(id) => self.params.get(id).copied(),
            Tok::Sym(s) if s == "(" => {
                let v = self.expr()?;
                let close = self.toks.get(self.i)?;
                if close.tok.is_sym(")") {
                    self.i += 1;
                    Some(v)
                } else {
                    None
                }
            }
            Tok::Sym(s) if s == "-" => Some(-self.atom()?),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LLM_TOP: &str = r#"
// Top-level interconnect of the LLM accelerator (cf. Fig 4a).
module LLM #(parameter W = 64, parameter DEPTH = W/2) (
  input  wire ap_clk,
  input  wire ap_rst_n,
  input  wire [W-1:0] in_data,
  input  wire in_vld,
  output wire in_rdy,
  output wire [31:0] out_data
);
  wire [63:0] I_wire;
  wire I_wire_vld, I_wire_rdy;
  reg [7:0] ctrl_state;

  assign in_rdy = I_wire_rdy & ~ctrl_state[0];

  always @(posedge ap_clk) begin
    if (!ap_rst_n) ctrl_state <= 8'd0;
    else ctrl_state <= ctrl_state + 1;
  end

  InputLoader #(.W(W)) InputLoader_inst (
    .clk(ap_clk),
    .data(in_data),
    .o(I_wire),
    .o_vld(I_wire_vld),
    .o_rdy(I_wire_rdy)
  );

  FIFO FIFO_inst (.I(I_wire), .I_vld(I_wire_vld), .I_rdy(I_wire_rdy), .O(out_data), .unused());
endmodule
"#;

    #[test]
    fn parses_header_and_params() {
        let m = parse_module(LLM_TOP).unwrap();
        assert_eq!(m.name, "LLM");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].name, "W");
        // DEPTH = W/2 folded with W=64
        assert_eq!(m.ports.len(), 6);
        let ind = m.port("in_data").unwrap();
        assert_eq!(ind.width, 64); // W-1:0 folded
        assert_eq!(ind.dir, Dir::In);
        assert_eq!(m.port("out_data").unwrap().dir, Dir::Out);
    }

    #[test]
    fn parses_nets_and_assigns() {
        let m = parse_module(LLM_TOP).unwrap();
        let nets: Vec<_> = m.nets().collect();
        assert_eq!(nets.len(), 3);
        assert_eq!(nets[0].width, 64);
        assert_eq!(nets[1].names, vec!["I_wire_vld", "I_wire_rdy"]);
        let assigns: Vec<_> = m.assigns().collect();
        assert_eq!(assigns.len(), 1);
        assert_eq!(assigns[0].lhs.trim(), "in_rdy");
        assert!(assigns[0].rhs.contains("ctrl_state"));
    }

    #[test]
    fn preserves_always_block_raw() {
        let m = parse_module(LLM_TOP).unwrap();
        let raws: Vec<_> = m
            .items
            .iter()
            .filter_map(|i| match i {
                VItem::Raw(r) => Some(r),
                _ => None,
            })
            .collect();
        assert!(raws.iter().any(|r| r.contains("ctrl_state <= ctrl_state + 1")));
        // the whole always block, including the trailing `end`
        assert!(raws.iter().any(|r| r.trim_start().starts_with("always") && r.trim_end().ends_with("end")));
    }

    #[test]
    fn parses_instances_with_params() {
        let m = parse_module(LLM_TOP).unwrap();
        let insts: Vec<_> = m.instances().collect();
        assert_eq!(insts.len(), 2);
        let il = insts[0];
        assert_eq!(il.module, "InputLoader");
        assert_eq!(il.name, "InputLoader_inst");
        assert_eq!(il.params, vec![("W".to_string(), "W".to_string())]);
        assert_eq!(il.conn("o"), Some("I_wire"));
        let fifo = insts[1];
        assert_eq!(fifo.conn("unused"), Some("")); // explicitly open
    }

    #[test]
    fn nonansi_ports() {
        let src = "module M (a, b, c);\ninput [7:0] a;\noutput reg b;\ninout c;\nendmodule";
        let m = parse_module(src).unwrap();
        assert_eq!(m.port("a").unwrap().width, 8);
        assert_eq!(m.port("a").unwrap().dir, Dir::In);
        assert_eq!(m.port("b").unwrap().net, "reg");
        assert_eq!(m.port("c").unwrap().dir, Dir::InOut);
    }

    #[test]
    fn multiple_modules_per_file() {
        let src = "module A(); endmodule\nmodule B(input x); endmodule";
        let f = parse_file(src).unwrap();
        assert_eq!(f.modules.len(), 2);
        assert!(f.module("B").unwrap().port("x").is_some());
    }

    #[test]
    fn generate_blocks_raw() {
        let src = "module G(input c);\ngenerate\n genvar i;\n for (i=0;i<4;i=i+1) begin: g\n  buf b(c);\n end\nendgenerate\nendmodule";
        let m = parse_module(src).unwrap();
        assert!(m.items.iter().any(|i| matches!(i, VItem::Raw(r) if r.contains("endgenerate"))));
        // the buf instance inside generate must NOT be extracted
        assert_eq!(m.instances().count(), 0);
    }

    #[test]
    fn if_else_single_statement_always() {
        let src = "module T(input c, output reg q);\nalways @(posedge c) if (c) q <= 1; else q <= 0;\nendmodule";
        let m = parse_module(src).unwrap();
        let raw = m
            .items
            .iter()
            .find_map(|i| match i {
                VItem::Raw(r) => Some(r),
                _ => None,
            })
            .unwrap();
        assert!(raw.contains("else q <= 0;"), "{raw}");
    }

    #[test]
    fn localparam_updates_env() {
        let src = "module L();\nlocalparam W = 16;\nwire [W-1:0] d;\nendmodule";
        let m = parse_module(src).unwrap();
        assert_eq!(m.nets().next().unwrap().width, 16);
    }

    #[test]
    fn arrayed_net_kept_raw() {
        let src = "module R();\nreg [7:0] mem [0:255];\nendmodule";
        let m = parse_module(src).unwrap();
        assert_eq!(m.nets().count(), 0);
        assert!(m.items.iter().any(|i| matches!(i, VItem::Raw(r) if r.contains("mem"))));
    }

    #[test]
    fn errors_on_missing_endmodule() {
        assert!(parse_module("module X(input a);").is_err());
    }

    #[test]
    fn module_spans_slice_own_source() {
        let src = "// banner\nmodule A(); endmodule\nmodule B(input x); endmodule\n// tail";
        let f = parse_file(src).unwrap();
        assert_eq!(f.module("A").unwrap().source_slice(src), "module A(); endmodule");
        assert_eq!(
            f.module("B").unwrap().source_slice(src),
            "module B(input x); endmodule"
        );
    }

    #[test]
    fn errors_carry_line_and_column() {
        let err = parse_module("module M(\n  input 4);\nendmodule").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("expected identifier"), "{msg}");
    }

    #[test]
    fn malformed_inputs_error_without_panic() {
        // Each of these previously risked an unwrap or usize underflow;
        // all must return (Ok or Err), never panic.
        for src in [
            "module M(a; endmodule",
            "module M(); input; endmodule",
            "module M(); assign ) = 1; endmodule",
            "module M(); wire ]]] ; endmodule",
            "module M(input 4); endmodule",
            "module",
            "module M #(parameter ) (); endmodule",
            "module M(); sub s0 (.p(x))",
            "module M(); output }; endmodule",
        ] {
            let _ = parse_file(src);
        }
    }

    #[test]
    fn positional_connections() {
        let src = "module P(input a, input b);\nsub s0 (a, b);\nendmodule";
        let m = parse_module(src).unwrap();
        let inst = m.instances().next().unwrap();
        assert_eq!(
            inst.conns,
            vec![
                (String::new(), "a".to_string()),
                (String::new(), "b".to_string())
            ]
        );
    }
}

//! Verilog rewriter — the three capabilities the hierarchy-rebuild pass
//! requires of *any* source format (§3.3): (1) extraction of submodule
//! names and port connections, (2) addition of new ports to a module, and
//! (3) connection of expressions to these new ports via `assign`.
//!
//! `extract_aux` combines them to split a Verilog module into its
//! submodule instances plus a residual **aux module** holding all original
//! logic, with fresh ports standing in for each extracted connection.

use crate::ir::core::Dir;
use crate::verilog::ast::*;
use anyhow::{bail, Result};
use std::collections::BTreeSet;

/// Capability (1): extracted instance info.
#[derive(Debug, Clone)]
pub struct ExtractedInst {
    pub inst: VInst,
    /// (port, expr, aux_port_name, dir as seen on the aux module, width).
    pub bindings: Vec<AuxBinding>,
}

#[derive(Debug, Clone)]
pub struct AuxBinding {
    pub sub_port: String,
    /// Original connection expression text ("" for open).
    pub expr: String,
    pub aux_port: String,
    /// Direction of the new aux port: flipped vs the submodule port
    /// (submodule input ⇒ aux output drives it).
    pub aux_dir: Dir,
    pub width: u32,
}

/// Result of [`extract_aux`].
#[derive(Debug, Clone)]
pub struct AuxSplit {
    /// The residual module: original logic, instances removed, new ports
    /// added, glue assigns appended.
    pub aux: VModule,
    /// Extracted instances with their aux-port bindings.
    pub extracted: Vec<ExtractedInst>,
}

/// Port widths/directions of extraction targets must be resolvable: the
/// callback maps `(module_name, port_name)` to `(dir, width)` for known
/// library modules; returns None for unknown modules (those instances are
/// left inside the aux).
pub fn extract_aux(
    m: &VModule,
    aux_name: &str,
    lookup: &dyn Fn(&str, &str) -> Option<(Dir, u32)>,
) -> Result<AuxSplit> {
    extract_aux_with_skip(m, aux_name, lookup, &|_, _, _| false)
}

/// Like [`extract_aux`], but `skip(inst, port, expr)` can mark bindings
/// that should bypass the aux module entirely — the hierarchy-rebuild pass
/// uses this to keep clock/reset connections as direct broadcast nets
/// instead of threading them through aux ports. Skipped bindings keep
/// their original expression and get an empty `aux_port`.
pub fn extract_aux_with_skip(
    m: &VModule,
    aux_name: &str,
    lookup: &dyn Fn(&str, &str) -> Option<(Dir, u32)>,
    skip: &dyn Fn(&VInst, &str, &str) -> bool,
) -> Result<AuxSplit> {
    let mut aux = VModule::new(aux_name);
    aux.params = m.params.clone();
    aux.ports = m.ports.clone();
    let mut extracted = Vec::new();
    let mut used_names: BTreeSet<String> = m.ports.iter().map(|p| p.name.clone()).collect();
    for n in m.nets() {
        used_names.extend(n.names.iter().cloned());
    }

    for item in &m.items {
        match item {
            VItem::Instance(inst) => {
                // Extract only if every named connection resolves on the
                // target module; otherwise keep the instance in the aux.
                let resolvable = inst.conns.iter().all(|(p, _)| {
                    !p.is_empty() && lookup(&inst.module, p).is_some()
                });
                if !resolvable {
                    aux.items.push(item.clone());
                    continue;
                }
                let mut bindings = Vec::new();
                for (port, expr) in &inst.conns {
                    let (dir, width) = lookup(&inst.module, port).unwrap();
                    if dir == Dir::InOut {
                        bail!(
                            "inout port {}.{} cannot be extracted",
                            inst.module,
                            port
                        );
                    }
                    if expr.trim().is_empty() || skip(inst, port, expr) {
                        // Explicitly open, or a clock/reset-style direct
                        // connection: no aux port needed.
                        bindings.push(AuxBinding {
                            sub_port: port.clone(),
                            expr: expr.trim().to_string(),
                            aux_port: String::new(),
                            aux_dir: dir.flipped(),
                            width,
                        });
                        continue;
                    }
                    let mut aux_port = format!("{}_{}", inst.name, port);
                    while used_names.contains(&aux_port) {
                        aux_port.push('_');
                    }
                    used_names.insert(aux_port.clone());
                    bindings.push(AuxBinding {
                        sub_port: port.clone(),
                        expr: expr.clone(),
                        aux_port,
                        aux_dir: dir.flipped(),
                        width,
                    });
                }
                extracted.push(ExtractedInst {
                    inst: inst.clone(),
                    bindings,
                });
            }
            other => aux.items.push(other.clone()),
        }
    }

    // Capabilities (2) + (3): add aux ports and glue assigns.
    for e in &extracted {
        for b in &e.bindings {
            if b.aux_port.is_empty() {
                continue;
            }
            aux.ports.push(VPort {
                name: b.aux_port.clone(),
                dir: b.aux_dir,
                width: b.width,
                net: "wire".into(),
            });
            match b.aux_dir {
                // Submodule input: aux drives it with the original expr.
                Dir::Out => aux.items.push(VItem::Assign(VAssign {
                    lhs: b.aux_port.clone(),
                    rhs: b.expr.clone(),
                })),
                // Submodule output: the original expr (an lvalue —
                // identifier or concat) receives the value from the new
                // aux input port.
                Dir::In => {
                    if is_single_identifier(&b.expr) && aux.width_of(b.expr.trim()).is_none() {
                        // The identifier was only used as an implicit net
                        // on the instance; declare it so the assign is
                        // well-formed.
                        aux.items.insert(
                            0,
                            VItem::Net(VNet {
                                kind: "wire".into(),
                                width: b.width,
                                names: vec![b.expr.trim().to_string()],
                            }),
                        );
                    }
                    aux.items.push(VItem::Assign(VAssign {
                        lhs: b.expr.clone(),
                        rhs: b.aux_port.clone(),
                    }));
                }
                Dir::InOut => unreachable!(),
            }
        }
    }
    Ok(AuxSplit { aux, extracted })
}

/// Capability (2) standalone: add a port to a module.
pub fn add_port(m: &mut VModule, name: &str, dir: Dir, width: u32) {
    m.ports.push(VPort {
        name: name.into(),
        dir,
        width,
        net: "wire".into(),
    });
}

/// Capability (3) standalone: connect an expression to a port via assign.
pub fn connect_expr(m: &mut VModule, port: &str, expr: &str, port_is_lhs: bool) {
    let (lhs, rhs) = if port_is_lhs {
        (port.to_string(), expr.to_string())
    } else {
        (expr.to_string(), port.to_string())
    };
    m.items.push(VItem::Assign(VAssign { lhs, rhs }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verilog::parser::parse_module;
    use crate::verilog::printer::print_module;

    const SRC: &str = r#"
module LLM (
  input  wire ap_clk,
  input  wire [63:0] in_data,
  output wire [31:0] out_data
);
  wire [63:0] I_wire;
  reg  [7:0] state;
  always @(posedge ap_clk) state <= state + 1;

  InputLoader il (.clk(ap_clk), .data(in_data & 64'hFF), .o(I_wire));
  FIFO f0 (.I(I_wire), .O(fifo_out), .dbg());
  UnknownIP u0 (.x(I_wire));
endmodule
"#;

    fn lookup(module: &str, port: &str) -> Option<(Dir, u32)> {
        match (module, port) {
            ("InputLoader", "clk") => Some((Dir::In, 1)),
            ("InputLoader", "data") => Some((Dir::In, 64)),
            ("InputLoader", "o") => Some((Dir::Out, 64)),
            ("FIFO", "I") => Some((Dir::In, 64)),
            ("FIFO", "O") => Some((Dir::Out, 32)),
            ("FIFO", "dbg") => Some((Dir::Out, 1)),
            _ => None,
        }
    }

    #[test]
    fn extracts_known_instances_only() {
        let m = parse_module(SRC).unwrap();
        let split = extract_aux(&m, "LLM_Aux", &lookup).unwrap();
        assert_eq!(split.extracted.len(), 2);
        // UnknownIP stays inside the aux.
        assert_eq!(split.aux.instances().count(), 1);
        assert_eq!(split.aux.instances().next().unwrap().module, "UnknownIP");
    }

    #[test]
    fn aux_gains_flipped_ports() {
        let m = parse_module(SRC).unwrap();
        let split = extract_aux(&m, "LLM_Aux", &lookup).unwrap();
        let aux = &split.aux;
        // il.data is a submodule input ⇒ aux output port il_data.
        let p = aux.port("il_data").unwrap();
        assert_eq!(p.dir, Dir::Out);
        assert_eq!(p.width, 64);
        // il.o is a submodule output ⇒ aux input port il_o.
        assert_eq!(aux.port("il_o").unwrap().dir, Dir::In);
        // Original ports survive.
        assert!(aux.port("ap_clk").is_some());
    }

    #[test]
    fn glue_assigns_preserve_expressions() {
        let m = parse_module(SRC).unwrap();
        let split = extract_aux(&m, "LLM_Aux", &lookup).unwrap();
        let printed = print_module(&split.aux);
        // Complex input expression moved into the aux.
        assert!(printed.contains("assign il_data = in_data & 64'hFF;"), "{printed}");
        // Output port value flows back into the original identifier.
        assert!(printed.contains("assign I_wire = il_o;"), "{printed}");
        // Implicit net fifo_out gets declared.
        assert!(printed.contains("wire [31:0] fifo_out;"), "{printed}");
        assert!(printed.contains("assign fifo_out = f0_O;"), "{printed}");
    }

    #[test]
    fn open_connections_get_no_aux_port() {
        let m = parse_module(SRC).unwrap();
        let split = extract_aux(&m, "LLM_Aux", &lookup).unwrap();
        assert!(split.aux.port("f0_dbg").is_none());
    }

    #[test]
    fn residual_logic_survives() {
        let m = parse_module(SRC).unwrap();
        let split = extract_aux(&m, "LLM_Aux", &lookup).unwrap();
        let printed = print_module(&split.aux);
        assert!(printed.contains("state <= state + 1"));
        assert!(printed.contains("reg [7:0] state;"));
    }

    #[test]
    fn aux_is_reparsable() {
        let m = parse_module(SRC).unwrap();
        let split = extract_aux(&m, "LLM_Aux", &lookup).unwrap();
        let printed = print_module(&split.aux);
        let re = parse_module(&printed).unwrap();
        assert_eq!(re.name, "LLM_Aux");
        assert_eq!(re.ports.len(), split.aux.ports.len());
    }

    #[test]
    fn name_collision_resolved() {
        let src = "module M(input a);\n  wire s0_x;\n  sub s0 (.x(a));\nendmodule";
        let m = parse_module(src).unwrap();
        let split = extract_aux(&m, "M_Aux", &|mo, p| {
            (mo == "sub" && p == "x").then_some((Dir::In, 1))
        })
        .unwrap();
        // s0_x taken ⇒ new port gets underscore suffix.
        assert!(split.aux.port("s0_x_").is_some());
    }

    #[test]
    fn standalone_capabilities() {
        let mut m = parse_module("module T(input a); endmodule").unwrap();
        add_port(&mut m, "np", Dir::Out, 4);
        connect_expr(&mut m, "np", "{a, 3'd0}", true);
        let p = print_module(&m);
        assert!(p.contains("output wire [3:0] np"));
        assert!(p.contains("assign np = {a, 3'd0};"));
    }
}

//! Verilog printer: regenerate source text from a [`VModule`].
//!
//! Used by the design exporter (§3.2): unchanged leaf modules are emitted
//! from their embedded original source; rebuilt/partitioned modules are
//! printed from their AST, with raw items emitted verbatim.

use crate::ir::core::Dir;
use crate::verilog::ast::*;

pub fn print_module(m: &VModule) -> String {
    let mut s = String::new();
    s.push_str(&format!("module {}", m.name));
    if !m.params.is_empty() {
        s.push_str(" #(\n");
        for (i, p) in m.params.iter().enumerate() {
            let comma = if i + 1 < m.params.len() { "," } else { "" };
            if p.default.is_empty() {
                s.push_str(&format!("  parameter {}{comma}\n", p.name));
            } else {
                s.push_str(&format!("  parameter {} = {}{comma}\n", p.name, p.default));
            }
        }
        s.push_str(")");
    }
    if m.ports.is_empty() {
        s.push_str(" ();\n");
    } else {
        s.push_str(" (\n");
        for (i, p) in m.ports.iter().enumerate() {
            let comma = if i + 1 < m.ports.len() { "," } else { "" };
            s.push_str(&format!("  {}{comma}\n", port_decl(p)));
        }
        s.push_str(");\n");
    }
    for item in &m.items {
        match item {
            VItem::Net(n) => {
                let range = range_str(n.width);
                s.push_str(&format!("  {} {}{};\n", n.kind, range, n.names.join(", ")));
            }
            VItem::Assign(a) => {
                s.push_str(&format!("  assign {} = {};\n", a.lhs.trim(), a.rhs.trim()));
            }
            VItem::Instance(inst) => {
                s.push_str(&print_instance(inst));
            }
            VItem::Raw(r) => {
                s.push_str("  ");
                s.push_str(r.trim_end());
                s.push('\n');
            }
        }
    }
    s.push_str("endmodule\n");
    s
}

pub fn print_instance(inst: &VInst) -> String {
    let mut s = String::new();
    s.push_str(&format!("  {}", inst.module));
    if !inst.params.is_empty() {
        s.push_str(" #(");
        let ps: Vec<String> = inst
            .params
            .iter()
            .map(|(k, v)| format!(".{k}({v})"))
            .collect();
        s.push_str(&ps.join(", "));
        s.push(')');
    }
    s.push_str(&format!(" {} (\n", inst.name));
    for (i, (port, expr)) in inst.conns.iter().enumerate() {
        let comma = if i + 1 < inst.conns.len() { "," } else { "" };
        if port.is_empty() {
            s.push_str(&format!("    {expr}{comma}\n"));
        } else {
            s.push_str(&format!("    .{port}({expr}){comma}\n"));
        }
    }
    s.push_str("  );\n");
    s
}

fn port_decl(p: &VPort) -> String {
    let dir = match p.dir {
        Dir::In => "input ",
        Dir::Out => "output",
        Dir::InOut => "inout ",
    };
    let net = if p.net == "reg" { " reg " } else { " wire " };
    format!("{dir}{net}{}{}", range_str(p.width), p.name)
}

fn range_str(width: u32) -> String {
    if width > 1 {
        format!("[{}:0] ", width - 1)
    } else {
        String::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verilog::parser::parse_module;

    #[test]
    fn roundtrip_reparse_equal_structure() {
        let src = r#"
module M #(parameter W = 8) (
  input wire [W-1:0] a,
  output reg b
);
  wire [3:0] x;
  assign b = a[0] & x[1];
  always @(a) begin
    // comment inside raw is dropped by lexer but the block survives
    x[0] = a[1];
  end
  sub #(.P(2)) s0 (.i(a), .o(x));
endmodule
"#;
        let m1 = parse_module(src).unwrap();
        let printed = print_module(&m1);
        let m2 = parse_module(&printed).unwrap();
        assert_eq!(m2.name, m1.name);
        assert_eq!(m2.ports.len(), m1.ports.len());
        assert_eq!(m2.instances().count(), 1);
        assert_eq!(m2.assigns().count(), 1);
        // Width folded to a constant at first parse; printer emits [7:0].
        assert_eq!(m2.port("a").unwrap().width, 8);
    }

    #[test]
    fn print_idempotent() {
        let src = "module X(input a, output wire [15:0] y);\n  assign y = {16{a}};\nendmodule";
        let once = print_module(&parse_module(src).unwrap());
        let twice = print_module(&parse_module(&once).unwrap());
        assert_eq!(once, twice);
    }

    #[test]
    fn open_connection_printed() {
        let mut inst = VInst {
            module: "FIFO".into(),
            name: "f0".into(),
            params: vec![],
            conns: vec![("dbg".into(), String::new())],
        };
        let s = print_instance(&inst);
        assert!(s.contains(".dbg()"));
        inst.conns[0].1 = "w".into();
        assert!(print_instance(&inst).contains(".dbg(w)"));
    }
}

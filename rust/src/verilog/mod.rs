//! Verilog frontend: lexer, structural parser, printer, and the rewriter
//! capabilities required by the hierarchy-rebuild pass (replaces Slang in
//! the paper's toolchain).

pub mod ast;
pub mod lexer;
pub mod parser;
pub mod printer;
pub mod rewriter;

pub use ast::{VFile, VInst, VItem, VModule, VPort};
pub use parser::{parse_file, parse_module};
pub use printer::print_module;

//! Pipeline element templates (Figure 6 of the paper).
//!
//! * **Relay station** — an almost-full FIFO with registered outputs that
//!   pipelines a *handshake* interface: the AFull signal throttles the
//!   producer early enough to absorb the flip-flop latency, so inserting
//!   one never changes protocol semantics, only latency.
//! * **FF chain** — plain flip-flop stages pipelining a *feedforward*
//!   (scalar) interface.
//! * **Clock broadcast** — fan-out helper for clock/reset distribution to
//!   partition splits.
//!
//! Each generator returns a leaf [`Module`] with real Verilog, interface
//! metadata, a resource estimate, and `pipeline_element: true` so the STA
//! treats it as a register boundary.

use crate::ir::builder::{resources_to_json, LeafBuilder};
use crate::ir::core::*;
use crate::util::json::{Json, JsonObj};

pub mod sim;

/// Relay-station module name for a given width/depth.
pub fn relay_station_name(width: u32, stages: u32) -> String {
    format!("rs_w{width}_s{stages}")
}

/// Generate a relay station: handshake in `i`, handshake out `o`,
/// `stages` internal register levels (depth = stages + 2 so AFull can
/// tolerate the registered handshake round trip).
pub fn relay_station(width: u32, stages: u32) -> Module {
    let name = relay_station_name(width, stages);
    let depth = (stages + 2).next_power_of_two().max(4);
    let source = relay_station_verilog(&name, width, depth);
    let mut m = LeafBuilder::new(&name, SourceFormat::Verilog, source)
        .clk_rst()
        .handshake("i", Dir::In, width)
        .handshake("o", Dir::Out, width)
        .build();
    // FF: data regs per stage + FIFO control; LUT: small control.
    let ff = (width + 2) as f64 * (stages as f64 + 1.0) + 16.0;
    let lut = width as f64 * 0.5 + 24.0;
    m.metadata
        .insert("resource", resources_to_json(&Resources::new(lut, ff, 0.0, 0.0, 0.0)));
    let mut t = JsonObj::new();
    t.insert("internal_ns", Json::num(0.9));
    m.metadata.insert("timing", Json::Obj(t));
    m.metadata.insert("pipeline_element", Json::Bool(true));
    m.metadata.insert("pipeline_stages", Json::num(stages as f64));
    m
}

fn relay_station_verilog(name: &str, width: u32, depth: u32) -> String {
    let aw = (31 - depth.leading_zeros()).max(1);
    format!(
        r#"// Relay station: almost-full FIFO pipelining a handshake channel.
// AFull asserts {afull_margin} entries early so fully registered i_rdy
// never overflows the buffer (Fig 6, right).
module {name} (
  input  wire ap_clk,
  input  wire ap_rst_n,
  input  wire [{msb}:0] i,
  input  wire i_vld,
  output reg  i_rdy,
  output reg  [{msb}:0] o,
  output reg  o_vld,
  input  wire o_rdy
);
  reg [{msb}:0] buffer [0:{dmax}];
  reg [{aw}:0] wptr, rptr, count;
  wire afull = (count >= {afull_at});
  wire do_write = i_vld & i_rdy;
  wire do_read  = (count != 0) & (~o_vld | o_rdy);

  always @(posedge ap_clk) begin
    if (!ap_rst_n) begin
      wptr <= 0; rptr <= 0; count <= 0;
      i_rdy <= 1'b0; o_vld <= 1'b0;
    end else begin
      i_rdy <= ~afull;
      if (do_write) begin
        buffer[wptr[{awm1}:0]] <= i;
        wptr <= wptr + 1;
      end
      if (do_read) begin
        o <= buffer[rptr[{awm1}:0]];
        o_vld <= 1'b1;
        rptr <= rptr + 1;
      end else if (o_rdy) begin
        o_vld <= 1'b0;
      end
      count <= count + (do_write ? 1 : 0) - (do_read ? 1 : 0);
    end
  end
endmodule
"#,
        name = name,
        msb = width - 1,
        dmax = depth - 1,
        aw = aw,
        awm1 = aw.saturating_sub(1),
        afull_at = depth - 2,
        afull_margin = 2,
    )
}

/// FF-chain module name.
pub fn ff_chain_name(width: u32, stages: u32) -> String {
    format!("ff_w{width}_s{stages}")
}

/// Generate a feedforward pipeline: `stages` flip-flop levels on a scalar
/// bundle (Fig 6, left).
pub fn ff_chain(width: u32, stages: u32) -> Module {
    let name = ff_chain_name(width, stages);
    let source = format!(
        r#"// Feedforward pipeline: {stages} register stages.
module {name} (
  input  wire ap_clk,
  input  wire [{msb}:0] i,
  output wire [{msb}:0] o
);
  reg [{msb}:0] pipe [0:{smax}];
  integer k;
  always @(posedge ap_clk) begin
    pipe[0] <= i;
    for (k = 1; k <= {smax}; k = k + 1)
      pipe[k] <= pipe[k-1];
  end
  assign o = pipe[{smax}];
endmodule
"#,
        name = name,
        msb = width - 1,
        smax = stages.max(1) - 1,
        stages = stages
    );
    let mut m = LeafBuilder::new(&name, SourceFormat::Verilog, source)
        .port("ap_clk", Dir::In, 1)
        .iface(Interface::Clock {
            port: "ap_clk".into(),
        })
        .port("i", Dir::In, width)
        .port("o", Dir::Out, width)
        .iface(Interface::Feedforward {
            name: "i".into(),
            ports: vec!["i".into()],
        })
        .iface(Interface::Feedforward {
            name: "o".into(),
            ports: vec!["o".into()],
        })
        .build();
    m.metadata.insert(
        "resource",
        resources_to_json(&Resources::new(4.0, (width * stages) as f64, 0.0, 0.0, 0.0)),
    );
    let mut t = JsonObj::new();
    t.insert("internal_ns", Json::num(0.6));
    m.metadata.insert("timing", Json::Obj(t));
    m.metadata.insert("pipeline_element", Json::Bool(true));
    m.metadata.insert("pipeline_stages", Json::num(stages as f64));
    m
}

/// Clock/reset broadcast helper: 1-bit input fanned out to `n` outputs.
pub fn broadcast(n: u32) -> Module {
    let name = format!("bcast_{n}");
    let mut outs = String::new();
    let mut assigns = String::new();
    for k in 0..n {
        outs.push_str(&format!(",\n  output wire o{k}"));
        assigns.push_str(&format!("  assign o{k} = i;\n"));
    }
    let source = format!(
        "// Clock/reset broadcast tree.\nmodule {name} (\n  input  wire i{outs}\n);\n{assigns}endmodule\n"
    );
    let mut b = LeafBuilder::new(&name, SourceFormat::Verilog, source).port("i", Dir::In, 1);
    for k in 0..n {
        b = b.port(&format!("o{k}"), Dir::Out, 1);
    }
    let mut m = b.build();
    m.metadata.insert(
        "resource",
        resources_to_json(&Resources::new(1.0, 0.0, 0.0, 0.0, 0.0)),
    );
    m.metadata.insert("pipeline_element", Json::Bool(true));
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verilog::parser::parse_module;

    #[test]
    fn relay_station_verilog_parses() {
        let m = relay_station(64, 2);
        let Body::Leaf { source, .. } = &m.body else {
            panic!()
        };
        let vm = parse_module(source).unwrap();
        assert_eq!(vm.name, m.name);
        assert_eq!(vm.port("i").unwrap().width, 64);
        assert_eq!(vm.port("o_vld").unwrap().dir, Dir::Out);
    }

    #[test]
    fn relay_station_ir_shape() {
        let m = relay_station(32, 3);
        assert_eq!(m.interfaces.iter().filter(|i| i.pipelinable()).count(), 2);
        assert!(m
            .metadata
            .get("pipeline_element")
            .and_then(|v| v.as_bool())
            .unwrap());
        let r = crate::ir::builder::module_resources(&m).unwrap();
        assert!(r.ff > 100.0);
    }

    #[test]
    fn ff_chain_parses_and_scales() {
        let m = ff_chain(16, 4);
        let Body::Leaf { source, .. } = &m.body else {
            panic!()
        };
        parse_module(source).unwrap();
        let r = crate::ir::builder::module_resources(&m).unwrap();
        assert_eq!(r.ff, 64.0);
    }

    #[test]
    fn broadcast_parses() {
        let m = broadcast(4);
        let Body::Leaf { source, .. } = &m.body else {
            panic!()
        };
        let vm = parse_module(source).unwrap();
        assert_eq!(vm.ports.len(), 5);
        assert_eq!(vm.assigns().count(), 4);
    }

    #[test]
    fn names_stable() {
        assert_eq!(relay_station(64, 2).name, "rs_w64_s2");
        assert_eq!(ff_chain(8, 1).name, "ff_w8_s1");
    }
}

//! Cycle-level simulation of the relay-station RTL semantics.
//!
//! Mirrors the generated Verilog of [`super::relay_station`] register for
//! register, so the handshake-preservation property (latency-insensitivity:
//! no token dropped, no token duplicated, order preserved, no overflow even
//! with the registered `i_rdy`) can be property-tested in Rust against
//! randomized producer/consumer stall patterns.

use crate::util::rng::Rng;
use std::collections::VecDeque;

/// One relay station instance (behavioural twin of the Verilog).
pub struct RelayStationSim {
    depth: usize,
    afull_at: usize,
    buffer: VecDeque<u64>,
    // Registered outputs, exactly as in the RTL.
    pub i_rdy: bool,
    pub o: u64,
    pub o_vld: bool,
}

impl RelayStationSim {
    pub fn new(stages: u32) -> RelayStationSim {
        let depth = ((stages + 2).next_power_of_two().max(4)) as usize;
        RelayStationSim {
            depth,
            afull_at: depth - 2,
            buffer: VecDeque::new(),
            i_rdy: false,
            o: 0,
            o_vld: false,
        }
    }

    /// One clock edge. Inputs are the producer's `i`/`i_vld` and the
    /// consumer's `o_rdy` *before* the edge; registered outputs update.
    /// Returns the value accepted this cycle, if any.
    pub fn tick(&mut self, i: u64, i_vld: bool, o_rdy: bool) -> Option<u64> {
        let afull = self.buffer.len() >= self.afull_at;
        let do_write = i_vld && self.i_rdy;
        let do_read = !self.buffer.is_empty() && (!self.o_vld || o_rdy);

        let mut accepted = None;
        if do_write {
            assert!(
                self.buffer.len() < self.depth,
                "relay station overflow: AFull margin insufficient"
            );
            self.buffer.push_back(i);
            accepted = Some(i);
        }
        if do_read {
            self.o = self.buffer.pop_front().unwrap();
            self.o_vld = true;
        } else if o_rdy {
            self.o_vld = false;
        }
        self.i_rdy = !afull;
        accepted
    }

    pub fn occupancy(&self) -> usize {
        self.buffer.len()
    }
}

/// Drive `n_tokens` through a chain of relay stations with random stalls;
/// returns (received tokens, cycles taken).
pub fn run_chain(
    stations: &mut [RelayStationSim],
    n_tokens: u64,
    rng: &mut Rng,
    stall_p: f64,
) -> (Vec<u64>, usize) {
    let mut sent = 0u64;
    let mut received = Vec::new();
    let mut cycles = 0usize;
    // Handshake values travelling between stages this cycle.
    while received.len() < n_tokens as usize {
        cycles += 1;
        assert!(cycles < 100_000, "simulation did not converge");
        // Consumer side: random stall.
        let consumer_rdy = !rng.chance(stall_p);
        // Evaluate stages back-to-front so each stage sees the downstream
        // registered outputs of *this* cycle boundary.
        // Collect current outputs first (registered, so pre-edge values).
        let n = stations.len();
        let mut vld: Vec<bool> = stations.iter().map(|s| s.o_vld).collect();
        let mut data: Vec<u64> = stations.iter().map(|s| s.o).collect();
        let mut rdy: Vec<bool> = (0..n)
            .map(|k| {
                if k + 1 < n {
                    stations[k + 1].i_rdy
                } else {
                    consumer_rdy
                }
            })
            .collect();
        // Producer: random stall.
        let produce = sent < n_tokens && !rng.chance(stall_p);
        let p_vld = produce;
        let p_data = sent;
        // Tick all stages with pre-edge values.
        for k in 0..n {
            let (i, i_vld) = if k == 0 {
                (p_data, p_vld)
            } else {
                (data[k - 1], vld[k - 1])
            };
            let o_rdy = rdy[k];
            let accepted = stations[k].tick(i, i_vld, o_rdy);
            if k == 0 {
                if accepted.is_some() {
                    sent += 1;
                }
            }
        }
        // Last stage -> consumer transfer happens when vld & rdy pre-edge.
        if vld[n - 1] && rdy[n - 1] {
            received.push(data[n - 1]);
        }
        let _ = (&mut vld, &mut data, &mut rdy);
    }
    (received, cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{forall, Gen};

    #[test]
    fn tokens_arrive_in_order_no_stalls() {
        let mut st = [RelayStationSim::new(2)];
        let mut rng = Rng::new(1);
        let (rx, _) = run_chain(&mut st, 50, &mut rng, 0.0);
        assert_eq!(rx, (0..50).collect::<Vec<u64>>());
    }

    #[test]
    fn deep_chain_preserves_stream() {
        let mut st: Vec<RelayStationSim> = (0..5).map(|_| RelayStationSim::new(1)).collect();
        let mut rng = Rng::new(2);
        let (rx, cycles) = run_chain(&mut st, 100, &mut rng, 0.3);
        assert_eq!(rx, (0..100).collect::<Vec<u64>>());
        assert!(cycles > 100); // latency added, throughput sustained
    }

    #[test]
    fn full_throughput_when_unstalled() {
        // After warm-up, one token per cycle must flow through.
        let mut st = [RelayStationSim::new(2)];
        let mut rng = Rng::new(3);
        let (_, cycles) = run_chain(&mut st, 1000, &mut rng, 0.0);
        assert!(cycles <= 1010, "II != 1: {cycles} cycles for 1000 tokens");
    }

    struct StallGen;
    impl Gen for StallGen {
        type Item = (u64, u64, usize);
        fn generate(&self, rng: &mut Rng) -> Self::Item {
            (
                rng.next_u64(),
                rng.range(1, 200) as u64,
                rng.range(1, 4),
            )
        }
        fn shrink(&self, item: &Self::Item) -> Vec<Self::Item> {
            let mut v = Vec::new();
            if item.1 > 1 {
                v.push((item.0, item.1 / 2, item.2));
            }
            if item.2 > 1 {
                v.push((item.0, item.1, item.2 - 1));
            }
            v
        }
    }

    /// Property: latency-insensitivity under arbitrary stall patterns.
    #[test]
    fn property_latency_insensitive() {
        forall(0xF00D, 40, &StallGen, |&(seed, tokens, stages)| {
            let mut st: Vec<RelayStationSim> =
                (0..stages).map(|_| RelayStationSim::new(2)).collect();
            let mut rng = Rng::new(seed);
            let (rx, _) = run_chain(&mut st, tokens, &mut rng, 0.5);
            rx == (0..tokens).collect::<Vec<u64>>()
        });
    }

    #[test]
    fn never_overflows_with_registered_ready() {
        // The assert! inside tick() fires on overflow; hammer it.
        let mut st = [RelayStationSim::new(1)];
        let mut rng = Rng::new(99);
        // Consumer almost always stalled: buffer pressure maximal.
        let mut sent = 0u64;
        for cycle in 0..2000 {
            let consumer_rdy = cycle % 17 == 0;
            let pre_vld = st[0].o_vld;
            let accepted = st[0].tick(sent, true, consumer_rdy);
            if accepted.is_some() {
                sent += 1;
            }
            let _ = pre_vld;
        }
        assert!(st[0].occupancy() <= 4);
    }
}

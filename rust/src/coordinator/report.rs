//! Evaluation orchestration: regenerates the paper's Table 1, Table 2,
//! Figure 12 and Figure 13 from the benchmark generators + the HLPS flow.
//! Shared by the CLI (`rsir table2 …`) and the bench targets.
//!
//! [`table2`] fans one job per design row onto the shared
//! [work-stealing pool](crate::util::pool::Pool); each row is an
//! independent full HLPS flow, so the matrix parallelizes embarrassingly
//! while row order (and every number in it) stays deterministic.

use crate::coordinator::flow::{run_hlps, FlowConfig, FlowStats};
use crate::designs;
use crate::device::builtin;
use crate::util::bench::Table;
use crate::util::pool::Pool;
use anyhow::Result;

/// One Table 2 row.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub app: String,
    pub target: String,
    pub hierarchy: bool,
    pub mixed_source: bool,
    pub new_fpga: bool,
    /// LUT/FF/BRAM/DSP/URAM utilization %, original design.
    pub util_pct: [f64; 5],
    /// None = unroutable with the vendor-only flow ("-" in the paper).
    pub original_mhz: Option<f64>,
    pub rir_mhz: f64,
    /// Literature reference value, when one exists.
    pub others: Option<(f64, &'static str)>,
}

impl Table2Row {
    pub fn improvement(&self) -> Option<f64> {
        self.original_mhz
            .map(|o| 100.0 * (self.rir_mhz - o) / o)
    }
}

/// The benchmark matrix of Table 2 (name, generator id, device, flags).
pub fn table2_specs() -> Vec<(&'static str, &'static str, &'static str)> {
    vec![
        ("CNN 13x4", "cnn:13x4", "u250"),
        ("CNN 13x6", "cnn:13x6", "u250"),
        ("CNN 13x8", "cnn:13x8", "u250"),
        ("CNN 13x10", "cnn:13x10", "u250"),
        ("CNN 13x12", "cnn:13x12", "u250"),
        ("LLaMA2", "llama2", "vp1552"),
        ("LLaMA2", "llama2", "vhk158"),
        ("LLaMA2", "llama2", "u55c"),
        ("LLaMA2", "llama2", "vu9p"),
        ("LLaMA2", "llama2", "u250"),
        ("LLaMA2", "llama2", "u280"),
        ("LLaMA2 (opt)", "llama2_opt", "u280"),
        ("Minimap2", "minimap2", "vp1552"),
        ("KNN", "knn", "u280"),
    ]
}

fn literature(app: &str, target: &str) -> Option<(f64, &'static str)> {
    match (app, target) {
        ("CNN 13x4", _) => Some((325.0, "[17]")),
        ("CNN 13x6", _) => Some((324.0, "[17]")),
        ("CNN 13x8", _) => Some((320.0, "[17]")),
        ("CNN 13x10", _) => Some((322.0, "[17]")),
        ("CNN 13x12", _) => Some((295.0, "[17]")),
        ("LLaMA2", "u280") | ("LLaMA2 (opt)", "u280") => Some((245.0, "[8]")),
        _ => None,
    }
}

/// Instantiate a benchmark generator by CLI id (`cnn:<rows>x<cols>`,
/// `llama2`, `llama2_opt`, `minimap2`, `knn`) — shared by `rsir flow`,
/// `rsir pipeline` and the Table 2 matrix.
pub fn generate_by_id(id: &str) -> Result<designs::Generated> {
    if let Some(dims) = id.strip_prefix("cnn:") {
        let (r, c) = dims.split_once('x').unwrap();
        return designs::cnn::generate(&designs::cnn::CnnConfig {
            rows: r.parse()?,
            cols: c.parse()?,
        });
    }
    match id {
        "llama2" => designs::llama2::generate(&designs::llama2::Llama2Config::default()),
        "llama2_opt" => designs::llama2::generate(&designs::llama2::Llama2Config {
            blocks: 4,
            opt: true,
        }),
        "minimap2" => designs::minimap2::generate(),
        "knn" => designs::knn::generate(&designs::knn::KnnConfig::default()),
        other => anyhow::bail!("unknown benchmark id '{other}'"),
    }
}

fn features(id: &str) -> (bool, bool, bool) {
    // (hierarchy, mixed-source) per the paper's Benchmark Features.
    match id {
        id if id.starts_with("cnn") => (false, false),
        "llama2" | "llama2_opt" => (true, true),
        "minimap2" => (true, false),
        "knn" => (false, true),
        _ => (false, false),
    }
    .into_tuple()
}

trait IntoTuple3 {
    fn into_tuple(self) -> (bool, bool, bool);
}
impl IntoTuple3 for (bool, bool) {
    fn into_tuple(self) -> (bool, bool, bool) {
        (self.0, self.1, false)
    }
}

/// Run one Table 2 row end-to-end.
pub fn run_row(app: &str, id: &str, target: &str, cfg: &FlowConfig) -> Result<Table2Row> {
    run_row_timed(app, id, target, cfg).map(|(row, _)| row)
}

/// Like [`run_row`], but also returns the flow's per-stage wall-time
/// breakdown (rendered by `rsir flow`).
pub fn run_row_timed(
    app: &str,
    id: &str,
    target: &str,
    cfg: &FlowConfig,
) -> Result<(Table2Row, FlowStats)> {
    let dev = builtin::by_name(target)?;
    let g = generate_by_id(id)?;
    let mut design = g.design;
    let report = run_hlps(&mut design, &dev, cfg)?;
    let (hierarchy, mixed_source, _) = features(id);
    let new_fpga = matches!(target, "vp1552" | "vhk158" | "u55c");
    // "we report the original utilization percentages on the target
    // device" — take them from the baseline when it placed, else from
    // the optimized netlist (same design resources either way).
    let util_pct = report
        .baseline
        .as_ref()
        .map(|b| b.util_pct)
        .unwrap_or(report.optimized.util_pct);
    let row = Table2Row {
        app: app.to_string(),
        target: target.to_string(),
        hierarchy,
        mixed_source,
        new_fpga,
        util_pct,
        original_mhz: report.baseline_fmax(),
        rir_mhz: report.optimized.fmax_mhz(),
        others: literature(app, target),
    };
    Ok((row, report.stats))
}

/// Run the full Table 2 (or a filtered subset by substring match on
/// `"<app>-<target>"`, case-insensitive), one pool job per row.
///
/// Rows come back in spec order regardless of completion order, and the
/// numbers are identical for any worker count (each row is an isolated
/// flow over its own design instance).
pub fn table2(filter: Option<&str>, cfg: &FlowConfig, pool: &Pool) -> Result<Vec<Table2Row>> {
    let specs: Vec<(&'static str, &'static str, &'static str)> = table2_specs()
        .into_iter()
        .filter(|(app, _, target)| {
            filter
                .map(|f| {
                    format!("{app}-{target}")
                        .to_lowercase()
                        .contains(&f.to_lowercase())
                })
                .unwrap_or(true)
        })
        .collect();
    pool.par_map(specs, |(app, id, target)| run_row(app, id, target, cfg))
        .into_iter()
        .collect()
}

/// Render Table 2 in the paper's format.
pub fn render_table2(rows: &[Table2Row]) -> Table {
    let mut t = Table::new(&[
        "Application",
        "Target",
        "Hier",
        "Mixed",
        "NewFPGA",
        "LUT%",
        "FF%",
        "BRAM%",
        "DSP%",
        "URAM%",
        "Original",
        "RIR",
        "Others",
    ]);
    for r in rows {
        let orig = r
            .original_mhz
            .map(|f| format!("{f:.0}"))
            .unwrap_or_else(|| "-".to_string());
        let rir = match r.improvement() {
            Some(imp) => format!("{:.0} (+{:.0}%)", r.rir_mhz, imp),
            None => format!("{:.0} (+inf%)", r.rir_mhz),
        };
        let others = r
            .others
            .map(|(f, src)| format!("{f:.0} {src}"))
            .unwrap_or_else(|| "N/A".to_string());
        let b = |x: bool| if x { "x" } else { "" }.to_string();
        t.row(&[
            r.app.clone(),
            r.target.clone(),
            b(r.hierarchy),
            b(r.mixed_source),
            b(r.new_fpga),
            format!("{:.0}", r.util_pct[0]),
            format!("{:.0}", r.util_pct[1]),
            format!("{:.0}", r.util_pct[2]),
            format!("{:.0}", r.util_pct[3]),
            format!("{:.0}", r.util_pct[4]),
            orig,
            rir,
            others,
        ]);
    }
    t
}

/// Table 1: lines of adaptation code per HLS tool, plus the benchmark
/// counts each frontend was validated on.
pub fn table1() -> Table {
    let mut t = Table::new(&["Software", "Dynamatic", "Catapult HLS", "Intel HLS"]);
    t.row(&[
        "Lines of code".to_string(),
        designs::dynamatic::support_loc().to_string(),
        designs::catapult::support_loc().to_string(),
        designs::intel_hls::support_loc().to_string(),
    ]);
    t.row(&[
        "Benchmarks imported".to_string(),
        designs::dynamatic::EXAMPLES.len().to_string(),
        "1".to_string(),
        designs::intel_hls::CHSTONE.len().to_string(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> FlowConfig {
        FlowConfig {
            sa_refine: false,
            ..Default::default()
        }
    }

    #[test]
    fn cnn_13x4_row_matches_paper_shape() {
        let r = run_row("CNN 13x4", "cnn:13x4", "u250", &quick_cfg()).unwrap();
        // RIR result in the AutoBridge class (paper: 335 vs 325).
        assert!(r.rir_mhz > 280.0, "rir {:.0}", r.rir_mhz);
        if let Some(orig) = r.original_mhz {
            assert!(orig < r.rir_mhz, "orig {orig:.0} rir {:.0}", r.rir_mhz);
            // Baseline in the paper's 230-250 band.
            assert!((180.0..300.0).contains(&orig), "orig {orig:.0}");
        }
        // DSP utilization ≈ 17 % of a U250.
        assert!((10.0..25.0).contains(&r.util_pct[3]), "{:?}", r.util_pct);
    }

    /// Same seed ⇒ byte-identical Table 2 rendering no matter how many
    /// workers the pool schedules the rows onto.
    #[test]
    fn table2_rows_identical_across_worker_counts() {
        let cfg = quick_cfg();
        let run = |workers: usize| {
            let pool = Pool::new(workers);
            let rows = table2(Some("llama2-u2"), &cfg, &pool).unwrap();
            render_table2(&rows).to_string()
        };
        let serial = run(1);
        assert_eq!(serial, run(8));
        // The filter must have matched the two LLaMA2 rows (u250, u280)
        // and nothing else — two rows + header + separator.
        assert_eq!(serial.lines().count(), 4, "{serial}");
    }

    #[test]
    fn table2_filter_preserves_spec_order() {
        let pool = Pool::new(4);
        let rows = table2(Some("cnn 13x4"), &quick_cfg(), &pool).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].app, "CNN 13x4");
        assert_eq!(rows[0].target, "u250");
    }

    #[test]
    fn table1_counts() {
        let t = table1();
        let s = t.to_string();
        assert!(s.contains("Dynamatic"));
        assert!(s.contains("29"));
        assert!(s.contains("12"));
    }
}

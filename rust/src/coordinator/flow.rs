//! The integrated HLPS flow (§3.4): the four-stage methodology assembled
//! from RIR plugins and passes.
//!
//! 1. **Communication analysis** — platform analysis, hierarchy rebuild,
//!    interface inference, aux partitioning + passthrough.
//! 2. **Design partitioning** — flatten; units joined by non-pipelinable
//!    connections are merged so they always share a slot.
//! 3. **Coarse-grained floorplanning** — the AutoBridge ILP (optionally
//!    refined by batched SA through the PJRT-compiled Pallas kernel);
//!    slot assignments written back as `floorplan` metadata.
//! 4. **Global interconnect synthesis** — relay stations / FF chains
//!    inserted on every slot-crossing pipelinable channel, staged along
//!    the route; the result is re-analyzed by the EDA backend.

use crate::device::model::VirtualDevice;
use crate::eda::place::PlacerConfig;
use crate::eda::vivado::{self, ImplReport};
use crate::floorplan::autobridge::{self, IlpFpConfig};
use crate::floorplan::cost::{BatchEvaluator, CostModel, CpuEvaluator};
use crate::floorplan::problem::Problem;
use crate::floorplan::sa::{self, SaConfig};
use crate::ir::core::*;
use crate::passes::manager::{PassContext, PipelineReport};
use crate::passes::pipeline_insert;
use crate::passes::registry;
use crate::timing::delay::DelayModel;
use crate::util::union_find::UnionFind;
use anyhow::{Context, Result};
use std::fmt;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Stage-4 interconnect-synthesis strategy — one of the DSE knob axes.
/// A stage-4-only knob: stage 3 never reads it, so floorplan results
/// (and the floorplan memo key) are shared across strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum PipelineStrategy {
    /// One relay station per die crossing plus one per two plain hops
    /// (`stages_for_distance`) — the paper's full pipelining.
    #[default]
    Full,
    /// Relay stations only where a channel crosses a die boundary — the
    /// latency-lean AutoBridge-style policy.
    DiesOnly,
    /// Skip stage 4 entirely (floorplan-only flow).
    Off,
}

impl PipelineStrategy {
    /// Canonical CLI / report token.
    pub fn as_str(self) -> &'static str {
        match self {
            PipelineStrategy::Full => "full",
            PipelineStrategy::DiesOnly => "dies",
            PipelineStrategy::Off => "off",
        }
    }

    /// Parse a CLI token (the output of [`Self::as_str`]).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "full" => Ok(PipelineStrategy::Full),
            "dies" => Ok(PipelineStrategy::DiesOnly),
            "off" => Ok(PipelineStrategy::Off),
            other => anyhow::bail!("unknown pipeline strategy '{other}' (full | dies | off)"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct FlowConfig {
    pub util_limit: f64,
    pub die_weight: f64,
    pub ilp: IlpFpConfig,
    /// Refine the ILP floorplan with batched SA.
    pub sa_refine: bool,
    /// SA knobs, including `SaConfig::workers` — the incremental lane's
    /// parallel-chains width (CLI `--sa-workers`; results are identical
    /// for any value). Flows through `coordinator::explore` untouched,
    /// so every Figure-12 sweep point anneals with the same settings.
    pub sa: SaConfig,
    /// Use the PJRT-compiled Pallas kernel for SA scoring (falls back to
    /// the CPU oracle when artifacts are missing).
    pub use_pjrt: bool,
    /// Stage-4 relay-station policy (a DSE axis; default [`PipelineStrategy::Full`]).
    pub pipeline: PipelineStrategy,
    pub delay: DelayModel,
}

impl Default for FlowConfig {
    fn default() -> Self {
        FlowConfig {
            util_limit: 0.70,
            die_weight: 3.0,
            ilp: IlpFpConfig::default(),
            sa_refine: true,
            sa: SaConfig {
                steps: 120,
                ..Default::default()
            },
            use_pjrt: false,
            pipeline: PipelineStrategy::default(),
            delay: DelayModel::default(),
        }
    }
}

/// Wall-clock time spent in each stage of one [`run_hlps`] invocation,
/// aggregated from the stage pipelines' [`PipelineReport`]s plus the
/// non-pass stages (floorplanning, implementation).
///
/// Purely observational: no stage *result* depends on these durations, so
/// the flow's numeric outputs stay deterministic for a given seed no
/// matter the worker count or machine load (asserted by the Table 2
/// determinism test). Rendered by the CLI after `rsir flow`.
#[derive(Debug, Clone, Default)]
pub struct FlowStats {
    /// Vendor-only baseline implementation (placement + STA).
    pub baseline: Duration,
    /// Stages 1+2: communication analysis, partitioning, netlist build.
    pub analysis: Duration,
    /// Stage 3: ILP floorplanning (+ optional SA refinement) and
    /// metadata write-back.
    pub floorplan: Duration,
    /// Stage 4: global interconnect synthesis (relay-station insertion).
    pub pipeline: Duration,
    /// Final implementation of the optimized netlist.
    pub implement: Duration,
    /// End-to-end wall time of the whole flow.
    pub total: Duration,
    /// Per-pass wall times inside the analysis stage: derived state,
    /// always equal to [`FlowReport::analysis`]`.timings()` (aggregated
    /// by pass name, repeated passes summed) — kept here so stats stay
    /// self-contained when passed around without the full report.
    pub pass_times: Vec<(String, Duration)>,
}

impl FlowStats {
    /// One-line human-readable breakdown, e.g. for the CLI.
    pub fn render(&self) -> String {
        format!(
            "stage wall times: baseline {:.2?} | analysis {:.2?} | floorplan {:.2?} | pipeline {:.2?} | implement {:.2?} | total {:.2?}",
            self.baseline, self.analysis, self.floorplan, self.pipeline, self.implement, self.total
        )
    }

    /// One-line per-pass breakdown of the analysis-stage pipeline.
    pub fn render_passes(&self) -> String {
        format!(
            "pass wall times: {}",
            crate::passes::manager::render_timings(&self.pass_times)
        )
    }
}

/// Everything [`run_hlps`] learned about one design: the optimized
/// implementation, the vendor-only baseline (which may legitimately fail
/// on congested designs), flow shape counters, and per-stage timings.
#[derive(Debug)]
pub struct FlowReport {
    pub baseline: Result<ImplReport>,
    pub optimized: ImplReport,
    pub relay_stations: usize,
    pub partitions: usize,
    pub floorplan_wirelength: f64,
    pub log: Vec<String>,
    pub evaluator_used: &'static str,
    /// Per-stage wall-clock instrumentation (observational only).
    pub stats: FlowStats,
    /// Structured record of the stages-1–2 pass pipeline (per-pass wall
    /// time, DRC outcome, log lines).
    pub analysis: PipelineReport,
}

impl FlowReport {
    pub fn baseline_fmax(&self) -> Option<f64> {
        self.baseline
            .as_ref()
            .ok()
            .filter(|r| r.routable())
            .map(|r| r.fmax_mhz())
    }

    pub fn improvement_pct(&self) -> Option<f64> {
        self.baseline_fmax()
            .map(|b| 100.0 * (self.optimized.fmax_mhz() - b) / b)
    }
}

/// Stage 1 + 2 of the integrated flow: communication analysis
/// (platform, rebuild, inference, partition, passthrough) and flattening.
/// Shared by the HLPS flow and the baseline — the *netlist* a vendor tool
/// elaborates is the same either way; only floorplanning and pipelining
/// differ.
///
/// The pass sequence is the registered
/// [`analyze-structure`](registry::ANALYZE_STRUCTURE) pipeline
/// (`platform-analyze, rebuild, iface-infer, partition-aux, passthrough,
/// iface-infer, platform-analyze, flatten` — interface inference runs
/// again post-passthrough because bypassed aux may have joined modules
/// directly, the Catapult pattern of §4.1; platform analysis runs again
/// because new aux splits need characterization too). Whether DRC runs
/// between passes is the caller's choice via `ctx.drc_after_each`.
pub fn analyze_structure(design: &mut Design, ctx: &mut PassContext) -> Result<PipelineReport> {
    registry::named(registry::ANALYZE_STRUCTURE)?.run(design, ctx)
}

/// A design snapshotted right after stages 1+2 (`analyze-structure`) ran
/// on a clone of the input, together with everything the remaining
/// stages need to resume: the pipeline report and the pass context
/// (log, name map, warm [`DesignIndex`](crate::ir::index::DesignIndex)).
///
/// This is the unit the daemon's warm cache stores, keyed by the FNV-1a
/// digest of the *input* design: analysis is a pure function of the
/// input, so resuming from a cached snapshot is byte-equivalent to
/// re-analyzing — only faster.
#[derive(Debug, Clone)]
pub struct AnalyzedDesign {
    /// The design after `analyze-structure`.
    pub design: Design,
    /// Structured record of the stage-1–2 pipeline run.
    pub report: PipelineReport,
    /// The pass context exactly as the pipeline left it; stages 3–4
    /// resume from a clone so warm and cold runs share one code path.
    pub ctx: PassContext,
}

/// Pre-warmed state for [`run_hlps_warm`], plus the state it harvested.
///
/// Both inputs are *keyed by the caller*: `analyzed` must come from the
/// same input design (same IR digest), and `cost_model` from the same
/// (design, device, `util_limit`, `die_weight`) tuple — supplying state
/// for the wrong key silently changes results. With correct keys the
/// contract is the daemon's determinism invariant: warm state changes
/// wall time only, never a single output byte.
#[derive(Default)]
pub struct FlowWarm<'a> {
    /// Stage-1–2 snapshot to resume from (skips re-analysis).
    pub analyzed: Option<Arc<AnalyzedDesign>>,
    /// Memoized SA cost model (skips `CostModel::build`).
    pub cost_model: Option<Arc<CostModel>>,
    /// Per-stage incremental caches (characterization, elaboration,
    /// placement, floorplan, delta STA). `None` runs the classic
    /// non-memoized path; `Some` routes every stage through
    /// [`StageMemo`](crate::coordinator::memo::StageMemo) — byte-identical
    /// either way, per the determinism contract.
    pub stage: Option<Arc<crate::coordinator::memo::StageMemo>>,
    /// Cooperative cancellation hook, polled between stages; returning
    /// `true` aborts the flow with a [`FlowCanceled`] error.
    pub cancel: Option<&'a (dyn Fn() -> bool + Sync)>,
    /// SA checkpoint from a compatible neighbor (same problem / device /
    /// util limit, fewer-or-equal steps) to resume refinement from. Per
    /// [`sa::anneal_resumable`]'s prefix property this changes wall time
    /// only, never a byte; an incompatible checkpoint falls back cold.
    pub sa_resume: Option<Arc<sa::SaCheckpoint>>,
    /// The snapshot this run used (computed or passed in) — callers
    /// cache it for the next request on the same design.
    pub harvest_analyzed: Option<Arc<AnalyzedDesign>>,
    /// The cost model this run used, when SA refinement ran.
    pub harvest_cost: Option<Arc<CostModel>>,
    /// SA checkpoint harvested when refinement actually annealed this
    /// run (a floorplan-memo hit skips the anneal and leaves this unset).
    pub harvest_sa: Option<Arc<sa::SaCheckpoint>>,
}

/// Typed marker error raised when a [`FlowWarm::cancel`] hook fires;
/// callers downcast it to distinguish cancellation from real failures.
#[derive(Debug, Clone, Copy)]
pub struct FlowCanceled {
    /// The stage boundary where cancellation was observed.
    pub stage: &'static str,
}

impl fmt::Display for FlowCanceled {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "flow canceled at stage boundary '{}'", self.stage)
    }
}

impl std::error::Error for FlowCanceled {}

/// Run stages 1+2 on a clone of `design` and snapshot the result. The
/// single producer of [`AnalyzedDesign`]s — both the cold flow path and
/// the daemon's cache-miss path go through here.
pub fn analyze_design(design: &Design) -> Result<AnalyzedDesign> {
    analyze_design_with(design, None)
}

/// [`analyze_design`] with an optional shared characterization memo
/// threaded into the pass context (the incremental re-flow path).
/// Annotated values are identical with or without the memo, so cache
/// state never changes an output byte.
pub fn analyze_design_with(
    design: &Design,
    chars: Option<Arc<crate::eda::synth::CharMemo>>,
) -> Result<AnalyzedDesign> {
    let mut d = design.clone();
    let mut ctx = PassContext::new();
    // The flow has never DRC-checked between stage-1 passes (mid-rebuild
    // states may be transiently inconsistent); the optimized result is
    // validated end-to-end by the e2e tests instead.
    ctx.drc_after_each = false;
    ctx.chars = chars;
    let report = analyze_structure(&mut d, &mut ctx)?;
    Ok(AnalyzedDesign {
        design: d,
        report,
        ctx,
    })
}

/// Implement an *already analyzed* design the vendor-only way: floorplan
/// hints stripped, wirelength placer with unconstrained headroom,
/// unguided STA. Shared by [`run_baseline`] and [`run_hlps_warm`] so the
/// baseline never re-analyzes when a warm snapshot exists.
pub fn implement_baseline(
    analyzed: &Design,
    dev: &VirtualDevice,
    dm: &DelayModel,
) -> Result<ImplReport> {
    implement_baseline_staged(analyzed, dev, dm, None)
}

/// [`implement_baseline`] routed through an optional [`StageMemo`]
/// (elaboration fragments, placement cache, delta STA) — byte-identical
/// to the plain path by the memo's determinism contract.
fn implement_baseline_staged(
    analyzed: &Design,
    dev: &VirtualDevice,
    dm: &DelayModel,
    stage: Option<&crate::coordinator::memo::StageMemo>,
) -> Result<ImplReport> {
    let mut nl = match stage {
        Some(m) => m.elaborate(analyzed),
        None => vivado::elaborate(analyzed),
    };
    for node in &mut nl.nodes {
        node.fixed_slot = None; // vendor flow ignores floorplan hints
    }
    // Vendor placers leave ~30 % headroom per region when unconstrained.
    let placer = PlacerConfig {
        capacity_limit: 0.72,
        ..Default::default()
    };
    let opts = crate::timing::sta::StaOptions { unguided: true };
    match stage {
        Some(m) => m.implement(&nl, dev, &placer, dm, opts, "baseline"),
        None => vivado::implement_netlist_with(&nl, dev, &placer, dm, opts),
    }
}

/// Run the baseline (vendor-only) flow: no HLPS, wirelength placer.
/// The design is structurally analyzed so the vendor tool sees the same
/// netlist, but no floorplanning or pipelining is applied and no
/// floorplan metadata is honored.
pub fn run_baseline(design: &Design, dev: &VirtualDevice, dm: &DelayModel) -> Result<ImplReport> {
    let analyzed = analyze_design(design)?;
    implement_baseline(&analyzed.design, dev, dm)
}

/// Run the full RIR HLPS flow, mutating `design` into its optimized form.
pub fn run_hlps(
    design: &mut Design,
    dev: &VirtualDevice,
    cfg: &FlowConfig,
) -> Result<FlowReport> {
    run_hlps_warm(design, dev, cfg, &mut FlowWarm::default())
}

/// [`run_hlps`] with pre-warmed state: an optional stage-1–2 snapshot
/// and memoized cost model are consumed from `warm` (computed when
/// absent, and harvested back onto `warm` either way), and an optional
/// cancellation hook is polled at every stage boundary.
///
/// Warm and cold runs share this single code path — the cold path
/// computes the same snapshot a warm path would receive — which is what
/// makes the daemon's byte-identical determinism contract structural
/// rather than aspirational.
pub fn run_hlps_warm(
    design: &mut Design,
    dev: &VirtualDevice,
    cfg: &FlowConfig,
    warm: &mut FlowWarm,
) -> Result<FlowReport> {
    let t_total = Instant::now();
    let checkpoint = |stage: &'static str| -> Result<()> {
        // Fault site `flow.stage.<stage>`: fire *before* polling
        // cancellation — a Delay then overlaps the cancellation window —
        // but let cancellation win over an injected error, so a client
        // that cancels mid-fault still gets its typed `canceled` reply
        // (and, the stage having never completed, no memo was poisoned).
        let injected = crate::testing::faults::fire_stage(stage);
        if let Some(hook) = warm.cancel {
            if hook() {
                return Err(anyhow::Error::new(FlowCanceled { stage }));
            }
        }
        match injected {
            Some(msg) => Err(anyhow::anyhow!("{msg}")),
            None => Ok(()),
        }
    };
    checkpoint("start")?;

    // ---- Stages 1 + 2: communication analysis & partitioning ------------
    let t = Instant::now();
    let analyzed = match warm.analyzed.clone() {
        Some(a) => a,
        None => Arc::new(analyze_design_with(
            design,
            warm.stage.as_ref().map(|m| m.chars()),
        )?),
    };
    warm.harvest_analyzed = Some(analyzed.clone());
    *design = analyzed.design.clone();
    let mut ctx = analyzed.ctx.clone();
    let analysis = analyzed.report.clone();
    let nl = match warm.stage.as_deref() {
        Some(m) => m.elaborate(design),
        None => vivado::elaborate(design),
    };
    let mut problem = Problem::from_netlist(&nl, dev, cfg.die_weight);
    merge_nonpipelinable(&mut problem, &nl);
    let partitions = problem.units.len();
    let stat_analysis = t.elapsed();
    checkpoint("analysis")?;

    // Vendor-only baseline over the same analyzed netlist (it was
    // historically re-analyzed from scratch; sharing the snapshot is a
    // pure wall-time win — analysis is deterministic).
    let t = Instant::now();
    let baseline =
        implement_baseline_staged(&analyzed.design, dev, &cfg.delay, warm.stage.as_deref());
    let stat_baseline = t.elapsed();
    checkpoint("baseline")?;

    // ---- Stage 3: coarse-grained floorplanning ---------------------------
    let t = Instant::now();
    let fp = match warm.stage.clone() {
        Some(memo) => {
            let key = crate::coordinator::memo::floorplan_key(&problem, dev, cfg);
            memo.floorplan(key, || floorplan_stage(&problem, dev, cfg, warm))?
        }
        None => floorplan_stage(&problem, dev, cfg, warm)?,
    };
    for line in &fp.log {
        ctx.log(line.clone());
    }
    let unit_slots = fp.unit_slots;
    let evaluator_used = fp.evaluator_used;
    let floorplan_wirelength = problem.wirelength(&unit_slots, dev);

    // Write floorplan metadata onto the flat top's instances.
    let node_slots = problem.expand(&unit_slots, nl.nodes.len());
    {
        let top_name = design.top.clone();
        let top = design.module_mut(&top_name).unwrap();
        for (n, node) in nl.nodes.iter().enumerate() {
            let pblock = dev.slots[node_slots[n]].pblock.clone();
            if let Some(inst) = top
                .instances_mut()
                .iter_mut()
                .find(|i| i.instance_name == node.path)
            {
                inst.metadata
                    .insert("floorplan", crate::util::json::Json::str(&pblock));
            }
        }
    }
    let stat_floorplan = t.elapsed();
    checkpoint("floorplan")?;

    // ---- Stage 4: global interconnect synthesis --------------------------
    let t = Instant::now();
    let relay_stations =
        insert_pipelines(design, dev, &nl, &node_slots, cfg.pipeline, &mut ctx)?;
    let stat_pipeline = t.elapsed();
    checkpoint("pipeline")?;

    // Final implementation with fixed placement.
    let t = Instant::now();
    let final_nl = match warm.stage.as_deref() {
        Some(m) => m.elaborate(design),
        None => vivado::elaborate(design),
    };
    let optimized = match warm.stage.as_deref() {
        Some(m) => m.implement(
            &final_nl,
            dev,
            &PlacerConfig::default(),
            &cfg.delay,
            crate::timing::sta::StaOptions::default(),
            "optimized",
        )?,
        None => vivado::implement_netlist(&final_nl, dev, &PlacerConfig::default(), &cfg.delay)?,
    };
    let stat_implement = t.elapsed();

    let mut log = std::mem::take(&mut ctx.log);
    log.push(format!(
        "flow: {partitions} partitions, {relay_stations} relay stations, wl {floorplan_wirelength:.0}"
    ));
    Ok(FlowReport {
        baseline,
        optimized,
        relay_stations,
        partitions,
        floorplan_wirelength,
        log,
        evaluator_used,
        stats: FlowStats {
            baseline: stat_baseline,
            analysis: stat_analysis,
            floorplan: stat_floorplan,
            pipeline: stat_pipeline,
            implement: stat_implement,
            total: t_total.elapsed(),
            pass_times: analysis.timings(),
        },
        analysis,
    })
}

/// The stage-3 floorplanning block (ILP solve + optional SA refinement),
/// extracted so the memoized and plain paths share one body. Log lines
/// are collected into the returned entry — the caller replays them into
/// the pass context — which is what makes a floorplan-cache hit
/// byte-identical to a recompute, log included.
fn floorplan_stage(
    problem: &Problem,
    dev: &VirtualDevice,
    cfg: &FlowConfig,
    warm: &mut FlowWarm,
) -> Result<crate::coordinator::memo::FloorplanEntry> {
    let mut log: Vec<String> = Vec::new();
    let mut ilp_cfg = cfg.ilp.clone();
    ilp_cfg.util_limit = cfg.util_limit;
    // The ILP result depends on no SA knob, so it routes through its own
    // SA-free memo key: DSE points differing only in SA budget miss the
    // floorplan cache (steps are keyed there) yet share this solve.
    let ilp = match warm.stage.clone() {
        Some(memo) => {
            let key = crate::coordinator::memo::ilp_key(problem, dev, &ilp_cfg);
            memo.ilp(key, || autobridge::solve(problem, dev, &ilp_cfg))
                .context("floorplan ILP")?
        }
        None => autobridge::solve(problem, dev, &ilp_cfg).context("floorplan ILP")?,
    };
    let mut unit_slots = ilp.unit_slots.clone();
    let mut evaluator_used: &'static str = "ilp-only";
    if cfg.sa_refine {
        // Built once and cloned where needed (historically built twice,
        // identically — `CostModel::build` is deterministic).
        let model = match warm.cost_model.clone() {
            Some(m) => m,
            None => Arc::new(CostModel::build(problem, dev, cfg.util_limit, 1e-4)),
        };
        warm.harvest_cost = Some(model.clone());
        let mut cpu_holder;
        let mut pjrt_holder;
        let evaluator: &mut dyn BatchEvaluator = if cfg.use_pjrt {
            match crate::runtime::Manifest::load(&crate::runtime::artifacts_dir())
                .and_then(|man| crate::runtime::PjrtEvaluator::new((*model).clone(), &man))
            {
                Ok(ev) => {
                    pjrt_holder = ev;
                    &mut pjrt_holder
                }
                Err(e) => {
                    log.push(format!("pjrt unavailable ({e}); using cpu oracle"));
                    cpu_holder = CpuEvaluator {
                        model: (*model).clone(),
                    };
                    &mut cpu_holder
                }
            }
        } else {
            cpu_holder = CpuEvaluator {
                model: (*model).clone(),
            };
            &mut cpu_holder
        };
        evaluator_used = evaluator.name();
        // `workers` only applies to the incremental lane; batch-only
        // evaluators (PJRT) anneal through the single-launch lane.
        let sa_lane = if evaluator.cost_model().is_some() {
            format!("{} sa worker(s)", cfg.sa.workers.max(1))
        } else {
            "batched lane".to_string()
        };
        let (sa_res, sa_ck) = sa::anneal_resumable(
            problem,
            dev,
            evaluator,
            Some(&unit_slots),
            &cfg.sa,
            warm.sa_resume.as_deref(),
        );
        warm.harvest_sa = sa_ck.map(Arc::new);
        // Accept SA only if it beats the ILP solution on the same metric
        // and stays feasible per-slot.
        let mut chk = CpuEvaluator {
            model: (*model).clone(),
        };
        let ilp_cost = chk.evaluate(&[unit_slots.clone()])[0];
        if sa_res.best_cost < ilp_cost && feasible(problem, &sa_res.best, dev, cfg.util_limit) {
            log.push(format!(
                "sa refine: {} -> {} ({} candidates via {}, {})",
                ilp_cost, sa_res.best_cost, sa_res.evaluated, evaluator_used, sa_lane
            ));
            unit_slots = sa_res.best;
        }
    }
    Ok(crate::coordinator::memo::FloorplanEntry {
        unit_slots,
        evaluator_used,
        log,
    })
}

/// Merge units joined by non-pipelinable edges: they must share a slot.
fn merge_nonpipelinable(problem: &mut Problem, nl: &crate::timing::netlist::FlatNetlist) {
    let n = problem.units.len();
    let mut uf = UnionFind::new(n);
    // unit index by node: problems built 1:1 node->unit.
    for e in &nl.edges {
        if !e.pipelinable {
            uf.union(e.src, e.dst);
        }
    }
    if uf.components() == n {
        return;
    }
    let groups = uf.groups();
    let mut new_units = Vec::with_capacity(groups.len());
    let mut remap = vec![0usize; n];
    for (gi, g) in groups.iter().enumerate() {
        let mut merged = problem.units[g[0]].clone();
        for &m in &g[1..] {
            merged.resources = merged.resources.add(&problem.units[m].resources);
            merged.nodes.extend(problem.units[m].nodes.iter().copied());
            if merged.fixed_slot.is_none() {
                merged.fixed_slot = problem.units[m].fixed_slot;
            }
        }
        for &m in g {
            remap[m] = gi;
        }
        new_units.push(merged);
    }
    let mut agg: std::collections::BTreeMap<(usize, usize), u64> = Default::default();
    for e in &problem.edges {
        let (a, b) = (remap[e.a], remap[e.b]);
        if a != b {
            let k = if a < b { (a, b) } else { (b, a) };
            *agg.entry(k).or_default() += e.width;
        }
    }
    problem.units = new_units;
    problem.edges = agg
        .into_iter()
        .map(|((a, b), width)| crate::floorplan::problem::UnitEdge { a, b, width })
        .collect();
}

/// Insert relay stations on every pipelinable channel that crosses slots,
/// placed along an L-shaped route. The per-channel stage count follows
/// `strategy`: [`PipelineStrategy::Full`] adds one per die crossing plus
/// one per two plain hops, [`PipelineStrategy::DiesOnly`] only the die
/// crossings, and [`PipelineStrategy::Off`] skips the stage entirely.
fn insert_pipelines(
    design: &mut Design,
    dev: &VirtualDevice,
    nl: &crate::timing::netlist::FlatNetlist,
    node_slots: &[usize],
    strategy: PipelineStrategy,
    ctx: &mut PassContext,
) -> Result<usize> {
    if strategy == PipelineStrategy::Off {
        return Ok(0);
    }
    let top = design.top.clone();
    let channels = match pipeline_insert::pipelinable_channels(design, &top, &mut ctx.index) {
        Ok(c) => c,
        Err(e) => {
            // A leaf top has no channels to pipeline. Record the typed
            // diagnostic and skip stage 4 (this used to panic).
            ctx.error(format!("interconnect synthesis skipped: {e}"));
            return Ok(0);
        }
    };
    let mut inserted = 0usize;
    for (src_inst, iface, dst_inst, _width) in channels {
        let (Some(src_n), Some(dst_n)) = (nl.node_index(&src_inst), nl.node_index(&dst_inst))
        else {
            continue;
        };
        let (s_a, s_b) = (node_slots[src_n], node_slots[dst_n]);
        if s_a == s_b {
            continue;
        }
        let route = l_route(dev, s_a, s_b);
        let (man, dies) = dev.slot_dist(s_a, s_b);
        let stages = match strategy {
            PipelineStrategy::Full => pipeline_insert::stages_for_distance(man, dies),
            PipelineStrategy::DiesOnly => dies as u32,
            PipelineStrategy::Off => unreachable!("handled above"),
        };
        if stages == 0 {
            continue;
        }
        // Place relay stations at evenly spaced slots along the route.
        let mut prev = src_inst.clone();
        let mut prev_iface = iface.clone();
        for k in 0..stages {
            let pos = ((k as usize + 1) * route.len()) / (stages as usize + 1);
            let slot = route[pos.min(route.len() - 1)];
            let pblock = dev.slots[slot].pblock.clone();
            let rs = pipeline_insert::insert_relay_station(
                design,
                &top,
                &prev,
                &prev_iface,
                1,
                Some(&pblock),
                ctx,
            )?;
            prev = rs;
            prev_iface = "o".to_string();
            inserted += 1;
        }
    }
    Ok(inserted)
}

/// L-shaped slot route from a to b (inclusive), vertical-first.
fn l_route(dev: &VirtualDevice, a: usize, b: usize) -> Vec<usize> {
    let (ax, ay) = (dev.slots[a].x, dev.slots[a].y);
    let (bx, by) = (dev.slots[b].x, dev.slots[b].y);
    let mut out = Vec::new();
    let mut y = ay;
    while y != by {
        y = if by > y { y + 1 } else { y - 1 };
        out.push(dev.slot_index(ax, y));
    }
    let mut x = ax;
    while x != bx {
        x = if bx > x { x + 1 } else { x - 1 };
        out.push(dev.slot_index(x, by));
    }
    if out.is_empty() {
        out.push(a);
    }
    out
}

/// Per-slot feasibility at the given utilization limit.
fn feasible(problem: &Problem, slots: &[usize], dev: &VirtualDevice, limit: f64) -> bool {
    let mut used = vec![Resources::ZERO; dev.num_slots()];
    for (u, &s) in problem.units.iter().zip(slots) {
        used[s] = used[s].add(&u.resources);
    }
    used.iter()
        .zip(&dev.slots)
        .all(|(u, s)| u.max_util(&s.capacity) <= limit + 1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::ir::builder::*;

    /// A chain of heavy stages that cannot fit one slot: the textbook
    /// HLPS win — the baseline packs and congests / stretches nets, RIR
    /// spreads and pipelines.
    fn heavy_chain(dev: &VirtualDevice, n: usize, frac: f64) -> Design {
        let cap = dev.slots[dev.num_slots() - 1].capacity.lut;
        let mut d = Design::new("Top");
        let mut top = GroupedBuilder::new("Top")
            .port("ap_clk", Dir::In, 1)
            .port("ap_rst_n", Dir::In, 1)
            .iface(Interface::Clock {
                port: "ap_clk".into(),
            })
            .iface(Interface::Reset {
                port: "ap_rst_n".into(),
                active_high: false,
            });
        for i in 0..n {
            let m = LeafBuilder::verilog_stub(format!("Stage{i}"))
                .clk_rst()
                .handshake("i", Dir::In, 64)
                .handshake("o", Dir::Out, 64)
                .resource(Resources::new(cap * frac, cap * frac, 20.0, 100.0, 4.0))
                .meta(
                    "timing",
                    crate::util::json::Json::parse(r#"{"internal_ns": 3.0}"#).unwrap(),
                )
                .build();
            d.add(m);
        }
        for i in 0..n - 1 {
            top = top
                .wire(&format!("w{i}"), 64)
                .wire(&format!("w{i}_vld"), 1)
                .wire(&format!("w{i}_rdy"), 1);
        }
        for i in 0..n {
            let mut inst = Instance::new(format!("s{i}"), format!("Stage{i}"));
            inst.connect("ap_clk", ConnExpr::id("ap_clk"));
            inst.connect("ap_rst_n", ConnExpr::id("ap_rst_n"));
            if i > 0 {
                inst.connect("i", ConnExpr::id(&format!("w{}", i - 1)));
                inst.connect("i_vld", ConnExpr::id(&format!("w{}_vld", i - 1)));
                inst.connect("i_rdy", ConnExpr::id(&format!("w{}_rdy", i - 1)));
            }
            if i + 1 < n {
                inst.connect("o", ConnExpr::id(&format!("w{i}")));
                inst.connect("o_vld", ConnExpr::id(&format!("w{i}_vld")));
                inst.connect("o_rdy", ConnExpr::id(&format!("w{i}_rdy")));
            }
            top = top.inst_full(inst);
        }
        d.add(top.build());
        d
    }

    #[test]
    fn hlps_beats_baseline_on_multi_die_chain() {
        let dev = builtin::by_name("u280").unwrap();
        let mut d = heavy_chain(&dev, 6, 0.40);
        let cfg = FlowConfig {
            sa_refine: false,
            ..Default::default()
        };
        let report = run_hlps(&mut d, &dev, &cfg).unwrap();
        assert!(report.optimized.routable(), "{:?}", report.optimized.timing.unroutable_reason);
        let opt = report.optimized.fmax_mhz();
        assert!(report.relay_stations > 0, "no pipelining happened");
        if let Some(base) = report.baseline_fmax() {
            assert!(
                opt > base * 1.15,
                "expected >15% gain: baseline {base:.0} vs optimized {opt:.0}"
            );
        }
        // Optimized design should run near the stages' internal limit.
        assert!(opt > 250.0, "optimized only {opt:.0} MHz");
    }

    #[test]
    fn floorplan_metadata_written() {
        let dev = builtin::by_name("u280").unwrap();
        let mut d = heavy_chain(&dev, 6, 0.40);
        let cfg = FlowConfig {
            sa_refine: false,
            ..Default::default()
        };
        run_hlps(&mut d, &dev, &cfg).unwrap();
        let top = d.top_module();
        let pinned = top
            .instances()
            .iter()
            .filter(|i| i.metadata.contains_key("floorplan"))
            .count();
        assert!(pinned >= 6);
    }

    #[test]
    fn sa_refinement_never_regresses() {
        let dev = builtin::by_name("u250").unwrap();
        let mut d1 = heavy_chain(&dev, 6, 0.30);
        let mut d2 = heavy_chain(&dev, 6, 0.30);
        let no_sa = run_hlps(
            &mut d1,
            &dev,
            &FlowConfig {
                sa_refine: false,
                ..Default::default()
            },
        )
        .unwrap();
        let with_sa = run_hlps(
            &mut d2,
            &dev,
            &FlowConfig {
                sa_refine: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(with_sa.floorplan_wirelength <= no_sa.floorplan_wirelength + 1e-6);
    }

    /// Warm state must change wall time only, never bytes: a run resumed
    /// from a harvested snapshot + cost model is identical to a cold run.
    #[test]
    fn warm_state_changes_nothing() {
        let dev = builtin::by_name("u280").unwrap();
        let cfg = FlowConfig::default();

        let mut cold_d = heavy_chain(&dev, 6, 0.40);
        let mut cold_warm = FlowWarm::default();
        let cold = run_hlps_warm(&mut cold_d, &dev, &cfg, &mut cold_warm).unwrap();
        assert!(cold_warm.harvest_analyzed.is_some());
        assert!(cold_warm.harvest_cost.is_some());

        let mut warm_d = heavy_chain(&dev, 6, 0.40);
        let mut warm = FlowWarm {
            analyzed: cold_warm.harvest_analyzed.clone(),
            cost_model: cold_warm.harvest_cost.clone(),
            ..Default::default()
        };
        let hot = run_hlps_warm(&mut warm_d, &dev, &cfg, &mut warm).unwrap();

        let cold_json = crate::ir::schema::design_to_json(&cold_d).dump();
        let warm_json = crate::ir::schema::design_to_json(&warm_d).dump();
        assert_eq!(cold_json, warm_json, "warm run produced different IR");
        assert_eq!(cold.partitions, hot.partitions);
        assert_eq!(cold.relay_stations, hot.relay_stations);
        assert_eq!(cold.floorplan_wirelength, hot.floorplan_wirelength);
        assert_eq!(cold.optimized.fmax_mhz(), hot.optimized.fmax_mhz());
        assert_eq!(cold.log, hot.log);
        assert_eq!(cold.evaluator_used, hot.evaluator_used);
    }

    /// The stage memo must change wall time only: a cold run and two
    /// consecutive runs through one shared memo are byte-identical.
    #[test]
    fn stage_memo_changes_nothing() {
        let dev = builtin::by_name("u280").unwrap();
        let cfg = FlowConfig::default();

        let mut cold_d = heavy_chain(&dev, 6, 0.40);
        let cold = run_hlps(&mut cold_d, &dev, &cfg).unwrap();

        let memo = Arc::new(crate::coordinator::memo::StageMemo::new(32));
        for pass in 0..2 {
            let mut d = heavy_chain(&dev, 6, 0.40);
            let mut warm = FlowWarm {
                stage: Some(memo.clone()),
                ..Default::default()
            };
            let hot = run_hlps_warm(&mut d, &dev, &cfg, &mut warm).unwrap();
            assert_eq!(
                crate::ir::schema::design_to_json(&cold_d).dump(),
                crate::ir::schema::design_to_json(&d).dump(),
                "pass {pass}: memoized run produced different IR"
            );
            assert_eq!(cold.log, hot.log, "pass {pass}");
            assert_eq!(cold.partitions, hot.partitions);
            assert_eq!(cold.relay_stations, hot.relay_stations);
            assert_eq!(cold.floorplan_wirelength, hot.floorplan_wirelength);
            assert_eq!(cold.evaluator_used, hot.evaluator_used);
            assert_eq!(
                format!("{:?}", cold.optimized),
                format!("{:?}", hot.optimized),
                "pass {pass}"
            );
            assert_eq!(
                format!("{:?}", cold.baseline),
                format!("{:?}", hot.baseline),
                "pass {pass}"
            );
        }
        // The second run must have hit the big caches; the delta-STA
        // lane must have taken over after the first full computes.
        let stats = memo.stats();
        let get = |k: &str| stats.iter().find(|(n, _)| *n == k).unwrap().1;
        assert!(get("flat_netlists").hits >= 1, "{stats:?}");
        assert!(get("floorplans").hits >= 1, "{stats:?}");
        assert!(get("placements").hits >= 1, "{stats:?}");
        assert!(get("sta_delta").hits >= 1, "{stats:?}");
    }

    /// A firing cancel hook aborts with a downcastable [`FlowCanceled`].
    #[test]
    fn cancel_hook_aborts_with_typed_error() {
        let dev = builtin::by_name("u280").unwrap();
        let mut d = heavy_chain(&dev, 6, 0.40);
        let hook = || true;
        let mut warm = FlowWarm {
            cancel: Some(&hook),
            ..Default::default()
        };
        let err = run_hlps_warm(&mut d, &dev, &FlowConfig::default(), &mut warm).unwrap_err();
        let canceled = err
            .downcast_ref::<FlowCanceled>()
            .expect("expected FlowCanceled");
        assert_eq!(canceled.stage, "start");
    }

    /// The pipelining strategy is a stage-4-only knob: stage 3 (and the
    /// floorplan wirelength) is identical across strategies, while the
    /// relay-station count shrinks monotonically Full → DiesOnly → Off.
    #[test]
    fn pipeline_strategy_scales_relay_stations() {
        let dev = builtin::by_name("u280").unwrap();
        let base = FlowConfig {
            sa_refine: false,
            ..Default::default()
        };
        let mut counts = Vec::new();
        let mut wls = Vec::new();
        for strategy in [
            PipelineStrategy::Full,
            PipelineStrategy::DiesOnly,
            PipelineStrategy::Off,
        ] {
            let mut d = heavy_chain(&dev, 6, 0.40);
            let cfg = FlowConfig {
                pipeline: strategy,
                ..base.clone()
            };
            let report = run_hlps(&mut d, &dev, &cfg).unwrap();
            counts.push(report.relay_stations);
            wls.push(report.floorplan_wirelength);
        }
        assert!(counts[0] > 0, "{counts:?}");
        assert!(counts[1] <= counts[0], "{counts:?}");
        assert_eq!(counts[2], 0, "{counts:?}");
        assert!(wls.iter().all(|&w| w == wls[0]), "{wls:?}");
    }

    #[test]
    fn pipeline_strategy_tokens_round_trip() {
        for s in [
            PipelineStrategy::Full,
            PipelineStrategy::DiesOnly,
            PipelineStrategy::Off,
        ] {
            assert_eq!(PipelineStrategy::parse(s.as_str()).unwrap(), s);
        }
        assert!(PipelineStrategy::parse("sometimes").is_err());
    }

    #[test]
    fn small_design_stays_single_slot() {
        let dev = builtin::by_name("u250").unwrap();
        let mut d = heavy_chain(&dev, 3, 0.05);
        let report = run_hlps(
            &mut d,
            &dev,
            &FlowConfig {
                sa_refine: false,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.relay_stations, 0);
        assert_eq!(report.floorplan_wirelength, 0.0);
    }
}

//! L3 coordination: the integrated four-stage HLPS flow (§3.4), the
//! floorplan explorer (§4.2), the parallel-synthesis driver (§4.3), and
//! the evaluation orchestration regenerating the paper's tables/figures.

pub mod explore;
pub mod flow;
pub mod parallel_synth;
pub mod report;

pub use flow::{run_baseline, run_hlps, FlowConfig, FlowReport};

//! L3 coordination: the integrated four-stage HLPS flow (§3.4), the
//! floorplan explorer (§4.2), the multi-dimensional design-space
//! explorer ([`dse`]), the parallel-synthesis driver (§4.3), and the
//! evaluation orchestration regenerating the paper's tables/figures.
//!
//! All batch surfaces — the Table 2 row matrix ([`report::table2`]), the
//! Figure 12 utilization sweep ([`explore::explore`]) and the Figure 13
//! per-slot synthesis ([`parallel_synth::run`]) — execute on the shared
//! work-stealing [`crate::util::pool::Pool`]; results are returned in
//! input order, so every table and figure is deterministic for a given
//! seed regardless of the worker count.

pub mod dse;
pub mod explore;
pub mod flow;
pub mod memo;
pub mod parallel_synth;
pub mod report;

pub use flow::{run_baseline, run_hlps, FlowConfig, FlowReport, FlowStats};

//! Parallel synthesis (§4.3 / Figure 13): after floorplanning, each slot
//! group can be synthesized concurrently, with the top level seeing the
//! groups as black boxes, then assembled from post-synthesis netlists.
//! "We implement the parallel synthesis program as a standalone RIR
//! backend plugin."
//!
//! Two numbers are reported per design:
//! * the *modeled* vendor wall time (the [`SynthTimeModel`] — Vivado
//!   doesn't run here), monolithic vs per-slot-parallel, which
//!   regenerates Figure 13's bars;
//! * the *measured* wall time of actually running our own synthesis
//!   surrogate (estimation + netlist generation) sequentially vs on
//!   threads, demonstrating that the plugin's parallelism is real.

use crate::device::model::VirtualDevice;
use crate::eda::synthtime::SynthTimeModel;
use crate::ir::core::{Design, Resources};
use crate::plugins::exporter;
use crate::timing::netlist::ModuleCharacteristics;
use crate::util::pool::Pool;
use anyhow::Result;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct ParallelSynthReport {
    /// Per-slot resource groups (only non-empty slots).
    pub groups: Vec<Resources>,
    pub modeled_monolithic_s: f64,
    pub modeled_parallel_s: f64,
    pub modeled_speedup: f64,
    pub measured_sequential: std::time::Duration,
    pub measured_parallel: std::time::Duration,
    pub workers: usize,
}

/// Group the placed design's leaf instances by slot and synthesize the
/// groups in parallel (threads), comparing against the sequential run.
pub fn run(
    design: &Design,
    dev: &VirtualDevice,
    workers: usize,
    model: &SynthTimeModel,
) -> Result<ParallelSynthReport> {
    let nl = crate::eda::vivado::elaborate(design);
    // Group nodes by their floorplan slot (unplaced nodes go to slot 0).
    let mut groups_res = vec![Resources::ZERO; dev.num_slots()];
    let mut groups_mods: Vec<Vec<String>> = vec![Vec::new(); dev.num_slots()];
    for node in &nl.nodes {
        let slot = node
            .fixed_slot
            .as_ref()
            .and_then(|pb| dev.slots.iter().position(|s| &s.pblock == pb))
            .unwrap_or(0);
        groups_res[slot] = groups_res[slot].add(&node.resources);
        groups_mods[slot].push(node.module.clone());
    }
    let nonempty: Vec<usize> = (0..dev.num_slots())
        .filter(|&s| !groups_mods[s].is_empty())
        .collect();
    if nonempty.is_empty() {
        anyhow::bail!("design has no placed leaf instances (run the flow first)");
    }
    let groups: Vec<Resources> = nonempty.iter().map(|&s| groups_res[s]).collect();

    // Modeled vendor times (Figure 13).
    let total = groups.iter().fold(Resources::ZERO, |a, g| a.add(g));
    let modeled_monolithic_s = model.monolithic_s(&total);
    let modeled_parallel_s = model.parallel_s(&groups, workers);

    // Measured: run our synthesis surrogate per group, sequentially vs on
    // the work-stealing pool. The surrogate work = re-estimating every
    // module of the group from its source + exporting the group's netlist
    // stub. The pool is scoped, so the design is borrowed — no clone.
    let work = |mods: &[String]| -> f64 {
        let est = crate::eda::synth::SynthEstimator::default();
        let mut acc = 0.0f64;
        for mname in mods {
            if let Some(m) = design.module(mname) {
                let r = est.resources(m);
                acc += r.lut + r.ff;
            }
        }
        // netlist stub generation for the group
        acc
    };
    let t0 = Instant::now();
    let mut seq_acc = 0.0;
    for &s in &nonempty {
        seq_acc += work(&groups_mods[s]);
    }
    let measured_sequential = t0.elapsed();

    // One pool job per slot group: with more workers than groups the
    // extra workers simply stay idle, instead of the old chunking which
    // degenerated into one thread per group with no `workers` cap at all.
    let pool = Pool::new(workers);
    let t1 = Instant::now();
    let par_acc: f64 = pool
        .par_map(nonempty.clone(), |s| work(&groups_mods[s]))
        .iter()
        .sum();
    let measured_parallel = t1.elapsed();
    // Keep the work honest (same totals) — floating error tolerated.
    debug_assert!((seq_acc - par_acc).abs() <= 1e-6 * seq_acc.abs().max(1.0));

    // Assembly step (both flows export the final netlist once).
    let _ = exporter::export(design)?;

    Ok(ParallelSynthReport {
        modeled_speedup: modeled_monolithic_s / modeled_parallel_s,
        groups,
        modeled_monolithic_s,
        modeled_parallel_s,
        measured_sequential,
        measured_parallel,
        workers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::flow::{run_hlps, FlowConfig};
    use crate::designs::cnn::{self, CnnConfig};
    use crate::device::builtin;

    #[test]
    fn parallel_synth_after_flow() {
        let dev = builtin::by_name("u250").unwrap();
        // 13x4 needs >=2 slots by DSP count.
        let g = cnn::generate(&CnnConfig { rows: 13, cols: 4 }).unwrap();
        let mut d = g.design;
        run_hlps(
            &mut d,
            &dev,
            &FlowConfig {
                sa_refine: false,
                ..Default::default()
            },
        )
        .unwrap();
        let rep = run(&d, &dev, 8, &SynthTimeModel::default()).unwrap();
        assert!(rep.groups.len() >= 2, "expected multiple slot groups");
        assert!(rep.modeled_speedup > 1.0, "speedup {}", rep.modeled_speedup);
    }

    #[test]
    fn unplaced_design_is_one_group() {
        let dev = builtin::by_name("u250").unwrap();
        let g = cnn::generate(&CnnConfig { rows: 2, cols: 2 }).unwrap();
        let mut d = g.design;
        // Structure only (no floorplan metadata): everything in group 0.
        use crate::passes::manager::{Pass, PassContext};
        crate::passes::rebuild::RebuildAll
            .run(&mut d, &mut PassContext::new())
            .unwrap();
        let rep = run(&d, &dev, 4, &SynthTimeModel::default()).unwrap();
        assert_eq!(rep.groups.len(), 1);
        // One group: parallel flow only adds assembly overhead.
        assert!(rep.modeled_speedup <= 1.0 + 1e-9);
        // A genuinely invalid input (leaf top) errors cleanly.
        assert!(run(&g_err(), &dev, 4, &SynthTimeModel::default()).is_err());
    }

    /// A design whose top is a *leaf* module: elaboration finds no leaf
    /// instances at all, so there is nothing to group and `run` must
    /// reject it (unlike a merely un-floorplanned design, which is valid
    /// and collapses into a single group).
    fn g_err() -> crate::ir::core::Design {
        use crate::ir::builder::LeafBuilder;
        let mut d = crate::ir::core::Design::new("Lonely");
        d.add(LeafBuilder::verilog_stub("Lonely").clk_rst().build());
        d
    }
}

//! Digest-keyed stage memoization: the incremental re-flow engine.
//!
//! One [`StageMemo`] holds every per-stage cache the flow can reuse when
//! a design is re-run after a small edit:
//!
//! * **characterization** ([`CharMemo`]) — per-module resource/timing
//!   estimation, keyed by the module's own JSON digest;
//! * **elaboration** ([`FlattenMemo`]) — per-module flat fragments and
//!   whole netlists, keyed by IR subtree digests (dirty-slot
//!   re-elaboration: only modules on the edited path re-flatten);
//! * **placement** — keyed by exactly the placer's inputs (node
//!   resources + pins, edge topology, device, config — *not*
//!   `internal_ns`, which the placer never reads, so a pure timing edit
//!   reuses the placement verbatim);
//! * **floorplanning** — the whole stage-3 ILP + SA block, keyed by the
//!   partitioning problem and every floorplan knob;
//! * **ILP solves** — an SA-knob-free sub-key of the floorplan block,
//!   so DSE points that differ only in SA budget share one ILP solve;
//! * **STA terms** ([`StaTerms`]) — the delta-STA lane: prior per-slot /
//!   per-edge terms are patched instead of recomputed when the edit's
//!   cone allows it.
//!
//! The contract everywhere is the daemon's determinism invariant: memo
//! state changes wall time only, never a single output byte. Placement
//! and floorplan entries are exact-key lookups of deterministic
//! functions; the delta-STA lane self-validates (it falls back to a full
//! recompute whenever its fingerprints disagree), so even a coarse STA
//! key can never change a result. All caches are interior-mutable
//! behind poison-recovering locks: a panicking job cannot wedge a
//! shared memo (same policy as the daemon's request caches).
//!
//! Caveat, documented rather than keyed-around: a cached floorplan entry
//! replays the stage-3 log lines recorded when it was computed. With
//! `use_pjrt` those lines mention runtime-artifact availability, so the
//! cache assumes a stable artifact environment within one process — true
//! for the daemon, which is the only long-lived holder.

use crate::device::model::VirtualDevice;
use crate::eda::place::PlacerConfig;
use crate::eda::synth::CharMemo;
use crate::eda::vivado::{self, ImplReport};
use crate::floorplan::problem::Problem;
use crate::ir::digest::Fnv;
use crate::timing::delay::DelayModel;
use crate::timing::netlist::{FlatNetlist, FlattenMemo};
use crate::timing::sta::{analyze_delta, Placement, StaOptions, StaTerms, TimingReport};
use crate::util::lru::{fnv1a64, CacheStats, Lru, VerifiedLru};
use anyhow::Result;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// The memoized result of the whole stage-3 floorplanning block (ILP
/// solve + optional SA refinement), including the log lines the block
/// emitted so a cache hit replays them byte-for-byte.
#[derive(Debug, Clone)]
pub struct FloorplanEntry {
    /// Slot index per partitioning unit.
    pub unit_slots: Vec<usize>,
    /// `BatchEvaluator::name()` of the evaluator that scored SA (or
    /// `"ilp-only"` when refinement was off) — `&'static str` because
    /// every evaluator's name is.
    pub evaluator_used: &'static str,
    /// Log lines the block pushed, in order.
    pub log: Vec<String>,
}

/// Shared per-stage caches for incremental re-flow. Cheap to construct;
/// wrap in an [`Arc`] to share across flows / daemon jobs.
pub struct StageMemo {
    chars: Arc<CharMemo>,
    flatten: Mutex<FlattenMemo>,
    /// Placements feed STA and the assembled report directly, so this
    /// tier is digest-verified: a corrupted entry (injected via the
    /// `memo.place.insert` fault site, or a real memory fault) is
    /// evicted on hit and recomputed cold instead of skewing timing.
    placements: Mutex<VerifiedLru<u64, Placement>>,
    floorplans: Mutex<Lru<u64, FloorplanEntry>>,
    /// ILP solves keyed by [`ilp_key`] — a *sub*-key of the floorplan
    /// block: it excludes every SA knob, so DSE points that differ only
    /// in SA budget share one ILP solve even though their floorplan
    /// entries differ.
    ilps: Mutex<Lru<u64, crate::floorplan::FloorplanResult>>,
    sta: Mutex<Lru<u64, StaTerms>>,
    /// STA runs that reused patched terms (the delta lane).
    sta_delta: AtomicU64,
    /// STA runs that recomputed from scratch (cold or fallback).
    sta_full: AtomicU64,
}

impl fmt::Debug for StageMemo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("StageMemo").field("stats", &self.stats()).finish()
    }
}

impl StageMemo {
    pub fn new(cap: usize) -> Self {
        StageMemo {
            chars: Arc::new(CharMemo::new(cap.max(1) * 64)),
            flatten: Mutex::new(FlattenMemo::new(cap.max(1) * 16)),
            placements: Mutex::new(VerifiedLru::new(cap, placement_digest)),
            floorplans: Mutex::new(Lru::new(cap)),
            ilps: Mutex::new(Lru::new(cap)),
            sta: Mutex::new(Lru::new(cap)),
            sta_delta: AtomicU64::new(0),
            sta_full: AtomicU64::new(0),
        }
    }

    /// A memo whose caches never retain anything (`cap == 0`): every
    /// lookup misses, so the incremental code paths are exercised with
    /// cold-run results — the one-shot lane runs with this.
    pub fn disabled() -> Self {
        let mut m = StageMemo::new(0);
        m.chars = Arc::new(CharMemo::new(0));
        m.flatten = Mutex::new(FlattenMemo::new(0));
        m
    }

    /// The shared characterization memo, for threading into a
    /// [`PassContext`](crate::passes::manager::PassContext).
    pub fn chars(&self) -> Arc<CharMemo> {
        self.chars.clone()
    }

    /// Elaborate via the fragment cache: byte-identical to
    /// [`vivado::elaborate`], but only modules whose subtree digest is
    /// new get re-flattened.
    pub fn elaborate(&self, design: &crate::ir::core::Design) -> FlatNetlist {
        let chars = self.chars.clone();
        crate::timing::netlist::flatten_incremental(design, &*chars, &mut lock(&self.flatten))
    }

    /// Place via the placement cache. Returns `None` exactly when the
    /// underlying placer does.
    pub fn place(
        &self,
        nl: &FlatNetlist,
        dev: &VirtualDevice,
        cfg: &PlacerConfig,
    ) -> Option<Placement> {
        let key = place_key(nl, dev, cfg);
        if let Some(p) = lock(&self.placements).get(&key, false) {
            return Some(p);
        }
        let p = crate::eda::place::place(nl, dev, cfg)?;
        // Fault site: `Corrupt` stores a flipped digest (the next hit
        // detects and evicts it), `Skip` drops the insert. Both degrade
        // to a cold recompute — never a wrong placement.
        match crate::testing::faults::fire_cache("memo.place.insert") {
            crate::testing::faults::CacheFault::Skip => {}
            crate::testing::faults::CacheFault::Corrupt => {
                lock(&self.placements).put(key, p.clone(), true)
            }
            crate::testing::faults::CacheFault::None => {
                lock(&self.placements).put(key, p.clone(), false)
            }
        }
        Some(p)
    }

    /// STA via the delta lane: the previous run's terms for the same
    /// `role` are patched when their fingerprints prove it safe, else a
    /// full recompute runs. Either way the report is bit-identical to
    /// [`crate::timing::sta::analyze_with`].
    pub fn analyze(
        &self,
        nl: &FlatNetlist,
        placement: &Placement,
        dev: &VirtualDevice,
        dm: &DelayModel,
        opts: StaOptions,
        role: &'static str,
    ) -> TimingReport {
        let key = sta_key(nl, dev, opts, role);
        let prev = lock(&self.sta).get(&key);
        let (report, terms, used_delta) =
            analyze_delta(nl, placement, dev, dm, opts, prev.as_ref());
        if used_delta {
            self.sta_delta.fetch_add(1, Ordering::Relaxed);
        } else {
            self.sta_full.fetch_add(1, Ordering::Relaxed);
        }
        lock(&self.sta).put(key, terms);
        report
    }

    /// The memoized backend: place (cached) + STA (delta lane) +
    /// [`vivado::assemble_report`]. Identical bytes to
    /// [`vivado::implement_netlist_with`], including the error message.
    pub fn implement(
        &self,
        nl: &FlatNetlist,
        dev: &VirtualDevice,
        placer: &PlacerConfig,
        dm: &DelayModel,
        opts: StaOptions,
        role: &'static str,
    ) -> Result<ImplReport> {
        let placement = self.place(nl, dev, placer).ok_or_else(|| {
            anyhow::Error::new(crate::floorplan::Infeasible::new(
                "placement failed: design does not fit",
            ))
        })?;
        let timing = self.analyze(nl, &placement, dev, dm, opts, role);
        Ok(vivado::assemble_report(nl, dev, placement, timing))
    }

    /// Memoize one ILP floorplan solve under `key` (from [`ilp_key`]).
    /// On a miss, `compute` runs and its result is retained; errors are
    /// returned uncached — in particular a typed
    /// [`Infeasible`](crate::floorplan::Infeasible) outcome is
    /// re-derived per call, so every sweep point reports its own exact
    /// limit in the message.
    pub fn ilp<F>(&self, key: u64, compute: F) -> Result<crate::floorplan::FloorplanResult>
    where
        F: FnOnce() -> Result<crate::floorplan::FloorplanResult>,
    {
        if let Some(hit) = lock(&self.ilps).get(&key) {
            return Ok(hit);
        }
        let r = compute()?;
        lock(&self.ilps).put(key, r.clone());
        Ok(r)
    }

    /// Memoize one stage-3 floorplanning block under `key` (from
    /// [`floorplan_key`]). On a miss, `compute` runs and its result is
    /// retained; errors are returned uncached.
    pub fn floorplan<F>(&self, key: u64, compute: F) -> Result<FloorplanEntry>
    where
        F: FnOnce() -> Result<FloorplanEntry>,
    {
        if let Some(hit) = lock(&self.floorplans).get(&key) {
            return Ok(hit);
        }
        let entry = compute()?;
        lock(&self.floorplans).put(key, entry.clone());
        Ok(entry)
    }

    /// Entries the placement tier's integrity verification has evicted
    /// (rolled up into the daemon's `corruptions` diagnostic).
    pub fn corruptions(&self) -> u64 {
        lock(&self.placements).corrupt_dropped()
    }

    /// Per-stage counter snapshots, in a stable render order. The
    /// `sta_delta` entry abuses the hit/miss pair as delta-run /
    /// full-run counters (its `len`/`cap` are the terms cache's).
    pub fn stats(&self) -> Vec<(&'static str, CacheStats)> {
        let (fragments, netlists) = lock(&self.flatten).stats();
        let terms = lock(&self.sta).stats();
        vec![
            ("module_chars", self.chars.stats()),
            ("flat_fragments", fragments),
            ("flat_netlists", netlists),
            ("placements", lock(&self.placements).stats()),
            ("floorplans", lock(&self.floorplans).stats()),
            ("ilps", lock(&self.ilps).stats()),
            (
                "sta_delta",
                CacheStats {
                    hits: self.sta_delta.load(Ordering::Relaxed),
                    misses: self.sta_full.load(Ordering::Relaxed),
                    len: terms.len,
                    cap: terms.cap,
                },
            ),
        ]
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Integrity digest for a cached [`Placement`]: FNV over its `Debug`
/// rendering. `Placement` derives `Debug` structurally, so any field
/// change alters the rendering — good enough for corruption *detection*
/// (the [`VerifiedLru`] contract; this is not an adversarial MAC).
fn placement_digest(p: &Placement) -> u64 {
    fnv1a64(format!("{p:?}").as_bytes())
}

/// Fingerprint of exactly the inputs [`crate::eda::place::place`] reads:
/// per-node resources and fixed-slot pin, edge topology and widths, the
/// device, and every [`PlacerConfig`] knob. Deliberately excludes
/// `internal_ns`, `is_pipeline`, node paths, and edge pipelinability —
/// the placer never looks at them, so a pure timing edit keys to the
/// same placement.
fn place_key(nl: &FlatNetlist, dev: &VirtualDevice, cfg: &PlacerConfig) -> u64 {
    let mut f = Fnv::new();
    f.write_u64(dev.fingerprint());
    f.write_u64(cfg.seed)
        .write_usize(cfg.iterations)
        .write_f64(cfg.t0_frac)
        .write_f64(cfg.capacity_limit)
        .write_f64(cfg.die_weight);
    f.write_usize(nl.nodes.len());
    for n in &nl.nodes {
        f.write_f64(n.resources.lut)
            .write_f64(n.resources.ff)
            .write_f64(n.resources.bram)
            .write_f64(n.resources.dsp)
            .write_f64(n.resources.uram);
        match &n.fixed_slot {
            Some(pb) => {
                f.write_bool(true);
                f.write_str(pb);
            }
            None => {
                f.write_bool(false);
            }
        }
    }
    f.write_usize(nl.edges.len());
    for e in &nl.edges {
        f.write_usize(e.src).write_usize(e.dst).write_u64(e.width);
    }
    f.finish()
}

/// Coarse key for the STA terms cache: role + device + options + node
/// count. Coarseness is safe — [`StaTerms`] carries full fingerprints
/// and `analyze_delta` falls back to a from-scratch compute on any
/// mismatch — it only trades hit rate, never correctness.
fn sta_key(nl: &FlatNetlist, dev: &VirtualDevice, opts: StaOptions, role: &'static str) -> u64 {
    let mut f = Fnv::new();
    f.write_str(role);
    f.write_u64(dev.fingerprint());
    f.write_bool(opts.unguided);
    f.write_usize(nl.nodes.len());
    f.finish()
}

/// Hash the partitioning problem (units, pins, node sets, edges,
/// die weight) — shared by [`floorplan_key`] and [`ilp_key`].
fn hash_problem(f: &mut Fnv, problem: &Problem) {
    f.write_f64(problem.die_weight);
    f.write_usize(problem.units.len());
    for u in &problem.units {
        f.write_f64(u.resources.lut)
            .write_f64(u.resources.ff)
            .write_f64(u.resources.bram)
            .write_f64(u.resources.dsp)
            .write_f64(u.resources.uram);
        match u.fixed_slot {
            Some(s) => {
                f.write_bool(true);
                f.write_usize(s);
            }
            None => {
                f.write_bool(false);
            }
        }
        f.write_usize(u.nodes.len());
        for &n in &u.nodes {
            f.write_usize(n);
        }
    }
    f.write_usize(problem.edges.len());
    for e in &problem.edges {
        f.write_usize(e.a).write_usize(e.b).write_u64(e.width);
    }
}

/// Fingerprint of one stage-3 floorplanning instance: the partitioning
/// problem (units, pins, edges), the device, and every knob the block
/// reads (`util_limit`, ILP config, SA refinement + full SA config,
/// evaluator selection).
///
/// Deliberately *excludes*
/// [`PipelineStrategy`](crate::coordinator::flow::PipelineStrategy):
/// stage 3 never reads it (relay-station strategy is a stage-4 knob), so
/// DSE points differing only in pipelining strategy share one floorplan
/// entry.
pub fn floorplan_key(problem: &Problem, dev: &VirtualDevice, cfg: &super::flow::FlowConfig) -> u64 {
    let mut f = Fnv::new();
    f.write_u64(dev.fingerprint());
    hash_problem(&mut f, problem);
    f.write_f64(cfg.util_limit);
    f.write_f64(cfg.ilp.util_limit)
        .write_usize(cfg.ilp.max_nodes)
        .write_usize(cfg.ilp.max_units)
        .write_f64(cfg.ilp.sll_budget_frac);
    f.write_bool(cfg.sa_refine);
    f.write_u64(cfg.sa.seed)
        .write_usize(cfg.sa.population)
        .write_usize(cfg.sa.proposals)
        .write_usize(cfg.sa.steps)
        .write_f64(cfg.sa.t0)
        .write_f64(cfg.sa.cooling)
        .write_usize(cfg.sa.workers);
    f.write_bool(cfg.use_pjrt);
    f.finish()
}

/// Fingerprint of one ILP solve: the problem, the device, and exactly
/// the [`IlpFpConfig`](crate::floorplan::IlpFpConfig) knobs
/// [`crate::floorplan::autobridge::solve`] reads. No SA knob enters, so
/// sweep points that differ only in SA budget / seed / population key to
/// the same ILP result (the ILP never sees SA). A salt separates this
/// key space from [`floorplan_key`]'s.
pub fn ilp_key(
    problem: &Problem,
    dev: &VirtualDevice,
    ilp: &crate::floorplan::IlpFpConfig,
) -> u64 {
    let mut f = Fnv::new();
    f.write_str("ilp");
    f.write_u64(dev.fingerprint());
    hash_problem(&mut f, problem);
    f.write_f64(ilp.util_limit)
        .write_usize(ilp.max_nodes)
        .write_usize(ilp.max_units)
        .write_f64(ilp.sll_budget_frac);
    f.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::ir::core::Resources;
    use crate::timing::netlist::{FlatEdge, FlatNode};

    fn netlist(n: usize) -> FlatNetlist {
        FlatNetlist {
            nodes: (0..n)
                .map(|i| FlatNode {
                    path: format!("n{i}"),
                    module: "M".into(),
                    resources: Resources::new(1000.0, 1000.0, 0.0, 0.0, 0.0),
                    internal_ns: 2.0,
                    is_pipeline: false,
                    fixed_slot: None,
                })
                .collect(),
            edges: (0..n.saturating_sub(1))
                .map(|i| FlatEdge {
                    src: i,
                    dst: i + 1,
                    width: 64,
                    pipelinable: true,
                })
                .collect(),
        }
    }

    #[test]
    fn placement_cache_ignores_internal_ns() {
        let dev = builtin::by_name("u250").unwrap();
        let memo = StageMemo::new(8);
        let nl = netlist(6);
        let p1 = memo.place(&nl, &dev, &PlacerConfig::default()).unwrap();
        let mut edited = nl.clone();
        for node in &mut edited.nodes {
            node.internal_ns = 3.7;
        }
        let p2 = memo.place(&edited, &dev, &PlacerConfig::default()).unwrap();
        assert_eq!(p1, p2);
        let stats = memo.stats();
        let placements = stats.iter().find(|(k, _)| *k == "placements").unwrap().1;
        assert_eq!((placements.hits, placements.misses), (1, 1), "{placements:?}");
    }

    #[test]
    fn placement_key_sees_resource_edits() {
        let dev = builtin::by_name("u250").unwrap();
        let nl = netlist(6);
        let base = place_key(&nl, &dev, &PlacerConfig::default());
        let mut edited = nl.clone();
        edited.nodes[2].resources.lut += 1.0;
        assert_ne!(base, place_key(&edited, &dev, &PlacerConfig::default()));
        let mut pinned = nl.clone();
        pinned.nodes[0].fixed_slot = Some("SLOT_X0Y0".into());
        assert_ne!(base, place_key(&pinned, &dev, &PlacerConfig::default()));
    }

    #[test]
    fn memoized_implement_matches_plain_backend() {
        let dev = builtin::by_name("u250").unwrap();
        let memo = StageMemo::new(8);
        let nl = netlist(6);
        let plain = vivado::implement_netlist(
            &nl,
            &dev,
            &PlacerConfig::default(),
            &DelayModel::default(),
        )
        .unwrap();
        for _ in 0..2 {
            let memoized = memo
                .implement(
                    &nl,
                    &dev,
                    &PlacerConfig::default(),
                    &DelayModel::default(),
                    StaOptions::default(),
                    "test",
                )
                .unwrap();
            assert_eq!(format!("{plain:?}"), format!("{memoized:?}"));
        }
        let stats = memo.stats();
        let sta = stats.iter().find(|(k, _)| *k == "sta_delta").unwrap().1;
        assert_eq!((sta.hits, sta.misses), (1, 1), "{sta:?}");
    }

    #[test]
    fn floorplan_block_memoizes_by_key() {
        let memo = StageMemo::new(8);
        let entry = FloorplanEntry {
            unit_slots: vec![0, 1, 2],
            evaluator_used: "ilp-only",
            log: vec!["hello".into()],
        };
        let mut computed = 0;
        for _ in 0..3 {
            let got = memo
                .floorplan(42, || {
                    computed += 1;
                    Ok(entry.clone())
                })
                .unwrap();
            assert_eq!(got.unit_slots, entry.unit_slots);
            assert_eq!(got.log, entry.log);
        }
        assert_eq!(computed, 1);
    }

    #[test]
    fn ilp_solves_memoize_by_key_and_skip_errors() {
        let memo = StageMemo::new(8);
        let res = crate::floorplan::FloorplanResult {
            unit_slots: vec![0, 1],
            wirelength: 3.0,
            optimal: true,
        };
        let mut computed = 0;
        for _ in 0..3 {
            let got = memo
                .ilp(7, || {
                    computed += 1;
                    Ok(res.clone())
                })
                .unwrap();
            assert_eq!(got.unit_slots, res.unit_slots);
        }
        assert_eq!(computed, 1);
        let mut attempts = 0;
        for _ in 0..2 {
            let e = memo.ilp(8, || {
                attempts += 1;
                Err(anyhow::anyhow!("infeasible attempt"))
            });
            assert!(e.is_err());
        }
        assert_eq!(attempts, 2, "errors must never be cached");
    }

    #[test]
    fn ilp_key_ignores_sa_knobs_floorplan_key_does_not() {
        let dev = builtin::by_name("u250").unwrap();
        let problem = crate::floorplan::Problem {
            units: vec![crate::floorplan::Unit {
                nodes: vec![0],
                resources: Resources::new(1000.0, 1000.0, 0.0, 0.0, 0.0),
                fixed_slot: None,
                name: "u0".into(),
            }],
            edges: vec![],
            die_weight: 3.0,
        };
        let mut a = crate::coordinator::flow::FlowConfig::default();
        let mut b = a.clone();
        b.sa.steps = a.sa.steps + 1;
        let mut ia = a.ilp.clone();
        ia.util_limit = a.util_limit;
        let mut ib = b.ilp.clone();
        ib.util_limit = b.util_limit;
        assert_eq!(ilp_key(&problem, &dev, &ia), ilp_key(&problem, &dev, &ib));
        assert_ne!(
            floorplan_key(&problem, &dev, &a),
            floorplan_key(&problem, &dev, &b)
        );
        // A util_limit change must miss both caches.
        b = a.clone();
        b.util_limit = 0.61;
        ib = b.ilp.clone();
        ib.util_limit = b.util_limit;
        a.ilp.util_limit = a.util_limit;
        assert_ne!(ilp_key(&problem, &dev, &a.ilp), ilp_key(&problem, &dev, &ib));
        assert_ne!(
            floorplan_key(&problem, &dev, &a),
            floorplan_key(&problem, &dev, &b)
        );
    }

    #[test]
    fn disabled_memo_still_produces_identical_results() {
        let dev = builtin::by_name("u250").unwrap();
        let memo = StageMemo::disabled();
        let nl = netlist(5);
        let plain = vivado::implement_netlist(
            &nl,
            &dev,
            &PlacerConfig::default(),
            &DelayModel::default(),
        )
        .unwrap();
        let memoized = memo
            .implement(
                &nl,
                &dev,
                &PlacerConfig::default(),
                &DelayModel::default(),
                StaOptions::default(),
                "test",
            )
            .unwrap();
        assert_eq!(format!("{plain:?}"), format!("{memoized:?}"));
        let stats = memo.stats();
        let placements = stats.iter().find(|(k, _)| *k == "placements").unwrap().1;
        assert_eq!(placements.hits, 0);
    }
}

//! Floorplan exploration (§4.2 / Figure 12): sweep the per-slot
//! utilization ceiling and report the trade-off between local congestion
//! (most-congested-slot utilization), global wirelength, and achieved
//! frequency. "This automation is implemented as a standalone RIR
//! plugin … that can be reused across different designs."

use crate::coordinator::flow::{run_hlps_warm, AnalyzedDesign, FlowConfig, FlowWarm};
use crate::device::model::VirtualDevice;
use crate::ir::core::Design;
use crate::util::pool::Pool;
use anyhow::Result;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct ExploreRow {
    pub util_limit: f64,
    /// Utilization of the most congested slot after placement.
    pub max_slot_util: f64,
    /// Total weighted wirelength of the floorplan.
    pub wirelength: f64,
    pub fmax_mhz: f64,
    pub routable: bool,
}

impl ExploreRow {
    /// Canonical bitwise equality: every float field compared under the
    /// SA total order ([`cmp_cost_f64`](crate::floorplan::cmp_cost_f64)),
    /// so `NaN == NaN` and `-0.0 != 0.0` — two rows are equal exactly
    /// when they would render identically in a deterministic report.
    /// This is the one equality the warm-vs-cold tests, the daemon lane,
    /// and the DSE dedup all share.
    pub fn bits_eq(&self, other: &Self) -> bool {
        use crate::floorplan::cmp_cost_f64;
        use std::cmp::Ordering::Equal;
        cmp_cost_f64(self.util_limit, other.util_limit) == Equal
            && cmp_cost_f64(self.max_slot_util, other.max_slot_util) == Equal
            && cmp_cost_f64(self.wirelength, other.wirelength) == Equal
            && cmp_cost_f64(self.fmax_mhz, other.fmax_mhz) == Equal
            && self.routable == other.routable
    }
}

/// Classify a flow error for a sweep point: a typed
/// [`Infeasible`](crate::floorplan::Infeasible) (the design does not fit
/// at this limit) is itself a data point — an explicit unroutable row —
/// while anything else (poisoned lock, bad input, logic bug) propagates
/// as `Err` so the sweep fails loudly instead of dressing an internal
/// error up as congestion.
pub fn row_for_error(limit: f64, e: anyhow::Error) -> Result<ExploreRow> {
    if e.downcast_ref::<crate::floorplan::Infeasible>().is_some() {
        Ok(ExploreRow {
            util_limit: limit,
            max_slot_util: f64::NAN,
            wirelength: f64::NAN,
            fmax_mhz: 0.0,
            routable: false,
        })
    } else {
        Err(e)
    }
}

/// Run the HLPS flow once per utilization limit — one pool job per sweep
/// point, each on a fresh clone of the design — and collect the Pareto
/// trade-off rows of Figure 12 in sweep order.
///
/// `base_cfg` is cloned per point with only `util_limit` overridden, so
/// the SA knobs (`base_cfg.sa`, including the `workers` parallel-chains
/// width) apply to every point's refinement identically. Note the two
/// parallelism levels compose: `pool` fans out sweep points while
/// `base_cfg.sa.workers` fans out chains *within* each point — both are
/// pure wall-clock knobs that never change any row.
pub fn explore(
    design: &Design,
    dev: &VirtualDevice,
    limits: &[f64],
    base_cfg: &FlowConfig,
    pool: &Pool,
) -> Result<Vec<ExploreRow>> {
    explore_warm(design, dev, limits, base_cfg, pool, None)
}

/// [`explore`] with an optional pre-analyzed snapshot of `design`. Every
/// sweep point runs the same stage-1–2 result regardless of its
/// `util_limit` (analysis is utilization-independent), so a daemon hands
/// its cached [`AnalyzedDesign`] to the whole sweep — a per-point
/// wall-time win that, per the flow's warm-state contract, never changes
/// a row.
pub fn explore_warm(
    design: &Design,
    dev: &VirtualDevice,
    limits: &[f64],
    base_cfg: &FlowConfig,
    pool: &Pool,
    analyzed: Option<Arc<AnalyzedDesign>>,
) -> Result<Vec<ExploreRow>> {
    explore_warm_staged(design, dev, limits, base_cfg, pool, analyzed, None)
}

/// [`explore_warm`] with an optional shared
/// [`StageMemo`](crate::coordinator::memo::StageMemo): every sweep
/// point runs through the same per-stage caches, so work independent of
/// `util_limit` (elaboration fragments, the baseline placement, module
/// characterization) is done once for the whole sweep instead of once
/// per point. Per the memo's determinism contract this never changes a
/// row — the memo is safe to share across the pool's worker threads.
#[allow(clippy::too_many_arguments)]
pub fn explore_warm_staged(
    design: &Design,
    dev: &VirtualDevice,
    limits: &[f64],
    base_cfg: &FlowConfig,
    pool: &Pool,
    analyzed: Option<Arc<AnalyzedDesign>>,
    stage: Option<Arc<crate::coordinator::memo::StageMemo>>,
) -> Result<Vec<ExploreRow>> {
    let rows = pool.par_map(limits.to_vec(), |limit| {
        let mut d = design.clone();
        let mut cfg = base_cfg.clone();
        cfg.util_limit = limit;
        let mut warm = FlowWarm {
            analyzed: analyzed.clone(),
            stage: stage.clone(),
            ..Default::default()
        };
        // The sweep wants the exact limit, not the auto-relaxed one; an
        // infeasible point is itself a data point, recorded as an
        // unroutable row — but only a typed infeasibility. Internal
        // errors propagate (see `row_for_error`).
        match run_hlps_warm(&mut d, dev, &cfg, &mut warm) {
            Ok(report) => Ok(ExploreRow {
                util_limit: limit,
                max_slot_util: report.optimized.timing.max_util,
                wirelength: report.floorplan_wirelength,
                fmax_mhz: report.optimized.fmax_mhz(),
                routable: report.optimized.routable(),
            }),
            Err(e) => row_for_error(limit, e),
        }
    });
    rows.into_iter().collect()
}

/// The default sweep of ten limits used by the Fig 12 bench.
pub fn default_limits() -> Vec<f64> {
    (0..10).map(|i| 0.50 + 0.04 * i as f64).collect()
}

/// Expected trade-off shape: tighter limits spread the design (lower
/// congestion, more wirelength); looser limits pack it. Returns Pearson
/// correlation between util_limit and wirelength over routable rows, or
/// `None` when the correlation is undefined — fewer than two routable
/// points, or zero variance on either axis. (It used to return `0.0` in
/// those cases, which read as "measured, no correlation" and let a fully
/// infeasible sweep sail through a `corr < 0.0`-style check's inverse.)
pub fn tradeoff_correlation(rows: &[ExploreRow]) -> Option<f64> {
    let pts: Vec<(f64, f64)> = rows
        .iter()
        .filter(|r| r.routable && r.wirelength.is_finite())
        .map(|r| (r.util_limit, r.wirelength))
        .collect();
    if pts.len() < 2 {
        return None;
    }
    let n = pts.len() as f64;
    let (mx, my) = (
        pts.iter().map(|p| p.0).sum::<f64>() / n,
        pts.iter().map(|p| p.1).sum::<f64>() / n,
    );
    let cov: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let (sx, sy) = (
        pts.iter().map(|p| (p.0 - mx).powi(2)).sum::<f64>().sqrt(),
        pts.iter().map(|p| (p.1 - my).powi(2)).sum::<f64>().sqrt(),
    );
    if sx == 0.0 || sy == 0.0 {
        None
    } else {
        Some(cov / (sx * sy))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::builtin;
    use crate::designs::cnn::{self, CnnConfig};

    #[test]
    fn sweep_produces_tradeoff() {
        let dev = builtin::by_name("u250").unwrap();
        let g = cnn::generate(&CnnConfig { rows: 4, cols: 3 }).unwrap();
        let cfg = FlowConfig {
            sa_refine: false,
            ..Default::default()
        };
        let pool = Pool::new(2);
        let rows = explore(&g.design, &dev, &[0.25, 0.55, 0.85], &cfg, &pool).unwrap();
        assert_eq!(rows.len(), 3);
        let routable: Vec<_> = rows.iter().filter(|r| r.routable).collect();
        assert!(routable.len() >= 2, "{rows:?}");
        // Packing tighter (higher limit) must not increase wirelength.
        let wl: Vec<f64> = routable.iter().map(|r| r.wirelength).collect();
        assert!(
            wl.windows(2).all(|w| w[1] <= w[0] + 1e-6),
            "wirelength not monotone: {wl:?}"
        );
    }

    #[test]
    fn warm_sweep_matches_cold() {
        let dev = builtin::by_name("u250").unwrap();
        let g = cnn::generate(&CnnConfig { rows: 4, cols: 3 }).unwrap();
        let cfg = FlowConfig {
            sa_refine: false,
            ..Default::default()
        };
        let pool = Pool::new(1);
        let limits = [0.55, 0.85];
        let cold = explore(&g.design, &dev, &limits, &cfg, &pool).unwrap();
        let snap = Arc::new(crate::coordinator::flow::analyze_design(&g.design).unwrap());
        let warm = explore_warm(&g.design, &dev, &limits, &cfg, &pool, Some(snap)).unwrap();
        for (a, b) in cold.iter().zip(&warm) {
            assert!(a.bits_eq(b), "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn staged_sweep_matches_cold() {
        let dev = builtin::by_name("u250").unwrap();
        let g = cnn::generate(&CnnConfig { rows: 4, cols: 3 }).unwrap();
        let cfg = FlowConfig {
            sa_refine: false,
            ..Default::default()
        };
        let pool = Pool::new(2);
        let limits = [0.55, 0.85];
        let cold = explore(&g.design, &dev, &limits, &cfg, &pool).unwrap();
        let memo = Arc::new(crate::coordinator::memo::StageMemo::new(32));
        let staged = explore_warm_staged(
            &g.design,
            &dev,
            &limits,
            &cfg,
            &pool,
            None,
            Some(memo.clone()),
        )
        .unwrap();
        for (a, b) in cold.iter().zip(&staged) {
            assert!(a.bits_eq(b), "{a:?} vs {b:?}");
        }
        // The sweep points share elaboration work through the memo: both
        // points elaborate the same analyzed design and the same final
        // netlist comes up again within each flow.
        let stats = memo.stats();
        let netlists = stats.iter().find(|(k, _)| *k == "flat_netlists").unwrap().1;
        assert!(netlists.hits >= 1, "{stats:?}");
    }

    #[test]
    fn default_limits_shape() {
        let l = default_limits();
        assert_eq!(l.len(), 10);
        assert!(l[0] >= 0.45 && *l.last().unwrap() <= 0.90);
    }

    #[test]
    fn row_for_error_classifies_infeasible_vs_internal() {
        // A typed infeasibility — even buried under context frames, as
        // the flow wraps it — becomes an explicit unroutable row.
        let inf = anyhow::Error::new(crate::floorplan::Infeasible::new(
            "placement failed: design does not fit",
        ))
        .context("floorplan ILP");
        let row = row_for_error(0.6, inf).unwrap();
        assert_eq!(row.util_limit, 0.6);
        assert!(!row.routable);
        assert!(row.max_slot_util.is_nan() && row.wirelength.is_nan());
        assert_eq!(row.fmax_mhz, 0.0);

        // Anything else is an internal error and must propagate.
        let internal = anyhow::anyhow!("lock poisoned");
        let err = row_for_error(0.6, internal).unwrap_err();
        assert!(format!("{err}").contains("lock poisoned"));
    }

    #[test]
    fn sweep_records_infeasible_point_as_unroutable_row() {
        // A design whose total resources exceed the device even at the
        // ILP's 0.90 relaxation ceiling: the flow surfaces a typed
        // Infeasible, which the sweep records as an explicit unroutable
        // row instead of erroring.
        let dev = builtin::by_name("u250").unwrap();
        let design = crate::testing::oversized_chain(&dev, 12, 0.8);
        let cfg = FlowConfig {
            sa_refine: false,
            ..Default::default()
        };
        let pool = Pool::new(2);
        let rows = explore(&design, &dev, &[0.5], &cfg, &pool).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(!rows[0].routable, "{rows:?}");
        assert!(rows[0].wirelength.is_nan(), "{rows:?}");
    }

    #[test]
    fn correlation_is_none_for_degenerate_sweeps() {
        let row = |util_limit: f64, wirelength: f64, routable: bool| ExploreRow {
            util_limit,
            max_slot_util: 0.5,
            wirelength,
            fmax_mhz: 300.0,
            routable,
        };
        // Empty, single-point, and all-unroutable sweeps: undefined.
        assert_eq!(tradeoff_correlation(&[]), None);
        assert_eq!(tradeoff_correlation(&[row(0.5, 10.0, true)]), None);
        assert_eq!(
            tradeoff_correlation(&[row(0.5, f64::NAN, false), row(0.6, f64::NAN, false)]),
            None
        );
        // Zero variance on either axis: undefined, not 0.0.
        assert_eq!(
            tradeoff_correlation(&[row(0.5, 10.0, true), row(0.5, 20.0, true)]),
            None
        );
        assert_eq!(
            tradeoff_correlation(&[row(0.5, 10.0, true), row(0.6, 10.0, true)]),
            None
        );
        // A real anti-correlated sweep still reports a value.
        let c = tradeoff_correlation(&[row(0.5, 20.0, true), row(0.6, 10.0, true)]).unwrap();
        assert!(c < 0.0, "{c}");
    }

    #[test]
    fn bits_eq_treats_nan_as_equal_and_zero_signs_as_distinct() {
        let row = |wirelength: f64| ExploreRow {
            util_limit: 0.5,
            max_slot_util: f64::NAN,
            wirelength,
            fmax_mhz: 0.0,
            routable: false,
        };
        assert!(row(f64::NAN).bits_eq(&row(f64::NAN)));
        assert!(!row(0.0).bits_eq(&row(-0.0)));
        assert!(!row(1.0).bits_eq(&row(2.0)));
    }
}

//! Multi-dimensional design-space exploration (`rsir dse`).
//!
//! Where [`explore`](crate::coordinator::explore) sweeps the single
//! Figure-12 axis (the per-slot utilization ceiling), this module sweeps
//! the full knob space the paper's infrastructure exposes:
//!
//! * **utilization limit** — the Figure-12 congestion/wirelength axis;
//! * **slot grid** — pblock granularity, via
//!   [`VirtualDevice::coarsen_columns`] (factor 1 = the device as-is);
//! * **pipelining strategy** — stage-4 relay-station policy
//!   ([`PipelineStrategy`]);
//! * **SA budget** — annealing steps spent refining each floorplan.
//!
//! Points stream through the shared work-stealing pool and one shared
//! [`StageMemo`], so work independent of a knob (elaboration, the
//! baseline placement, the SA-free ILP solve) is done once per sweep.
//!
//! **Warm-started SA.** Within one *group* — a (util, grid, strategy)
//! coordinate — points differ only in SA budget, and per
//! [`sa::anneal_resumable`]'s prefix property a shorter anneal is a
//! bit-exact prefix of a longer one. Each group's points therefore run
//! serially, budget ascending, each resuming from the nearest completed
//! point's checkpoint (the largest budget ≤ its own within the group;
//! cold fallback when none exists — the nearest-neighbor rule restricted
//! to the one axis along which resumption is sound). Across groups the
//! problem, device, or cost model differs, so checkpoints don't
//! transfer; groups fan out in parallel instead. Warm-starting is
//! therefore a pure wall-time win: every row is byte-identical to its
//! cold-start twin, at any `--workers` / `--sa-workers` count (the
//! groups are reassembled in canonical enumeration order).
//!
//! **Pareto front.** Routable rows are ranked on four objectives — max
//! frequency, min wirelength, min peak slot utilization, min SA budget
//! (the deterministic proxy for refinement wall time; measured wall
//! time is nondeterministic and never enters the front) — under the SA
//! NaN-total order ([`cmp_cost_f64`]). Dominated points are pruned
//! incrementally ([`ParetoFilter`]); a brute-force reference
//! ([`pareto_front`]) backs the property tests.

use crate::coordinator::explore::{row_for_error, ExploreRow};
use crate::coordinator::flow::{
    analyze_design, run_hlps_warm, FlowConfig, FlowWarm, PipelineStrategy,
};
use crate::coordinator::memo::StageMemo;
use crate::device::model::VirtualDevice;
use crate::floorplan::cmp_cost_f64;
use crate::floorplan::cost::CostModel;
use crate::floorplan::sa;
use crate::ir::core::Design;
use crate::util::bench::Table;
use crate::util::json::{Json, JsonObj};
use crate::util::pool::Pool;
use anyhow::{Context, Result};
use std::cmp::Ordering;
use std::sync::Arc;

/// The knob space of one DSE run. Empty axes default to the base flow
/// config's value for that knob, so the all-empty config is the
/// single-point sweep of `base` itself.
#[derive(Debug, Clone)]
pub struct DseConfig {
    /// Per-slot utilization ceilings (Figure-12 axis).
    pub utils: Vec<f64>,
    /// Column-coarsening factors for the slot grid (1 = native).
    pub grids: Vec<usize>,
    /// SA refinement budgets (steps); sorted ascending per group so each
    /// point can resume the previous one's checkpoint.
    pub sa_steps: Vec<usize>,
    /// Stage-4 pipelining strategies.
    pub strategies: Vec<PipelineStrategy>,
    /// Flow settings shared by every point (each point overrides
    /// `util_limit`, `pipeline`, and `sa.steps`).
    pub base: FlowConfig,
    /// Resume each point's SA from its group predecessor's checkpoint.
    /// Pure wall-time knob: rows are byte-identical either way.
    pub warm_sa: bool,
}

impl Default for DseConfig {
    fn default() -> Self {
        DseConfig {
            utils: vec![0.60, 0.70, 0.80],
            grids: vec![1, 2],
            sa_steps: vec![60, 120],
            strategies: vec![PipelineStrategy::Full, PipelineStrategy::DiesOnly],
            base: FlowConfig::default(),
            warm_sa: true,
        }
    }
}

/// One coordinate in the knob space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DsePoint {
    pub util_limit: f64,
    /// Column-coarsening factor (1 = the device's native grid).
    pub grid: usize,
    pub strategy: PipelineStrategy,
    pub sa_steps: usize,
}

/// One evaluated point: its knobs plus the flow's quality metrics.
/// Infeasible points (typed [`Infeasible`](crate::floorplan::Infeasible))
/// appear as explicit unroutable rows with NaN metrics; internal errors
/// never become rows — [`run_dse`] propagates them.
#[derive(Debug, Clone)]
pub struct DseRow {
    pub point: DsePoint,
    /// Utilization of the most congested slot after placement.
    pub max_slot_util: f64,
    /// Total weighted wirelength of the floorplan.
    pub wirelength: f64,
    pub fmax_mhz: f64,
    pub routable: bool,
}

impl DseRow {
    /// The row's Figure-12 projection — what [`bits_eq`](Self::bits_eq)
    /// delegates its float comparisons to.
    pub fn to_explore_row(&self) -> ExploreRow {
        ExploreRow {
            util_limit: self.point.util_limit,
            max_slot_util: self.max_slot_util,
            wirelength: self.wirelength,
            fmax_mhz: self.fmax_mhz,
            routable: self.routable,
        }
    }

    /// Canonical bitwise equality: knobs exactly, floats per
    /// [`ExploreRow::bits_eq`] (the SA NaN-total order). This is the
    /// dedup/identity predicate the DSE tests and report share.
    pub fn bits_eq(&self, other: &Self) -> bool {
        self.point.grid == other.point.grid
            && self.point.strategy == other.point.strategy
            && self.point.sa_steps == other.point.sa_steps
            && self.to_explore_row().bits_eq(&other.to_explore_row())
    }
}

/// Everything one DSE run produced: all rows in canonical enumeration
/// order, and the non-dominated front in the same order. Deterministic —
/// byte-identical for a given (design, device, config) at any worker
/// count — which is why no wall-clock figures live here.
#[derive(Debug, Clone)]
pub struct DseReport {
    /// Every evaluated point, canonical order (util, grid, strategy,
    /// then SA budget ascending).
    pub rows: Vec<DseRow>,
    /// The Pareto-optimal subset of the routable rows, canonical order.
    pub front: Vec<DseRow>,
}

/// `true` when `a` is at least as good as `b` on every objective and
/// strictly better on at least one — all float comparisons under
/// [`cmp_cost_f64`], so a NaN metric can never dominate anything.
pub fn dominates(a: &DseRow, b: &DseRow) -> bool {
    // Better-or-equal per objective: fmax maximized, the rest minimized.
    let cmps = [
        cmp_cost_f64(b.fmax_mhz, a.fmax_mhz),
        cmp_cost_f64(a.wirelength, b.wirelength),
        cmp_cost_f64(a.max_slot_util, b.max_slot_util),
        a.point.sa_steps.cmp(&b.point.sa_steps),
    ];
    cmps.iter().all(|c| *c != Ordering::Greater) && cmps.iter().any(|c| *c == Ordering::Less)
}

fn objectives_eq(a: &DseRow, b: &DseRow) -> bool {
    cmp_cost_f64(a.fmax_mhz, b.fmax_mhz) == Ordering::Equal
        && cmp_cost_f64(a.wirelength, b.wirelength) == Ordering::Equal
        && cmp_cost_f64(a.max_slot_util, b.max_slot_util) == Ordering::Equal
        && a.point.sa_steps == b.point.sa_steps
}

/// Canonical row order for reports: util, then grid, then strategy
/// (full < dies < off), then SA budget — the enumeration order of
/// [`run_dse`].
fn cmp_points(a: &DseRow, b: &DseRow) -> Ordering {
    let rank = |s: PipelineStrategy| match s {
        PipelineStrategy::Full => 0u8,
        PipelineStrategy::DiesOnly => 1,
        PipelineStrategy::Off => 2,
    };
    cmp_cost_f64(a.point.util_limit, b.point.util_limit)
        .then(a.point.grid.cmp(&b.point.grid))
        .then(rank(a.point.strategy).cmp(&rank(b.point.strategy)))
        .then(a.point.sa_steps.cmp(&b.point.sa_steps))
}

/// Incremental Pareto filter: feed rows as they complete; dominated rows
/// (and unroutable rows, and objective-duplicates of a present row) are
/// dropped, and a new non-dominated row evicts whatever it dominates.
/// Feeding the same rows in any order yields the same
/// [`front`](Self::front) — equal-objective ties are broken by canonical
/// point order, not arrival order.
#[derive(Debug, Default)]
pub struct ParetoFilter {
    front: Vec<DseRow>,
}

impl ParetoFilter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Offer a row; returns `true` if it joined the front.
    pub fn insert(&mut self, row: DseRow) -> bool {
        if !row.routable {
            return false;
        }
        if let Some(twin) = self.front.iter_mut().find(|f| objectives_eq(f, &row)) {
            // Objective tie: keep whichever comes first canonically.
            if cmp_points(&row, twin) == Ordering::Less {
                *twin = row;
                return true;
            }
            return false;
        }
        if self.front.iter().any(|f| dominates(f, &row)) {
            return false;
        }
        self.front.retain(|f| !dominates(&row, f));
        self.front.push(row);
        true
    }

    /// The current non-dominated set in canonical point order.
    pub fn front(&self) -> Vec<DseRow> {
        let mut f = self.front.clone();
        f.sort_by(cmp_points);
        f
    }
}

/// Brute-force Pareto reference (O(n²)): a routable row survives iff no
/// other row dominates it and no canonically-earlier row ties it on
/// every objective. The property tests pin [`ParetoFilter`] to this.
pub fn pareto_front(rows: &[DseRow]) -> Vec<DseRow> {
    let mut sorted: Vec<&DseRow> = rows.iter().filter(|r| r.routable).collect();
    sorted.sort_by(|a, b| cmp_points(a, b));
    let mut front: Vec<DseRow> = Vec::new();
    for (i, r) in sorted.iter().enumerate() {
        let dominated = sorted.iter().any(|o| dominates(o, r));
        let tied_earlier = sorted[..i].iter().any(|o| objectives_eq(o, r));
        if !dominated && !tied_earlier {
            front.push((*r).clone());
        }
    }
    front
}

/// An axis with declared values, or the base config's singleton.
fn axis<T: Clone>(values: &[T], base: T) -> Vec<T> {
    if values.is_empty() {
        vec![base]
    } else {
        values.to_vec()
    }
}

/// Run the full multi-dimensional sweep. One shared stage-1–2 snapshot
/// (analysis is device-independent) and one shared [`StageMemo`] serve
/// every point; (util, grid, strategy) groups fan out on `pool` while
/// each group's budgets run serially, warm-starting SA along the way
/// (see the module docs). Rows come back in canonical enumeration order
/// with the Pareto front attached — byte-identical at any worker count.
///
/// Typed-infeasible points become explicit unroutable rows; any other
/// per-point failure aborts the sweep with that error.
pub fn run_dse(
    design: &Design,
    dev: &VirtualDevice,
    cfg: &DseConfig,
    pool: &Pool,
) -> Result<DseReport> {
    // Canonicalize each axis: sort, dedup (utils by bit pattern — the
    // report's float order is cmp_cost_f64), defaults from `base`.
    let mut utils = axis(&cfg.utils, cfg.base.util_limit);
    utils.sort_by(|a, b| cmp_cost_f64(*a, *b));
    utils.dedup_by(|a, b| a.to_bits() == b.to_bits());
    let mut grids = axis(&cfg.grids, 1);
    grids.sort_unstable();
    grids.dedup();
    let mut sa_steps = axis(&cfg.sa_steps, cfg.base.sa.steps);
    sa_steps.sort_unstable();
    sa_steps.dedup();
    let mut strategies: Vec<PipelineStrategy> = Vec::new();
    for s in axis(&cfg.strategies, cfg.base.pipeline) {
        if !strategies.contains(&s) {
            strategies.push(s);
        }
    }

    // Coarsened device per grid factor, validated up front.
    let devs: Vec<VirtualDevice> = grids
        .iter()
        .map(|&g| dev.coarsen_columns(g))
        .collect::<Result<_>>()
        .with_context(|| format!("dse grid axis on device '{}'", dev.name))?;

    // Shared warm state for the whole sweep.
    let snap = Arc::new(analyze_design(design).context("dse analysis")?);
    let points = utils.len() * grids.len() * strategies.len() * sa_steps.len();
    let memo = Arc::new(StageMemo::new((2 * points).max(64)));

    // Canonical group enumeration; `par_map` preserves input order, so
    // the reassembled rows are order-identical at any worker count.
    let mut groups: Vec<(f64, usize, PipelineStrategy)> = Vec::new();
    for &u in &utils {
        for gi in 0..grids.len() {
            for &s in &strategies {
                groups.push((u, gi, s));
            }
        }
    }
    let results = pool.par_map(groups, |(util, gi, strategy)| -> Result<Vec<DseRow>> {
        let gdev = &devs[gi];
        let mut rows = Vec::with_capacity(sa_steps.len());
        // Carried across the group's budget-ascending chain: the SA
        // checkpoint (the prefix-resume warm start) and the cost model
        // (a pure function of (problem, device, util, die_weight), all
        // fixed within the group).
        let mut ck: Option<Arc<sa::SaCheckpoint>> = None;
        let mut cm: Option<Arc<CostModel>> = None;
        for &steps in &sa_steps {
            let mut d = design.clone();
            let mut fc = cfg.base.clone();
            fc.util_limit = util;
            fc.pipeline = strategy;
            fc.sa.steps = steps;
            let mut warm = FlowWarm {
                analyzed: Some(snap.clone()),
                stage: Some(memo.clone()),
                cost_model: cm.clone(),
                sa_resume: if cfg.warm_sa { ck.clone() } else { None },
                ..Default::default()
            };
            let point = DsePoint {
                util_limit: util,
                grid: grids[gi],
                strategy,
                sa_steps: steps,
            };
            let row = match run_hlps_warm(&mut d, gdev, &fc, &mut warm) {
                Ok(report) => DseRow {
                    point,
                    max_slot_util: report.optimized.timing.max_util,
                    wirelength: report.floorplan_wirelength,
                    fmax_mhz: report.optimized.fmax_mhz(),
                    routable: report.optimized.routable(),
                },
                Err(e) => {
                    let er = row_for_error(util, e)?;
                    DseRow {
                        point,
                        max_slot_util: er.max_slot_util,
                        wirelength: er.wirelength,
                        fmax_mhz: er.fmax_mhz,
                        routable: er.routable,
                    }
                }
            };
            if let Some(h) = warm.harvest_sa.take() {
                ck = Some(h);
            }
            if let Some(h) = warm.harvest_cost.take() {
                cm = Some(h);
            }
            rows.push(row);
        }
        Ok(rows)
    });

    let mut rows: Vec<DseRow> = Vec::with_capacity(points);
    for group_rows in results {
        rows.extend(group_rows?);
    }
    // Defensive dedup under the canonical predicate (axes are already
    // unique, so this is a no-op unless a caller builds degenerate rows).
    let mut unique: Vec<DseRow> = Vec::with_capacity(rows.len());
    for r in rows {
        if !unique.iter().any(|u| u.bits_eq(&r)) {
            unique.push(r);
        }
    }
    let mut filter = ParetoFilter::new();
    for r in &unique {
        filter.insert(r.clone());
    }
    Ok(DseReport {
        rows: unique,
        front: filter.front(),
    })
}

fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

fn row_json(r: &DseRow) -> Json {
    let mut o = JsonObj::new();
    o.insert("util_limit", Json::num(r.point.util_limit));
    o.insert("grid", Json::num(r.point.grid as f64));
    o.insert("strategy", Json::str(r.point.strategy.as_str()));
    o.insert("sa_steps", Json::num(r.point.sa_steps as f64));
    o.insert("max_slot_util", num_or_null(r.max_slot_util));
    o.insert("wirelength", num_or_null(r.wirelength));
    o.insert("fmax_mhz", num_or_null(r.fmax_mhz));
    o.insert("routable", Json::Bool(r.routable));
    Json::Obj(o)
}

impl DseReport {
    /// The report as JSON — the `rsir dse --out` artifact. Deterministic
    /// by construction: knobs and metrics only, no wall-clock figures.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("points", Json::num(self.rows.len() as f64));
        o.insert(
            "routable",
            Json::num(self.rows.iter().filter(|r| r.routable).count() as f64),
        );
        o.insert("rows", Json::Arr(self.rows.iter().map(row_json).collect()));
        o.insert("front", Json::Arr(self.front.iter().map(row_json).collect()));
        Json::Obj(o)
    }

    /// Human-readable Pareto-front table (the CLI's stdout artifact).
    pub fn render_front(&self) -> String {
        let mut t = Table::new(&[
            "util",
            "grid",
            "strategy",
            "sa_steps",
            "Fmax (MHz)",
            "wirelength",
            "max_slot_util",
        ]);
        for r in &self.front {
            t.row(&[
                format!("{:.2}", r.point.util_limit),
                format!("{}", r.point.grid),
                r.point.strategy.as_str().to_string(),
                format!("{}", r.point.sa_steps),
                format!("{:.0}", r.fmax_mhz),
                format!("{:.0}", r.wirelength),
                format!("{:.2}", r.max_slot_util),
            ]);
        }
        format!(
            "pareto front: {} of {} routable points ({} evaluated)\n{}",
            self.front.len(),
            self.rows.iter().filter(|r| r.routable).count(),
            self.rows.len(),
            t.to_string()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn row(util: f64, steps: usize, fmax: f64, wl: f64, peak: f64, routable: bool) -> DseRow {
        DseRow {
            point: DsePoint {
                util_limit: util,
                grid: 1,
                strategy: PipelineStrategy::Full,
                sa_steps: steps,
            },
            max_slot_util: peak,
            wirelength: wl,
            fmax_mhz: fmax,
            routable,
        }
    }

    #[test]
    fn dominance_basics() {
        let a = row(0.6, 60, 300.0, 100.0, 0.5, true);
        let worse = row(0.7, 60, 290.0, 120.0, 0.6, true);
        let tied = row(0.7, 60, 300.0, 100.0, 0.5, true);
        let mixed = row(0.7, 60, 310.0, 120.0, 0.5, true);
        assert!(dominates(&a, &worse));
        assert!(!dominates(&worse, &a));
        assert!(!dominates(&a, &tied) && !dominates(&tied, &a));
        assert!(!dominates(&a, &mixed) && !dominates(&mixed, &a));
        // A NaN metric can never dominate (NaN is the worst value in the
        // SA total order).
        let nan = row(0.7, 60, 310.0, f64::NAN, 0.4, true);
        assert!(!dominates(&nan, &a));
    }

    #[test]
    fn filter_prunes_dominated_and_evicts() {
        let mut f = ParetoFilter::new();
        assert!(f.insert(row(0.6, 60, 290.0, 120.0, 0.6, true)));
        // Strictly better on every axis: evicts the first row.
        assert!(f.insert(row(0.6, 40, 300.0, 100.0, 0.5, true)));
        assert_eq!(f.front().len(), 1);
        // Dominated: rejected.
        assert!(!f.insert(row(0.7, 80, 280.0, 130.0, 0.7, true)));
        // Unroutable: never enters.
        assert!(!f.insert(row(0.5, 40, f64::NAN, f64::NAN, f64::NAN, false)));
        // Incomparable trade-off joins the front.
        assert!(f.insert(row(0.7, 40, 320.0, 140.0, 0.8, true)));
        assert_eq!(f.front().len(), 2);
    }

    #[test]
    fn filter_breaks_objective_ties_canonically() {
        // Same objectives from two different knob points: the
        // canonically-earlier point wins regardless of arrival order.
        let early = row(0.5, 60, 300.0, 100.0, 0.5, true);
        let late = row(0.7, 60, 300.0, 100.0, 0.5, true);
        for arrival in [[&early, &late], [&late, &early]] {
            let mut f = ParetoFilter::new();
            for r in arrival {
                f.insert(r.clone());
            }
            let front = f.front();
            assert_eq!(front.len(), 1);
            assert!(front[0].bits_eq(&early));
        }
    }

    /// Property test: for random row sets, the incremental filter (fed
    /// in shuffled order) equals the brute-force reference, and no
    /// non-dominated row is ever dropped.
    #[test]
    fn filter_matches_brute_force_on_random_rows() {
        let mut rng = Rng::new(0xD5E);
        for case in 0..50u64 {
            let n = 1 + rng.below(24);
            let mut rows: Vec<DseRow> = (0..n)
                .map(|_| {
                    // Coarse value grids force plenty of ties and NaNs.
                    let fmax = [250.0, 275.0, 300.0, f64::NAN][rng.below(4)];
                    row(
                        0.5 + 0.1 * rng.below(4) as f64,
                        [40, 80, 120][rng.below(3)],
                        fmax,
                        (10 * (1 + rng.below(5))) as f64,
                        0.4 + 0.1 * rng.below(4) as f64,
                        rng.chance(0.8),
                    )
                })
                .collect();
            let reference = pareto_front(&rows);
            rng.shuffle(&mut rows);
            let mut f = ParetoFilter::new();
            for r in &rows {
                f.insert(r.clone());
            }
            let got = f.front();
            assert_eq!(got.len(), reference.len(), "case {case}: {rows:?}");
            for (a, b) in got.iter().zip(&reference) {
                assert!(a.bits_eq(b), "case {case}: {a:?} vs {b:?}");
            }
            // Completeness: every routable row is on the front or
            // dominated/tied by a front member.
            for r in rows.iter().filter(|r| r.routable) {
                assert!(
                    got.iter()
                        .any(|f| dominates(f, r) || objectives_eq(f, r) || f.bits_eq(r)),
                    "case {case}: dropped non-dominated {r:?}"
                );
            }
        }
    }

    #[test]
    fn empty_rows_have_empty_front() {
        assert!(pareto_front(&[]).is_empty());
        assert!(ParetoFilter::new().front().is_empty());
    }

    #[test]
    fn report_json_shape() {
        let report = DseReport {
            rows: vec![
                row(0.6, 60, 300.0, 100.0, 0.5, true),
                row(0.7, 60, 0.0, f64::NAN, f64::NAN, false),
            ],
            front: vec![row(0.6, 60, 300.0, 100.0, 0.5, true)],
        };
        let j = report.to_json();
        assert_eq!(j.at("points").and_then(|v| v.as_u64()), Some(2));
        assert_eq!(j.at("routable").and_then(|v| v.as_u64()), Some(1));
        let rows = j.at("rows").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows.len(), 2);
        // NaN renders as null, never as a bare NaN token.
        assert_eq!(rows[1].at("wirelength"), Some(&Json::Null));
        assert!(report.render_front().contains("pareto front: 1 of 1"));
    }
}

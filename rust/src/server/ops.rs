//! Job execution: the typed job requests the daemon queues, their
//! canonical (cache-key) form, and the dispatcher that runs them against
//! a [`CacheSet`].
//!
//! The same `execute` serves both lanes: the daemon calls it with warm
//! caches, the one-shot path (`rsir submit --local`, the differential
//! oracle's reference side) with [`CacheSet::disabled`]. Result payloads
//! are *canonical* — they carry no wall times or other nondeterminism —
//! so the two lanes are byte-identical by construction, and the memoized
//! `results` cache can replay them verbatim.

use crate::coordinator::explore;
use crate::coordinator::flow::{self, FlowCanceled, FlowConfig, FlowWarm};
use crate::coordinator::report::generate_by_id;
use crate::designs::synthetic::{self, SyntheticConfig};
use crate::device::builtin;
use crate::ir::core::Design;
use crate::ir::schema::{design_from_json, design_to_json};
use crate::passes::manager::{DrcOutcome, PassContext};
use crate::passes::registry;
use crate::server::cache::{CacheSet, CostKey};
use crate::server::jobs::CancelToken;
use crate::server::protocol::{ErrorCode, ProtocolError};
use crate::testing::fuzz;
use crate::util::json::{Json, JsonObj};
use crate::util::pool::Pool;
use std::sync::Arc;

/// Upper bound on `cases` for daemon-submitted fuzz jobs; a bigger run
/// belongs in the standalone `rsir fuzz` CLI, not a shared job queue.
pub const MAX_FUZZ_CASES: usize = 1024;

/// A job that failed (deterministically — the message is part of the
/// byte-identity contract, so it must not embed times or paths).
#[derive(Debug, Clone)]
pub struct JobError {
    pub code: ErrorCode,
    pub message: String,
}

impl JobError {
    fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        JobError {
            code,
            message: message.into(),
        }
    }

    fn bad(message: impl Into<String>) -> Self {
        JobError::new(ErrorCode::BadRequest, message)
    }
}

/// The design a job operates on: a named builtin benchmark or an inline
/// IR document shipped in the request line.
#[derive(Debug, Clone)]
pub enum DesignInput {
    /// A benchmark id for [`generate_by_id`] (`cnn:RxC`, `llama2`, ...).
    Bench(String),
    /// A full design, already validated at parse time.
    Inline(Box<Design>),
}

#[derive(Debug, Clone)]
pub struct FlowParams {
    pub input: DesignInput,
    pub device: String,
    pub util: Option<f64>,
    pub sa_refine: bool,
    pub seed: Option<u64>,
}

#[derive(Debug, Clone)]
pub struct PipelineParams {
    pub input: DesignInput,
    pub spec: String,
    pub drc: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FuzzLane {
    Ir,
    Verilog,
}

#[derive(Debug, Clone)]
pub struct FuzzParams {
    pub seed: u64,
    pub cases: usize,
    pub lane: FuzzLane,
}

#[derive(Debug, Clone)]
pub struct ExploreParams {
    pub input: DesignInput,
    pub device: String,
    pub limits: Vec<f64>,
}

/// A validated, queueable job.
#[derive(Debug, Clone)]
pub enum JobRequest {
    Flow(FlowParams),
    Pipeline(PipelineParams),
    Fuzz(FuzzParams),
    Explore(ExploreParams),
}

fn bad(message: impl Into<String>) -> ProtocolError {
    ProtocolError::new(ErrorCode::BadRequest, message)
}

/// Reject unknown params so typos fail loudly instead of silently
/// running with defaults.
fn check_keys(params: &JsonObj, allowed: &[&str]) -> Result<(), ProtocolError> {
    for k in params.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(bad(format!(
                "unknown param '{k}' (allowed: {})",
                allowed.join(", ")
            )));
        }
    }
    Ok(())
}

fn parse_input(params: &JsonObj) -> Result<DesignInput, ProtocolError> {
    match (params.get("bench"), params.get("design")) {
        (Some(b), None) => match b.as_str() {
            Some(s) => Ok(DesignInput::Bench(s.to_string())),
            None => Err(bad("'bench' must be a string")),
        },
        (None, Some(d)) => match design_from_json(d) {
            Ok(design) => Ok(DesignInput::Inline(Box::new(design))),
            Err(e) => Err(bad(format!("invalid inline design: {e:#}"))),
        },
        _ => Err(bad("exactly one of 'bench' or 'design' is required")),
    }
}

fn opt_str(params: &JsonObj, key: &str, default: &str) -> Result<String, ProtocolError> {
    match params.get(key) {
        None | Some(Json::Null) => Ok(default.to_string()),
        Some(v) => match v.as_str() {
            Some(s) => Ok(s.to_string()),
            None => Err(bad(format!("'{key}' must be a string"))),
        },
    }
}

fn opt_bool(params: &JsonObj, key: &str, default: bool) -> Result<bool, ProtocolError> {
    match params.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => match v.as_bool() {
            Some(b) => Ok(b),
            None => Err(bad(format!("'{key}' must be a boolean"))),
        },
    }
}

fn opt_u64(params: &JsonObj, key: &str) -> Result<Option<u64>, ProtocolError> {
    match params.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => match v.as_u64() {
            Some(n) => Ok(Some(n)),
            None => Err(bad(format!("'{key}' must be a non-negative integer"))),
        },
    }
}

fn opt_f64(params: &JsonObj, key: &str) -> Result<Option<f64>, ProtocolError> {
    match params.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => match v.as_f64() {
            Some(n) if n.is_finite() => Ok(Some(n)),
            _ => Err(bad(format!("'{key}' must be a finite number"))),
        },
    }
}

impl JobRequest {
    /// Validate the `params` object of a job request line. Strict: every
    /// structural problem is a typed `bad-request` before anything is
    /// queued.
    pub fn parse(kind: &str, params: &JsonObj) -> Result<JobRequest, ProtocolError> {
        match kind {
            "flow" => {
                check_keys(
                    params,
                    &["bench", "design", "device", "util", "sa_refine", "seed"],
                )?;
                Ok(JobRequest::Flow(FlowParams {
                    input: parse_input(params)?,
                    device: opt_str(params, "device", "u280")?,
                    util: opt_f64(params, "util")?,
                    sa_refine: opt_bool(params, "sa_refine", true)?,
                    seed: opt_u64(params, "seed")?,
                }))
            }
            "pipeline" => {
                check_keys(params, &["bench", "design", "spec", "drc"])?;
                Ok(JobRequest::Pipeline(PipelineParams {
                    input: parse_input(params)?,
                    spec: opt_str(params, "spec", registry::ANALYZE_STRUCTURE)?,
                    drc: opt_bool(params, "drc", false)?,
                }))
            }
            "fuzz" => {
                check_keys(params, &["seed", "cases", "lane"])?;
                let cases = opt_u64(params, "cases")?.unwrap_or(64) as usize;
                if cases == 0 || cases > MAX_FUZZ_CASES {
                    return Err(bad(format!("'cases' must be in 1..={MAX_FUZZ_CASES}")));
                }
                let lane = match opt_str(params, "lane", "ir")?.as_str() {
                    "ir" => FuzzLane::Ir,
                    "verilog" => FuzzLane::Verilog,
                    other => return Err(bad(format!("unknown fuzz lane '{other}'"))),
                };
                Ok(JobRequest::Fuzz(FuzzParams {
                    seed: opt_u64(params, "seed")?.unwrap_or(0),
                    cases,
                    lane,
                }))
            }
            "explore" => {
                check_keys(params, &["bench", "design", "device", "limits"])?;
                let limits = match params.get("limits") {
                    None | Some(Json::Null) => explore::default_limits(),
                    Some(v) => {
                        let Some(arr) = v.as_arr() else {
                            return Err(bad("'limits' must be an array of numbers"));
                        };
                        let mut out = Vec::with_capacity(arr.len());
                        for item in arr {
                            match item.as_f64() {
                                Some(f) if f.is_finite() && f > 0.0 && f <= 1.0 => out.push(f),
                                _ => {
                                    return Err(bad(
                                        "'limits' entries must be numbers in (0, 1]",
                                    ))
                                }
                            }
                        }
                        if out.is_empty() || out.len() > 64 {
                            return Err(bad("'limits' must have 1..=64 entries"));
                        }
                        out
                    }
                };
                Ok(JobRequest::Explore(ExploreParams {
                    input: parse_input(params)?,
                    device: opt_str(params, "device", "vhk158")?,
                    limits,
                }))
            }
            other => Err(ProtocolError::new(
                ErrorCode::UnknownType,
                format!("unknown request type '{other}'"),
            )),
        }
    }

    /// Canonical JSON of this request: fixed key order, absent options as
    /// `null`, inline designs reduced to their digest. Two requests that
    /// must produce the same bytes canonicalize identically — this is the
    /// `results` cache key material.
    pub fn canonical(&self) -> Json {
        fn input_keys(o: &mut JsonObj, input: &DesignInput) {
            match input {
                DesignInput::Bench(b) => o.insert("bench", Json::str(b)),
                DesignInput::Inline(d) => o.insert(
                    "design_digest",
                    Json::str(format!("{:016x}", synthetic::digest(d))),
                ),
            }
        }
        let mut o = JsonObj::new();
        match self {
            JobRequest::Flow(p) => {
                o.insert("type", Json::str("flow"));
                input_keys(&mut o, &p.input);
                o.insert("device", Json::str(&p.device));
                o.insert("util", p.util.map(Json::num).unwrap_or(Json::Null));
                o.insert("sa_refine", Json::Bool(p.sa_refine));
                o.insert(
                    "seed",
                    p.seed.map(|s| Json::num(s as f64)).unwrap_or(Json::Null),
                );
            }
            JobRequest::Pipeline(p) => {
                o.insert("type", Json::str("pipeline"));
                input_keys(&mut o, &p.input);
                o.insert("spec", Json::str(&p.spec));
                o.insert("drc", Json::Bool(p.drc));
            }
            JobRequest::Fuzz(p) => {
                o.insert("type", Json::str("fuzz"));
                o.insert("seed", Json::num(p.seed as f64));
                o.insert("cases", Json::num(p.cases as f64));
                o.insert(
                    "lane",
                    Json::str(match p.lane {
                        FuzzLane::Ir => "ir",
                        FuzzLane::Verilog => "verilog",
                    }),
                );
            }
            JobRequest::Explore(p) => {
                o.insert("type", Json::str("explore"));
                input_keys(&mut o, &p.input);
                o.insert("device", Json::str(&p.device));
                o.insert(
                    "limits",
                    Json::Arr(p.limits.iter().map(|&l| Json::num(l)).collect()),
                );
            }
        }
        Json::Obj(o)
    }

    /// The `results`-cache key: FNV-1a of the canonical request text.
    pub fn result_key(&self) -> u64 {
        synthetic::fnv1a64(self.canonical().dump().as_bytes())
    }

    /// The wire name of this job's kind.
    pub fn kind(&self) -> &'static str {
        match self {
            JobRequest::Flow(_) => "flow",
            JobRequest::Pipeline(_) => "pipeline",
            JobRequest::Fuzz(_) => "fuzz",
            JobRequest::Explore(_) => "explore",
        }
    }
}

/// Encode a float that may be NaN/inf: `Json::Num(NaN)` would dump
/// invalid JSON, so non-finite values become `null` on the wire.
pub fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::num(v)
    } else {
        Json::Null
    }
}

/// Cancellation/deadline pre-check, also used between coarse steps.
fn check(token: &CancelToken) -> Result<(), JobError> {
    if token.canceled() {
        return Err(JobError::new(ErrorCode::Canceled, "job canceled"));
    }
    if token.expired() {
        return Err(JobError::new(ErrorCode::Timeout, "job deadline exceeded"));
    }
    Ok(())
}

/// Resolve a job's design input to (design, input digest, bench name).
fn resolve_input(input: &DesignInput) -> Result<(Design, u64, Option<String>), JobError> {
    match input {
        DesignInput::Bench(id) => {
            let g = generate_by_id(id)
                .map_err(|e| JobError::bad(format!("unknown benchmark '{id}': {e:#}")))?;
            let digest = synthetic::digest(&g.design);
            Ok((g.design, digest, Some(id.clone())))
        }
        DesignInput::Inline(d) => {
            let digest = synthetic::digest(d);
            Ok(((**d).clone(), digest, None))
        }
    }
}

/// Run one job to a canonical result payload. The single dispatcher both
/// lanes share: memo probe → run → memo insert (success only, so a
/// canceled or failed job can never poison the cache).
pub fn execute(req: &JobRequest, caches: &CacheSet, token: &CancelToken) -> Result<Json, JobError> {
    let key = req.result_key();
    if let Some(hit) = caches.result(key) {
        return Ok(hit);
    }
    check(token)?;
    // Fault site `pool.job`: the body of a pool-scheduled daemon job.
    // This is where panic injection belongs — not inside the pool's own
    // plumbing, whose panic-transparency would re-raise on the server
    // thread — because [`execute_caught`]'s barrier is what's under test.
    if let Some(msg) = crate::testing::faults::fire_job("pool.job") {
        return Err(JobError::new(ErrorCode::Internal, msg));
    }
    let result = match req {
        JobRequest::Flow(p) => run_flow(p, caches, token),
        JobRequest::Pipeline(p) => run_pipeline(p, caches, token),
        JobRequest::Fuzz(p) => run_fuzz(p, token),
        JobRequest::Explore(p) => run_explore(p, caches, token),
    }?;
    caches.put_result(key, result.clone());
    Ok(result)
}

/// [`execute`] behind a per-job panic barrier: a panicking job — a bug
/// in a pass, or the fault plane's `pool.job` Panic action — becomes a
/// typed `internal-panic` envelope instead of unwinding the worker (and,
/// through the pool's panic transparency, the whole daemon). Both lanes
/// route through this, so a panic produces identical bytes from the
/// daemon and from `rsir submit --local`.
pub fn execute_caught(
    req: &JobRequest,
    caches: &CacheSet,
    token: &CancelToken,
) -> Result<Json, JobError> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| execute(req, caches, token)))
        .unwrap_or_else(|payload| {
            let msg = if let Some(s) = payload.downcast_ref::<&'static str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "opaque panic payload".to_string()
            };
            Err(JobError::new(
                ErrorCode::InternalPanic,
                format!("job panicked: {msg}"),
            ))
        })
}

/// Map a flow failure to a typed job error, distinguishing the
/// cancellation marker (explicit cancel vs deadline) from real failures.
fn flow_error(e: anyhow::Error, token: &CancelToken) -> JobError {
    if e.downcast_ref::<FlowCanceled>().is_some() {
        if token.canceled() {
            JobError::new(ErrorCode::Canceled, "job canceled")
        } else {
            JobError::new(ErrorCode::Timeout, "job deadline exceeded")
        }
    } else {
        JobError::new(ErrorCode::Internal, format!("flow failed: {e:#}"))
    }
}

fn run_flow(p: &FlowParams, caches: &CacheSet, token: &CancelToken) -> Result<Json, JobError> {
    let (mut design, digest, bench) = resolve_input(&p.input)?;
    let dev = builtin::by_name(&p.device)
        .map_err(|e| JobError::bad(format!("unknown device '{}': {e:#}", p.device)))?;
    let mut cfg = FlowConfig {
        sa_refine: p.sa_refine,
        ..Default::default()
    };
    if let Some(u) = p.util {
        cfg.util_limit = u;
    }
    if let Some(s) = p.seed {
        cfg.sa.seed = s;
    }
    let cost_key = CostKey::new(digest, &p.device, cfg.util_limit, cfg.die_weight);
    let stop = || token.stopped();
    let mut warm = FlowWarm {
        analyzed: caches.analyzed(digest),
        cost_model: caches.cost(&cost_key),
        cancel: Some(&stop),
        stage: Some(caches.stage()),
        ..Default::default()
    };
    let report = flow::run_hlps_warm(&mut design, &dev, &cfg, &mut warm);
    if let Some(a) = warm.harvest_analyzed.take() {
        caches.put_analyzed(digest, a);
    }
    if let Some(m) = warm.harvest_cost.take() {
        caches.put_cost(cost_key, m);
    }
    let report = report.map_err(|e| flow_error(e, token))?;

    let mut o = JsonObj::new();
    o.insert("design_digest", Json::str(format!("{digest:016x}")));
    if let Some(b) = bench {
        o.insert("bench", Json::str(b));
    }
    o.insert("device", Json::str(&p.device));
    o.insert("partitions", Json::num(report.partitions as f64));
    o.insert("relay_stations", Json::num(report.relay_stations as f64));
    o.insert(
        "floorplan_wirelength",
        num_or_null(report.floorplan_wirelength),
    );
    o.insert("evaluator", Json::str(report.evaluator_used));
    o.insert("optimized_mhz", num_or_null(report.optimized.fmax_mhz()));
    o.insert("routable", Json::Bool(report.optimized.routable()));
    o.insert(
        "baseline_mhz",
        report.baseline_fmax().map(num_or_null).unwrap_or(Json::Null),
    );
    o.insert(
        "improvement_pct",
        report
            .improvement_pct()
            .map(num_or_null)
            .unwrap_or(Json::Null),
    );
    o.insert(
        "util_pct",
        Json::Arr(
            report
                .optimized
                .util_pct
                .iter()
                .map(|&u| num_or_null(u))
                .collect(),
        ),
    );
    o.insert(
        "log",
        Json::Arr(report.log.iter().map(Json::str).collect()),
    );
    Ok(Json::Obj(o))
}

fn run_pipeline(
    p: &PipelineParams,
    caches: &CacheSet,
    _token: &CancelToken,
) -> Result<Json, JobError> {
    let (design, digest_in, _bench) = resolve_input(&p.input)?;
    // The analyze-structure/no-DRC combination is exactly what the flow's
    // stage-1–2 snapshot holds, so pipeline jobs share the flow's warm
    // cache in both directions.
    let (out_design, report, log) = if p.spec == registry::ANALYZE_STRUCTURE && !p.drc {
        let analyzed = match caches.analyzed(digest_in) {
            Some(a) => a,
            None => {
                let a = Arc::new(flow::analyze_design(&design).map_err(|e| {
                    JobError::new(ErrorCode::Internal, format!("pipeline failed: {e:#}"))
                })?);
                caches.put_analyzed(digest_in, a.clone());
                a
            }
        };
        (
            analyzed.design.clone(),
            analyzed.report.clone(),
            analyzed.ctx.log.clone(),
        )
    } else {
        let pipeline = registry::build(&p.spec)
            .map_err(|e| JobError::bad(format!("invalid pipeline spec: {e:#}")))?;
        let mut d = design.clone();
        let mut ctx = PassContext::new();
        ctx.drc_after_each = p.drc;
        let report = pipeline.run(&mut d, &mut ctx).map_err(|e| {
            JobError::new(ErrorCode::Internal, format!("pipeline failed: {e:#}"))
        })?;
        (d, report, ctx.log)
    };

    let mut o = JsonObj::new();
    o.insert("design_digest_in", Json::str(format!("{digest_in:016x}")));
    o.insert("spec", Json::str(&p.spec));
    o.insert(
        "passes",
        Json::Arr(
            report
                .passes
                .iter()
                .map(|rec| {
                    let mut po = JsonObj::new();
                    po.insert("name", Json::str(&rec.name));
                    po.insert(
                        "drc",
                        Json::str(match rec.drc {
                            DrcOutcome::Clean => "clean",
                            DrcOutcome::Skipped => "-",
                        }),
                    );
                    Json::Obj(po)
                })
                .collect(),
        ),
    );
    o.insert("log", Json::Arr(log.iter().map(Json::str).collect()));
    o.insert(
        "design_digest_out",
        Json::str(format!("{:016x}", synthetic::digest(&out_design))),
    );
    o.insert("design", design_to_json(&out_design));
    Ok(Json::Obj(o))
}

fn run_fuzz(p: &FuzzParams, token: &CancelToken) -> Result<Json, JobError> {
    check(token)?;
    let cfg = SyntheticConfig::default();
    let mut o = JsonObj::new();
    o.insert(
        "lane",
        Json::str(match p.lane {
            FuzzLane::Ir => "ir",
            FuzzLane::Verilog => "verilog",
        }),
    );
    o.insert("seed", Json::num(p.seed as f64));
    o.insert("cases", Json::num(p.cases as f64));
    let failure = match p.lane {
        FuzzLane::Ir => {
            let report = fuzz::run(p.seed, p.cases, &cfg);
            report.failure.map(|f| {
                let mut fo = JsonObj::new();
                fo.insert("case", Json::num(f.case as f64));
                fo.insert(
                    "violations",
                    Json::Arr(f.violations.iter().map(|v| Json::str(*v)).collect()),
                );
                fo.insert("minimal_json", Json::str(f.minimal_json));
                Json::Obj(fo)
            })
        }
        FuzzLane::Verilog => {
            let report = fuzz::run_verilog(p.seed, p.cases, &cfg);
            report.failure.map(|f| {
                let mut fo = JsonObj::new();
                fo.insert("case", Json::num(f.case as f64));
                fo.insert(
                    "violations",
                    Json::Arr(f.violations.iter().map(|v| Json::str(*v)).collect()),
                );
                fo.insert("minimal_source", Json::str(f.minimal_source));
                Json::Obj(fo)
            })
        }
    };
    o.insert("ok", Json::Bool(failure.is_none()));
    o.insert("failure", failure.unwrap_or(Json::Null));
    Ok(Json::Obj(o))
}

fn run_explore(
    p: &ExploreParams,
    caches: &CacheSet,
    token: &CancelToken,
) -> Result<Json, JobError> {
    let (design, digest, _bench) = resolve_input(&p.input)?;
    let dev = builtin::by_name(&p.device)
        .map_err(|e| JobError::bad(format!("unknown device '{}': {e:#}", p.device)))?;
    check(token)?;
    // Warm the whole sweep from one snapshot. If analysis fails we pass
    // None so the first sweep point reproduces the identical per-point
    // failure the cold lane reports — an internal error that the sweep
    // now propagates (only typed infeasibility becomes a NaN row), so
    // the job fails with the cold lane's exact message.
    let analyzed = match caches.analyzed(digest) {
        Some(a) => Some(a),
        None => match flow::analyze_design(&design) {
            Ok(a) => {
                let a = Arc::new(a);
                caches.put_analyzed(digest, a.clone());
                Some(a)
            }
            Err(_) => None,
        },
    };
    let cfg = FlowConfig::default();
    let pool = Pool::new(1);
    let rows = explore::explore_warm_staged(
        &design,
        &dev,
        &p.limits,
        &cfg,
        &pool,
        analyzed,
        Some(caches.stage()),
    )
    .map_err(|e| JobError::new(ErrorCode::Internal, format!("explore failed: {e:#}")))?;

    let mut o = JsonObj::new();
    o.insert("design_digest", Json::str(format!("{digest:016x}")));
    o.insert("device", Json::str(&p.device));
    o.insert(
        "rows",
        Json::Arr(
            rows.iter()
                .map(|r| {
                    let mut ro = JsonObj::new();
                    ro.insert("util_limit", num_or_null(r.util_limit));
                    ro.insert("max_slot_util", num_or_null(r.max_slot_util));
                    ro.insert("wirelength", num_or_null(r.wirelength));
                    ro.insert("fmax_mhz", num_or_null(r.fmax_mhz));
                    ro.insert("routable", Json::Bool(r.routable));
                    Json::Obj(ro)
                })
                .collect(),
        ),
    );
    Ok(Json::Obj(o))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(text: &str) -> JsonObj {
        Json::parse(text).unwrap().as_obj().unwrap().clone()
    }

    #[test]
    fn parse_rejects_unknown_keys_and_bad_shapes() {
        assert!(JobRequest::parse("flow", &params(r#"{"bench":"cnn:2x2","oops":1}"#)).is_err());
        assert!(JobRequest::parse("flow", &params(r#"{}"#)).is_err());
        assert!(JobRequest::parse(
            "flow",
            &params(r#"{"bench":"cnn:2x2","design":{"top":"T","modules":[]}}"#)
        )
        .is_err());
        assert!(JobRequest::parse("fuzz", &params(r#"{"cases":0}"#)).is_err());
        assert!(JobRequest::parse("fuzz", &params(r#"{"cases":99999}"#)).is_err());
        assert!(JobRequest::parse("fuzz", &params(r#"{"lane":"vhdl"}"#)).is_err());
        assert!(JobRequest::parse("explore", &params(r#"{"bench":"x","limits":[2.0]}"#)).is_err());
        assert!(JobRequest::parse("nope", &params(r#"{}"#)).is_err());
    }

    #[test]
    fn parse_defaults() {
        let JobRequest::Flow(f) =
            JobRequest::parse("flow", &params(r#"{"bench":"cnn:2x2"}"#)).unwrap()
        else {
            panic!()
        };
        assert_eq!(f.device, "u280");
        assert!(f.sa_refine && f.util.is_none() && f.seed.is_none());
        let JobRequest::Fuzz(z) = JobRequest::parse("fuzz", &params(r#"{}"#)).unwrap() else {
            panic!()
        };
        assert_eq!((z.seed, z.cases, z.lane), (0, 64, FuzzLane::Ir));
        let JobRequest::Explore(e) =
            JobRequest::parse("explore", &params(r#"{"bench":"cnn:2x2"}"#)).unwrap()
        else {
            panic!()
        };
        assert_eq!(e.limits, explore::default_limits());
    }

    #[test]
    fn canonical_is_stable_and_distinguishes_params() {
        let a = JobRequest::parse("flow", &params(r#"{"bench":"cnn:2x2"}"#)).unwrap();
        let b = JobRequest::parse("flow", &params(r#"{"bench":"cnn:2x2","sa_refine":true}"#))
            .unwrap();
        // Defaulted and explicit-default params canonicalize identically.
        assert_eq!(a.canonical().dump(), b.canonical().dump());
        assert_eq!(a.result_key(), b.result_key());
        let c = JobRequest::parse("flow", &params(r#"{"bench":"cnn:2x2","util":0.6}"#)).unwrap();
        assert_ne!(a.result_key(), c.result_key());
        let d = JobRequest::parse("pipeline", &params(r#"{"bench":"cnn:2x2"}"#)).unwrap();
        assert_ne!(a.result_key(), d.result_key());
    }

    #[test]
    fn execute_memoizes_and_warm_equals_cold() {
        let req = JobRequest::parse(
            "flow",
            &params(r#"{"bench":"cnn:3x2","device":"u250","sa_refine":false}"#),
        )
        .unwrap();
        let token = CancelToken::default();
        let cold = execute(&req, &CacheSet::disabled(), &token).unwrap();
        let warm_caches = CacheSet::new(8);
        let first = execute(&req, &warm_caches, &token).unwrap();
        let second = execute(&req, &warm_caches, &token).unwrap();
        assert_eq!(cold.dump(), first.dump());
        assert_eq!(first.dump(), second.dump());
        let stats = warm_caches.stats();
        assert_eq!(stats[0].0, "results");
        assert!(stats[0].1.hits >= 1, "resubmit did not hit the memo");
    }

    #[test]
    fn pipeline_and_flow_share_the_analyzed_cache() {
        let caches = CacheSet::new(8);
        let token = CancelToken::default();
        let pipe = JobRequest::parse("pipeline", &params(r#"{"bench":"cnn:3x2"}"#)).unwrap();
        execute(&pipe, &caches, &token).unwrap();
        let analyzed_misses = caches.stats()[1].1.misses;
        let flow = JobRequest::parse(
            "flow",
            &params(r#"{"bench":"cnn:3x2","device":"u250","sa_refine":false}"#),
        )
        .unwrap();
        execute(&flow, &caches, &token).unwrap();
        let s = caches.stats()[1].1;
        assert!(s.hits >= 1, "flow did not reuse the pipeline's analysis");
        assert_eq!(s.misses, analyzed_misses, "flow re-analyzed a cached design");
    }

    #[test]
    fn canceled_token_yields_typed_error() {
        let req = JobRequest::parse("flow", &params(r#"{"bench":"cnn:2x2"}"#)).unwrap();
        let token = CancelToken::default();
        token.cancel();
        let err = execute(&req, &CacheSet::disabled(), &token).unwrap_err();
        assert_eq!(err.code, ErrorCode::Canceled);
    }
}

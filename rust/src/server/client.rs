//! The `rsir submit` client: ship a batch of request lines to a running
//! daemon (or run them through the identical one-shot lane with
//! `--local`) and print one response line per request, in request order.
//!
//! The two lanes are the two sides of the daemon's determinism contract:
//! for any job line, `run_batch_local` and `run_batch_remote` must emit
//! byte-identical responses. The differential oracle fuzzes exactly this
//! equivalence.

use crate::server::cache::CacheSet;
use crate::server::jobs::CancelToken;
use crate::server::ops;
use crate::server::protocol::{
    err_line, hello_result, job_id_string, ok_line, parse_line, shutdown_result, ErrorCode,
    LineEvent, LineReader, Request, DEFAULT_MAX_LINE, VERSION,
};
use crate::server::{connect, Bind, Stream};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::Write;
use std::time::{Duration, Instant};

/// Run a batch through the one-shot lane: no daemon, no warm state
/// ([`CacheSet::disabled`]), jobs executed sequentially in request
/// order. `timeout_ms` is ignored here — a one-shot run has no queue to
/// time out of — but every *semantic* check (job-id requirement,
/// duplicate ids, cancel targets) mirrors the daemon so responses match
/// byte for byte.
pub fn run_batch_local(lines: &[String]) -> Vec<String> {
    let caches = CacheSet::disabled();
    let mut seen_jobs: BTreeSet<String> = BTreeSet::new();
    let mut out = Vec::new();
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        let env = parse_line(line);
        let resp = match env.request {
            Err(e) => err_line(&env.id, e.code, &e.message),
            Ok(Request::Hello) => ok_line(&env.id, hello_result(0)),
            Ok(Request::Stats) => err_line(
                &env.id,
                ErrorCode::BadRequest,
                "stats is only available from a running daemon",
            ),
            Ok(Request::Cancel { job }) => {
                // Sequential execution: every earlier job already
                // completed, so a known target is "already completed" and
                // anything else is unknown — same bytes as a daemon that
                // processed the batch serially.
                if seen_jobs.contains(&job) {
                    err_line(
                        &env.id,
                        ErrorCode::UnknownJob,
                        &format!("job '{job}' already completed"),
                    )
                } else {
                    err_line(
                        &env.id,
                        ErrorCode::UnknownJob,
                        &format!("no such job '{job}'"),
                    )
                }
            }
            Ok(Request::Shutdown) => ok_line(&env.id, shutdown_result()),
            Ok(Request::Job(req)) => match job_id_string(&env.id) {
                None => err_line(
                    &env.id,
                    ErrorCode::BadRequest,
                    "job requests require a string or numeric id",
                ),
                Some(id) if seen_jobs.contains(&id) => err_line(
                    &env.id,
                    ErrorCode::DuplicateJob,
                    &format!("job id '{id}' already used on this connection"),
                ),
                Some(id) => {
                    seen_jobs.insert(id);
                    match ops::execute(&req, &caches, &CancelToken::default()) {
                        Ok(result) => ok_line(&env.id, result),
                        Err(e) => err_line(&env.id, e.code, &e.message),
                    }
                }
            },
        };
        out.push(resp);
    }
    out
}

/// Read one response line, polling through read timeouts until
/// `deadline`.
fn read_response(reader: &mut LineReader<Stream>, deadline: Instant) -> Result<String> {
    loop {
        match reader.poll_line()? {
            LineEvent::Line(l) if l.trim().is_empty() => continue,
            LineEvent::Line(l) => return Ok(l),
            LineEvent::Idle => {
                if Instant::now() >= deadline {
                    bail!("timed out waiting for a daemon response");
                }
            }
            LineEvent::Eof => bail!("daemon closed the connection"),
            LineEvent::Oversized => bail!("daemon response exceeded the line cap"),
        }
    }
}

/// The `id` key a response line files under (its dumped form).
fn response_id_key(line: &str) -> String {
    crate::util::json::Json::parse(line)
        .ok()
        .and_then(|j| j.as_obj().and_then(|o| o.get("id").cloned()))
        .unwrap_or(crate::util::json::Json::Null)
        .dump()
}

/// Ship a batch to a running daemon and return one response per
/// non-empty request line, **in request order** (the daemon may answer
/// jobs out of order; responses are re-matched by id). Performs a
/// `hello` handshake first and warns on version skew.
pub fn run_batch_remote(bind: &Bind, lines: &[String], timeout: Duration) -> Result<Vec<String>> {
    let stream = connect(bind).with_context(|| format!("connecting to {bind}"))?;
    stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .context("setting read timeout")?;
    let mut write_half = stream.try_clone().context("cloning stream")?;
    let mut reader = LineReader::new(stream, DEFAULT_MAX_LINE);
    let deadline = Instant::now() + timeout;

    // Handshake: sent before anything else, so the first response line
    // is unambiguously the hello.
    write_half.write_all(b"{\"type\":\"hello\"}\n")?;
    write_half.flush()?;
    let hello = read_response(&mut reader, deadline)?;
    if let Ok(j) = crate::util::json::Json::parse(&hello) {
        let server_version = j
            .as_obj()
            .and_then(|o| o.get("result"))
            .and_then(|r| r.as_obj())
            .and_then(|r| r.get("version"))
            .and_then(|v| v.as_str())
            .unwrap_or("?")
            .to_string();
        if server_version != VERSION {
            eprintln!(
                "warning: daemon version {server_version} differs from client {VERSION}"
            );
        }
    }

    let requests: Vec<&String> = lines.iter().filter(|l| !l.trim().is_empty()).collect();
    for line in &requests {
        write_half.write_all(line.as_bytes())?;
        write_half.write_all(b"\n")?;
    }
    write_half.flush()?;

    // Collect exactly one response per request, then restore request
    // order. Same-id responses (e.g. a duplicate-id rejection) queue up
    // and are consumed in arrival order.
    let mut by_id: BTreeMap<String, VecDeque<String>> = BTreeMap::new();
    for _ in 0..requests.len() {
        let resp = read_response(&mut reader, deadline)?;
        by_id.entry(response_id_key(&resp)).or_default().push_back(resp);
    }
    let mut out = Vec::with_capacity(requests.len());
    for line in &requests {
        let key = parse_line(line).id.dump();
        match by_id.get_mut(&key).and_then(|q| q.pop_front()) {
            Some(resp) => out.push(resp),
            None => bail!("daemon sent no response for request id {key}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_lane_handles_every_request_type() {
        let lines: Vec<String> = [
            r#"{"type":"hello"}"#,
            r#"{"id":"s","type":"stats"}"#,
            r#"{"id":"c","type":"cancel","params":{"job":"nope"}}"#,
            r#"{"type":"flow","params":{"bench":"cnn:2x2"}}"#,
            "not json",
            "",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let out = run_batch_local(&lines);
        assert_eq!(out.len(), 5); // blank line skipped
        assert!(out[0].contains("\"ok\":true") && out[0].contains("\"version\""));
        assert!(out[1].contains("bad-request"));
        assert!(out[2].contains("unknown-job"));
        // Job without an id is rejected, same as on the daemon.
        assert!(out[3].contains("job requests require"));
        assert!(out[4].contains("bad-json"));
    }

    #[test]
    fn local_lane_rejects_duplicate_job_ids() {
        let job = r#"{"id":"j1","type":"pipeline","params":{"bench":"cnn:2x2"}}"#.to_string();
        let out = run_batch_local(&[job.clone(), job]);
        assert!(out[0].contains("\"ok\":true"));
        assert!(out[1].contains("duplicate-job"));
    }
}

//! The `rsir submit` client: ship a batch of request lines to a running
//! daemon (or run them through the identical one-shot lane with
//! `--local`) and print one response line per request, in request order.
//!
//! The two lanes are the two sides of the daemon's determinism contract:
//! for any job line, `run_batch_local` and `run_batch_remote` must emit
//! byte-identical responses. The differential oracle fuzzes exactly this
//! equivalence.

use crate::server::cache::CacheSet;
use crate::server::jobs::CancelToken;
use crate::server::ops;
use crate::server::protocol::{
    err_line, hello_result, job_id_string, ok_line, parse_line, shutdown_result, ErrorCode,
    LineEvent, LineReader, Request, DEFAULT_MAX_LINE, VERSION,
};
use crate::server::{connect, Bind, Stream};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::Write;
use std::time::{Duration, Instant};

/// Run a batch through the one-shot lane: no daemon, no warm state
/// ([`CacheSet::disabled`]), jobs executed sequentially in request
/// order. `timeout_ms` is ignored here — a one-shot run has no queue to
/// time out of — but every *semantic* check (job-id requirement,
/// duplicate ids, cancel targets) mirrors the daemon so responses match
/// byte for byte.
pub fn run_batch_local(lines: &[String]) -> Vec<String> {
    let caches = CacheSet::disabled();
    let mut seen_jobs: BTreeSet<String> = BTreeSet::new();
    let mut out = Vec::new();
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        let env = parse_line(line);
        let resp = match env.request {
            Err(e) => err_line(&env.id, e.code, &e.message),
            Ok(Request::Hello) => ok_line(&env.id, hello_result(0)),
            Ok(Request::Stats) => err_line(
                &env.id,
                ErrorCode::BadRequest,
                "stats is only available from a running daemon",
            ),
            Ok(Request::Cancel { job }) => {
                // Sequential execution: every earlier job already
                // completed, so a known target is "already completed" and
                // anything else is unknown — same bytes as a daemon that
                // processed the batch serially.
                if seen_jobs.contains(&job) {
                    err_line(
                        &env.id,
                        ErrorCode::UnknownJob,
                        &format!("job '{job}' already completed"),
                    )
                } else {
                    err_line(
                        &env.id,
                        ErrorCode::UnknownJob,
                        &format!("no such job '{job}'"),
                    )
                }
            }
            Ok(Request::Shutdown) => ok_line(&env.id, shutdown_result()),
            Ok(Request::Job(req)) => match job_id_string(&env.id) {
                None => err_line(
                    &env.id,
                    ErrorCode::BadRequest,
                    "job requests require a string or numeric id",
                ),
                Some(id) if seen_jobs.contains(&id) => err_line(
                    &env.id,
                    ErrorCode::DuplicateJob,
                    &format!("job id '{id}' already used on this connection"),
                ),
                Some(id) => {
                    seen_jobs.insert(id);
                    // Same panic barrier as the daemon's workers, so a
                    // panicking job yields the identical typed
                    // `internal-panic` envelope from both lanes.
                    match ops::execute_caught(&req, &caches, &CancelToken::default()) {
                        Ok(result) => ok_line(&env.id, result),
                        Err(e) => err_line(&env.id, e.code, &e.message),
                    }
                }
            },
        };
        out.push(resp);
    }
    out
}

/// Read one response line, polling through read timeouts until
/// `deadline`.
fn read_response(reader: &mut LineReader<Stream>, deadline: Instant) -> Result<String> {
    loop {
        match reader.poll_line()? {
            LineEvent::Line(l) if l.trim().is_empty() => continue,
            LineEvent::Line(l) => return Ok(l),
            LineEvent::Idle => {
                if Instant::now() >= deadline {
                    bail!("timed out waiting for a daemon response");
                }
            }
            LineEvent::Eof => bail!("daemon closed the connection"),
            LineEvent::Oversized => bail!("daemon response exceeded the line cap"),
        }
    }
}

/// The `id` key a response line files under (its dumped form).
fn response_id_key(line: &str) -> String {
    crate::util::json::Json::parse(line)
        .ok()
        .and_then(|j| j.as_obj().and_then(|o| o.get("id").cloned()))
        .unwrap_or(crate::util::json::Json::Null)
        .dump()
}

/// Reconnect/backoff policy for [`run_batch_remote_with`]. Deliberately
/// jitter-free: the schedule is a pure function of the attempt number,
/// so a failing fuzz case replays with identical timing.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total connection attempts (clamped to at least 1).
    pub attempts: u32,
    /// Backoff before the first retry; doubles per retry.
    pub base_delay: Duration,
    /// Backoff cap.
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 4,
            base_delay: Duration::from_millis(25),
            max_delay: Duration::from_millis(400),
        }
    }
}

impl RetryPolicy {
    /// A client that never retries (the pre-hardening behavior, still
    /// wanted by tests that assert on first-failure semantics).
    pub fn none() -> Self {
        RetryPolicy {
            attempts: 1,
            ..RetryPolicy::default()
        }
    }

    /// The capped exponential delay before retry number `retry` (0-based).
    pub fn backoff(&self, retry: u32) -> Duration {
        let mult = 1u32.checked_shl(retry).unwrap_or(u32::MAX);
        self.base_delay
            .checked_mul(mult)
            .map(|d| d.min(self.max_delay))
            .unwrap_or(self.max_delay)
    }
}

/// One connection attempt: connect, handshake, ship the **whole** batch,
/// and file every response that arrives into its request's slot
/// (first answer wins). Replaying the full batch — rather than only the
/// unanswered suffix — preserves within-batch semantics (duplicate-id
/// rejections, cancel targets) exactly, and is free on the daemon side:
/// completed jobs replay from the results cache byte-for-byte.
fn attempt_batch(
    bind: &Bind,
    requests: &[&String],
    answered: &mut [Option<String>],
    deadline: Instant,
) -> Result<()> {
    let stream = connect(bind).with_context(|| format!("connecting to {bind}"))?;
    stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .context("setting read timeout")?;
    let mut write_half = stream.try_clone().context("cloning stream")?;
    let mut reader = LineReader::with_site(stream, DEFAULT_MAX_LINE, "client.io.read");

    // Handshake: sent before anything else, so the first response line
    // is unambiguously the hello.
    write_half.write_all(b"{\"type\":\"hello\"}\n")?;
    write_half.flush()?;
    let hello = read_response(&mut reader, deadline)?;
    if let Ok(j) = crate::util::json::Json::parse(&hello) {
        let server_version = j
            .as_obj()
            .and_then(|o| o.get("result"))
            .and_then(|r| r.as_obj())
            .and_then(|r| r.get("version"))
            .and_then(|v| v.as_str())
            .unwrap_or("?")
            .to_string();
        if server_version != VERSION {
            eprintln!(
                "warning: daemon version {server_version} differs from client {VERSION}"
            );
        }
    }

    for line in requests {
        write_half.write_all(line.as_bytes())?;
        write_half.write_all(b"\n")?;
    }
    write_half.flush()?;

    // Collect up to one response per request. A transport failure
    // mid-collection still files what already arrived — those answers
    // are final; only the remainder rides the next attempt.
    let mut received: Vec<String> = Vec::new();
    let mut failure: Option<anyhow::Error> = None;
    for _ in 0..requests.len() {
        match read_response(&mut reader, deadline) {
            Ok(resp) => received.push(resp),
            Err(e) => {
                failure = Some(e);
                break;
            }
        }
    }

    // Re-match by id in request order. Same-id responses (e.g. a
    // duplicate-id rejection) queue up and are consumed in arrival
    // order, as before retries existed.
    let mut by_id: BTreeMap<String, VecDeque<String>> = BTreeMap::new();
    for resp in received {
        by_id.entry(response_id_key(&resp)).or_default().push_back(resp);
    }
    for (slot, line) in requests.iter().enumerate() {
        let key = parse_line(line).id.dump();
        if let Some(resp) = by_id.get_mut(&key).and_then(|q| q.pop_front()) {
            if answered[slot].is_none() {
                answered[slot] = Some(resp);
            }
        }
    }
    match failure {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Ship a batch to a running daemon and return one response per
/// non-empty request line, **in request order** (the daemon may answer
/// jobs out of order; responses are re-matched by id). Performs a
/// `hello` handshake first and warns on version skew. Retries with the
/// default [`RetryPolicy`]; see [`run_batch_remote_with`].
pub fn run_batch_remote(bind: &Bind, lines: &[String], timeout: Duration) -> Result<Vec<String>> {
    run_batch_remote_with(bind, lines, timeout, &RetryPolicy::default())
}

/// [`run_batch_remote`] with an explicit reconnect policy. Only
/// *transport* failures retry (connect refused, EOF, read timeout,
/// oversized frame); a typed error envelope is a final answer — the
/// daemon has spoken — and is never re-submitted. The overall `timeout`
/// is a hard deadline across all attempts.
pub fn run_batch_remote_with(
    bind: &Bind,
    lines: &[String],
    timeout: Duration,
    policy: &RetryPolicy,
) -> Result<Vec<String>> {
    let requests: Vec<&String> = lines.iter().filter(|l| !l.trim().is_empty()).collect();
    let deadline = Instant::now() + timeout;
    let mut answered: Vec<Option<String>> = vec![None; requests.len()];
    let mut last_err: Option<anyhow::Error> = None;
    for attempt in 0..policy.attempts.max(1) {
        if attempt > 0 {
            let wait = policy.backoff(attempt - 1);
            if Instant::now() + wait >= deadline {
                break;
            }
            std::thread::sleep(wait);
        }
        // An attempt that *panics* (the fault plane's `client.io.read`
        // Panic action, or a real client bug) is indistinguishable from
        // a dropped connection to the caller: absorb it and retry.
        // Answers are only filed after a successful read, so a panicked
        // attempt cannot leave a half-written slot behind.
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            attempt_batch(bind, &requests, &mut answered, deadline)
        })) {
            Ok(Ok(())) => last_err = None,
            Ok(Err(e)) => last_err = Some(e),
            Err(_) => last_err = Some(anyhow::anyhow!("client connection attempt panicked")),
        }
        if answered.iter().all(|a| a.is_some()) || Instant::now() >= deadline {
            break;
        }
    }
    if let Some(out) = answered.into_iter().collect::<Option<Vec<String>>>() {
        return Ok(out);
    }
    match last_err {
        Some(e) => Err(e),
        None => bail!("daemon sent no response for at least one request id"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_lane_handles_every_request_type() {
        let lines: Vec<String> = [
            r#"{"type":"hello"}"#,
            r#"{"id":"s","type":"stats"}"#,
            r#"{"id":"c","type":"cancel","params":{"job":"nope"}}"#,
            r#"{"type":"flow","params":{"bench":"cnn:2x2"}}"#,
            "not json",
            "",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let out = run_batch_local(&lines);
        assert_eq!(out.len(), 5); // blank line skipped
        assert!(out[0].contains("\"ok\":true") && out[0].contains("\"version\""));
        assert!(out[1].contains("bad-request"));
        assert!(out[2].contains("unknown-job"));
        // Job without an id is rejected, same as on the daemon.
        assert!(out[3].contains("job requests require"));
        assert!(out[4].contains("bad-json"));
    }

    #[test]
    fn local_lane_rejects_duplicate_job_ids() {
        let job = r#"{"id":"j1","type":"pipeline","params":{"bench":"cnn:2x2"}}"#.to_string();
        let out = run_batch_local(&[job.clone(), job]);
        assert!(out[0].contains("\"ok\":true"));
        assert!(out[1].contains("duplicate-job"));
    }
}

//! The daemon's deterministic job queue: a bounded FIFO multiplexed onto
//! [`util::pool`](crate::util::pool) workers, with per-job cooperative
//! cancellation and deadlines.
//!
//! Determinism note: the *scheduling* is not what makes daemon results
//! reproducible (workers race freely) — the purity of each job is. The
//! queue's job is back-pressure (bounded depth, typed `queue-full`
//! rejection) and orderly shutdown (`close` drains what was accepted).

use crate::server::ops::JobRequest;
use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Cooperative cancellation handle, shared between the connection that
/// owns a job and the worker running it. Cheap to clone; polled by the
/// flow at stage boundaries.
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
    deadline: Option<Instant>,
}

impl CancelToken {
    pub fn new(deadline: Option<Instant>) -> Self {
        CancelToken {
            flag: Arc::new(AtomicBool::new(false)),
            deadline,
        }
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    /// Explicitly canceled (as opposed to timed out).
    pub fn canceled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    pub fn expired(&self) -> bool {
        matches!(self.deadline, Some(d) if Instant::now() >= d)
    }

    /// The single predicate jobs poll: stop for either reason.
    pub fn stopped(&self) -> bool {
        self.canceled() || self.expired()
    }
}

/// One queued unit of work, carrying everything a worker needs to run it
/// and deliver the response line back to its connection.
#[derive(Debug)]
pub struct Job {
    /// Canonical string form of the id (registry key on the connection).
    pub id: String,
    /// The id as submitted, echoed verbatim in the response envelope.
    pub raw_id: Json,
    pub request: JobRequest,
    pub token: CancelToken,
    /// Set by the worker the moment the job finishes; a later `cancel`
    /// for this id is then `unknown-job`.
    pub done: Arc<AtomicBool>,
    /// Channel to the submitting connection's writer thread.
    pub respond: Sender<String>,
}

struct State {
    q: VecDeque<Job>,
    open: bool,
    running: usize,
}

/// Bounded MPMC FIFO. `push` never blocks (full or closed → the job is
/// handed back for a typed rejection); `pop` blocks until work arrives
/// or the queue is closed *and* drained.
pub struct JobQueue {
    state: Mutex<State>,
    cond: Condvar,
    cap: usize,
}

impl JobQueue {
    pub fn new(cap: usize) -> Self {
        JobQueue {
            state: Mutex::new(State {
                q: VecDeque::new(),
                open: true,
                running: 0,
            }),
            cond: Condvar::new(),
            cap: cap.max(1),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Enqueue; on a full or closed queue the job comes back so the
    /// caller can answer `queue-full` with the job's own response channel.
    pub fn push(&self, job: Job) -> Result<(), Job> {
        // Fault site: an injected admission failure is indistinguishable
        // from a full queue — the caller's typed `queue-full` rejection
        // covers both. An injected panic here unwinds the connection
        // thread, which the accept loop's per-connection barrier absorbs.
        if crate::testing::faults::fire_job("server.queue.push").is_some() {
            return Err(job);
        }
        let mut s = self.lock();
        if !s.open || s.q.len() >= self.cap {
            return Err(job);
        }
        s.q.push_back(job);
        self.cond.notify_one();
        Ok(())
    }

    /// Blocking dequeue. `None` means the queue is closed and fully
    /// drained — the worker should exit. Increments the running count;
    /// pair every `Some` with a [`JobQueue::finished`] call.
    pub fn pop(&self) -> Option<Job> {
        let mut s = self.lock();
        loop {
            if let Some(job) = s.q.pop_front() {
                s.running += 1;
                return Some(job);
            }
            if !s.open {
                return None;
            }
            s = self
                .cond
                .wait(s)
                .unwrap_or_else(|p| p.into_inner());
        }
    }

    pub fn finished(&self) {
        let mut s = self.lock();
        s.running = s.running.saturating_sub(1);
    }

    pub fn depth(&self) -> usize {
        self.lock().q.len()
    }

    pub fn running(&self) -> usize {
        self.lock().running
    }

    /// Stop accepting work and wake every blocked worker; already-queued
    /// jobs still drain.
    pub fn close(&self) {
        self.lock().open = false;
        self.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::ops::{DesignInput, JobRequest, PipelineParams};
    use std::sync::mpsc;
    use std::thread;
    use std::time::Duration;

    fn dummy_job(id: &str, tx: &Sender<String>) -> Job {
        Job {
            id: id.to_string(),
            raw_id: Json::str(id),
            request: JobRequest::Pipeline(PipelineParams {
                input: DesignInput::Bench("cnn:2x2".to_string()),
                spec: "analyze-structure".to_string(),
                drc: false,
            }),
            token: CancelToken::default(),
            done: Arc::new(AtomicBool::new(false)),
            respond: tx.clone(),
        }
    }

    #[test]
    fn fifo_order_and_bound() {
        let (tx, _rx) = mpsc::channel();
        let q = JobQueue::new(2);
        assert!(q.push(dummy_job("a", &tx)).is_ok());
        assert!(q.push(dummy_job("b", &tx)).is_ok());
        let rejected = q.push(dummy_job("c", &tx)).unwrap_err();
        assert_eq!(rejected.id, "c");
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop().unwrap().id, "a");
        assert_eq!(q.running(), 1);
        q.finished();
        assert_eq!(q.pop().unwrap().id, "b");
    }

    #[test]
    fn close_drains_then_releases_workers() {
        let (tx, _rx) = mpsc::channel();
        let q = Arc::new(JobQueue::new(4));
        q.push(dummy_job("a", &tx)).unwrap();
        q.close();
        assert!(q.push(dummy_job("b", &tx)).is_err());
        // The queued job still comes out; then pop returns None.
        assert_eq!(q.pop().unwrap().id, "a");
        assert!(q.pop().is_none());
        // A worker blocked in pop() is woken by close.
        let q2 = Arc::new(JobQueue::new(4));
        let qc = q2.clone();
        let h = thread::spawn(move || qc.pop().is_none());
        thread::sleep(Duration::from_millis(20));
        q2.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn cancel_token_states() {
        let t = CancelToken::default();
        assert!(!t.stopped());
        t.cancel();
        assert!(t.canceled() && t.stopped() && !t.expired());
        let expired = CancelToken::new(Some(Instant::now() - Duration::from_millis(1)));
        assert!(expired.expired() && expired.stopped() && !expired.canceled());
    }
}

//! Warm cross-request state for the daemon: the three whole-request
//! caches `rsir serve` keeps across jobs, plus the per-stage incremental
//! memo ([`StageMemo`]) that serves requests whose whole-request keys
//! miss.
//!
//! The cache-key design enforces the determinism contract structurally:
//! every cached value is a **pure function of its key**, so cache state
//! can change wall time but never a single result byte.
//!
//! | cache         | key                                                    | value |
//! |---------------|--------------------------------------------------------|-------|
//! | `analyzed`    | FNV-1a digest of the *input* IR                        | [`AnalyzedDesign`] (stage-1–2 snapshot) |
//! | `cost_models` | (digest, device, `util_limit` bits, `die_weight` bits) | [`CostModel`] |
//! | `results`     | FNV-1a of the canonical request JSON (type + params)   | canonical result payload |
//!
//! Floats enter keys as their IEEE bit patterns (`f64::to_bits`), so two
//! requests share a model only when the configuration is bit-identical.
//! Only *completed* computations are inserted — a canceled job can never
//! poison a cache — and concurrent misses on the same key both compute
//! (idempotent by the purity argument above; the last insert wins).

use crate::coordinator::flow::AnalyzedDesign;
use crate::coordinator::memo::StageMemo;
use crate::floorplan::cost::CostModel;
use crate::util::json::Json;
use std::sync::{Arc, Mutex, MutexGuard};

// The LRU substrate grew up here and was promoted to `util::lru` when the
// incremental re-flow engine needed it below the server layer; re-exported
// so existing daemon call sites keep compiling unchanged.
pub use crate::util::lru::{CacheStats, Lru};

use crate::testing::faults::{self, CacheFault};
use crate::util::lru::{fnv1a64, VerifiedLru};

/// Everything a memoized [`CostModel`] depends on: the analyzed design
/// (via its input digest), the device, and the two floats that shape the
/// floorplan problem and model (`util_limit`, `die_weight`), keyed by
/// bit pattern.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CostKey {
    pub digest: u64,
    pub device: String,
    pub util_bits: u64,
    pub die_weight_bits: u64,
}

impl CostKey {
    pub fn new(digest: u64, device: &str, util_limit: f64, die_weight: f64) -> Self {
        CostKey {
            digest,
            device: device.to_string(),
            util_bits: util_limit.to_bits(),
            die_weight_bits: die_weight.to_bits(),
        }
    }
}

/// The daemon's warm state: three independently locked LRUs. All methods
/// take `&self`; lock scope is a single get/put (never held across a
/// computation), so slow jobs don't serialize cache access.
#[derive(Debug)]
pub struct CacheSet {
    analyzed: Mutex<Lru<u64, Arc<AnalyzedDesign>>>,
    cost: Mutex<Lru<CostKey, Arc<CostModel>>>,
    /// Result payloads are the cache tier whose corruption would reach
    /// the wire verbatim, so entries carry an FNV digest of their dumped
    /// form, verified on every hit: a flipped payload degrades to a cold
    /// recompute plus a diagnostic, never a wrong answer.
    results: Mutex<VerifiedLru<u64, Json>>,
    /// Per-stage incremental caches (characterization, elaboration,
    /// placement, floorplan, delta STA) — the finer tier below the
    /// whole-request caches above: when a request digest misses (the
    /// design changed), the stage memo still reuses everything the edit
    /// didn't touch.
    stage: Arc<StageMemo>,
}

/// A panicking job must not wedge every later cache access: recover the
/// guard from a poisoned lock (the data is a cache — worst case we serve
/// a stale-but-pure entry, which by the key contract is still correct).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Integrity digest for a cached result payload: FNV over its canonical
/// dump (results are compared as bytes on the wire, so dump bytes are
/// exactly what must survive storage).
fn result_digest(v: &Json) -> u64 {
    fnv1a64(v.dump().as_bytes())
}

impl CacheSet {
    pub fn new(cap: usize) -> Self {
        CacheSet {
            analyzed: Mutex::new(Lru::new(cap)),
            cost: Mutex::new(Lru::new(cap)),
            results: Mutex::new(VerifiedLru::new(cap, result_digest)),
            stage: Arc::new(if cap == 0 {
                StageMemo::disabled()
            } else {
                StageMemo::new(cap)
            }),
        }
    }

    /// The shared per-stage memo, for threading into
    /// [`FlowWarm::stage`](crate::coordinator::flow::FlowWarm).
    pub fn stage(&self) -> Arc<StageMemo> {
        self.stage.clone()
    }

    /// The disabled cache set the one-shot lane (`rsir submit --local`,
    /// the differential oracle's reference side) runs with.
    pub fn disabled() -> Self {
        CacheSet::new(0)
    }

    pub fn analyzed(&self, digest: u64) -> Option<Arc<AnalyzedDesign>> {
        lock(&self.analyzed).get(&digest)
    }

    pub fn put_analyzed(&self, digest: u64, a: Arc<AnalyzedDesign>) {
        lock(&self.analyzed).put(digest, a);
    }

    pub fn cost(&self, key: &CostKey) -> Option<Arc<CostModel>> {
        lock(&self.cost).get(key)
    }

    pub fn put_cost(&self, key: CostKey, m: Arc<CostModel>) {
        lock(&self.cost).put(key, m);
    }

    pub fn result(&self, key: u64) -> Option<Json> {
        // Fault site: `Skip` models a lost read (treated as a miss —
        // recompute), `Corrupt` simulates reading back a flipped payload
        // (verification evicts it). Either way the caller recomputes the
        // same bytes.
        match faults::fire_cache("server.cache.get") {
            CacheFault::Skip => return None,
            CacheFault::Corrupt => return lock(&self.results).get(&key, true),
            CacheFault::None => {}
        }
        lock(&self.results).get(&key, false)
    }

    pub fn put_result(&self, key: u64, v: Json) {
        // Fault site: `Corrupt` stores a flipped digest (the next hit
        // detects it), `Skip` drops the insert (pure wall-time cost).
        match faults::fire_cache("server.cache.insert") {
            CacheFault::Skip => {}
            CacheFault::Corrupt => lock(&self.results).put(key, v, true),
            CacheFault::None => lock(&self.results).put(key, v, false),
        }
    }

    /// Total entries integrity verification has evicted across the
    /// verified tiers (results here, placements in the stage memo) — the
    /// corruption diagnostic `stats` reports.
    pub fn corruptions(&self) -> u64 {
        lock(&self.results).corrupt_dropped() + self.stage.corruptions()
    }

    /// Per-cache counter snapshots, in a stable order for the `stats`
    /// payload. The three whole-request caches come first (existing
    /// consumers index them); the per-stage memo's entries are appended.
    pub fn stats(&self) -> Vec<(&'static str, CacheStats)> {
        let mut out = vec![
            ("results", lock(&self.results).stats()),
            ("analyzed", lock(&self.analyzed).stats()),
            ("cost_models", lock(&self.cost).stats()),
        ];
        out.extend(self.stage.stats());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_key_distinguishes_bit_patterns() {
        let a = CostKey::new(1, "u250", 0.70, 3.0);
        let b = CostKey::new(1, "u250", 0.70 + 1e-16, 3.0);
        assert_eq!(a, CostKey::new(1, "u250", 0.70, 3.0));
        // 0.70 + 1e-16 rounds to the same f64; a genuinely different
        // float must differ.
        assert_eq!(a, b);
        assert_ne!(a, CostKey::new(1, "u250", 0.71, 3.0));
        assert_ne!(a, CostKey::new(1, "u280", 0.70, 3.0));
    }

    #[test]
    fn cache_set_round_trips_results() {
        let c = CacheSet::new(8);
        assert!(c.result(42).is_none());
        c.put_result(42, Json::str("hello"));
        assert_eq!(c.result(42), Some(Json::str("hello")));
        let stats = c.stats();
        assert_eq!(stats[0].0, "results");
        assert_eq!(stats[0].1.hits, 1);
        assert_eq!(stats[0].1.misses, 1);
    }
}

//! Warm cross-request state for the daemon: a deterministic LRU map and
//! the three caches `rsir serve` keeps across jobs.
//!
//! The cache-key design enforces the determinism contract structurally:
//! every cached value is a **pure function of its key**, so cache state
//! can change wall time but never a single result byte.
//!
//! | cache         | key                                                    | value |
//! |---------------|--------------------------------------------------------|-------|
//! | `analyzed`    | FNV-1a digest of the *input* IR                        | [`AnalyzedDesign`] (stage-1–2 snapshot) |
//! | `cost_models` | (digest, device, `util_limit` bits, `die_weight` bits) | [`CostModel`] |
//! | `results`     | FNV-1a of the canonical request JSON (type + params)   | canonical result payload |
//!
//! Floats enter keys as their IEEE bit patterns (`f64::to_bits`), so two
//! requests share a model only when the configuration is bit-identical.
//! Only *completed* computations are inserted — a canceled job can never
//! poison a cache — and concurrent misses on the same key both compute
//! (idempotent by the purity argument above; the last insert wins).

use crate::coordinator::flow::AnalyzedDesign;
use crate::floorplan::cost::CostModel;
use crate::util::json::{Json, JsonObj};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

/// A small deterministic LRU map: recency is a monotone tick, eviction
/// removes the smallest tick (an O(n) scan — caps are small and the scan
/// order over a `BTreeMap` is deterministic). `cap == 0` disables the
/// cache entirely (every `get` misses, `put` is a no-op) — that is what
/// the one-shot lane runs with.
#[derive(Debug)]
pub struct Lru<K: Ord + Clone, V> {
    cap: usize,
    map: BTreeMap<K, (u64, V)>,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl<K: Ord + Clone, V: Clone> Lru<K, V> {
    pub fn new(cap: usize) -> Self {
        Lru {
            cap,
            map: BTreeMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn get(&mut self, key: &K) -> Option<V> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some((t, v)) => {
                *t = self.tick;
                self.hits += 1;
                Some(v.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn put(&mut self, key: K, value: V) {
        if self.cap == 0 {
            return;
        }
        self.tick += 1;
        self.map.insert(key, (self.tick, value));
        if self.map.len() > self.cap {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone());
            if let Some(k) = oldest {
                self.map.remove(&k);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            len: self.map.len(),
            cap: self.cap,
        }
    }
}

/// Snapshot of one cache's counters, rendered by the `stats` request.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub len: usize,
    pub cap: usize,
}

impl CacheStats {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("hits", Json::num(self.hits as f64));
        o.insert("misses", Json::num(self.misses as f64));
        o.insert("len", Json::num(self.len as f64));
        o.insert("cap", Json::num(self.cap as f64));
        Json::Obj(o)
    }
}

/// Everything a memoized [`CostModel`] depends on: the analyzed design
/// (via its input digest), the device, and the two floats that shape the
/// floorplan problem and model (`util_limit`, `die_weight`), keyed by
/// bit pattern.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CostKey {
    pub digest: u64,
    pub device: String,
    pub util_bits: u64,
    pub die_weight_bits: u64,
}

impl CostKey {
    pub fn new(digest: u64, device: &str, util_limit: f64, die_weight: f64) -> Self {
        CostKey {
            digest,
            device: device.to_string(),
            util_bits: util_limit.to_bits(),
            die_weight_bits: die_weight.to_bits(),
        }
    }
}

/// The daemon's warm state: three independently locked LRUs. All methods
/// take `&self`; lock scope is a single get/put (never held across a
/// computation), so slow jobs don't serialize cache access.
#[derive(Debug)]
pub struct CacheSet {
    analyzed: Mutex<Lru<u64, Arc<AnalyzedDesign>>>,
    cost: Mutex<Lru<CostKey, Arc<CostModel>>>,
    results: Mutex<Lru<u64, Json>>,
}

/// A panicking job must not wedge every later cache access: recover the
/// guard from a poisoned lock (the data is a cache — worst case we serve
/// a stale-but-pure entry, which by the key contract is still correct).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl CacheSet {
    pub fn new(cap: usize) -> Self {
        CacheSet {
            analyzed: Mutex::new(Lru::new(cap)),
            cost: Mutex::new(Lru::new(cap)),
            results: Mutex::new(Lru::new(cap)),
        }
    }

    /// The disabled cache set the one-shot lane (`rsir submit --local`,
    /// the differential oracle's reference side) runs with.
    pub fn disabled() -> Self {
        CacheSet::new(0)
    }

    pub fn analyzed(&self, digest: u64) -> Option<Arc<AnalyzedDesign>> {
        lock(&self.analyzed).get(&digest)
    }

    pub fn put_analyzed(&self, digest: u64, a: Arc<AnalyzedDesign>) {
        lock(&self.analyzed).put(digest, a);
    }

    pub fn cost(&self, key: &CostKey) -> Option<Arc<CostModel>> {
        lock(&self.cost).get(key)
    }

    pub fn put_cost(&self, key: CostKey, m: Arc<CostModel>) {
        lock(&self.cost).put(key, m);
    }

    pub fn result(&self, key: u64) -> Option<Json> {
        lock(&self.results).get(&key)
    }

    pub fn put_result(&self, key: u64, v: Json) {
        lock(&self.results).put(key, v);
    }

    /// Per-cache counter snapshots, in a stable order for the `stats`
    /// payload.
    pub fn stats(&self) -> Vec<(&'static str, CacheStats)> {
        vec![
            ("results", lock(&self.results).stats()),
            ("analyzed", lock(&self.analyzed).stats()),
            ("cost_models", lock(&self.cost).stats()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut lru: Lru<u32, u32> = Lru::new(2);
        lru.put(1, 10);
        lru.put(2, 20);
        assert_eq!(lru.get(&1), Some(10)); // 1 is now most recent
        lru.put(3, 30); // evicts 2
        assert_eq!(lru.get(&2), None);
        assert_eq!(lru.get(&1), Some(10));
        assert_eq!(lru.get(&3), Some(30));
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn lru_counts_hits_and_misses() {
        let mut lru: Lru<u32, u32> = Lru::new(4);
        lru.put(1, 1);
        lru.get(&1);
        lru.get(&9);
        let s = lru.stats();
        assert_eq!((s.hits, s.misses, s.len, s.cap), (1, 1, 1, 4));
    }

    #[test]
    fn zero_cap_disables() {
        let mut lru: Lru<u32, u32> = Lru::new(0);
        lru.put(1, 1);
        assert_eq!(lru.get(&1), None);
        assert!(lru.is_empty());
    }

    #[test]
    fn cost_key_distinguishes_bit_patterns() {
        let a = CostKey::new(1, "u250", 0.70, 3.0);
        let b = CostKey::new(1, "u250", 0.70 + 1e-16, 3.0);
        assert_eq!(a, CostKey::new(1, "u250", 0.70, 3.0));
        // 0.70 + 1e-16 rounds to the same f64; a genuinely different
        // float must differ.
        assert_eq!(a, b);
        assert_ne!(a, CostKey::new(1, "u250", 0.71, 3.0));
        assert_ne!(a, CostKey::new(1, "u280", 0.70, 3.0));
    }

    #[test]
    fn cache_set_round_trips_results() {
        let c = CacheSet::new(8);
        assert!(c.result(42).is_none());
        c.put_result(42, Json::str("hello"));
        assert_eq!(c.result(42), Some(Json::str("hello")));
        let stats = c.stats();
        assert_eq!(stats[0].0, "results");
        assert_eq!(stats[0].1.hits, 1);
        assert_eq!(stats[0].1.misses, 1);
    }
}

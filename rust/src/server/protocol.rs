//! The daemon's wire protocol: line-delimited JSON over a unix socket or
//! TCP, framed on [`util::json`](crate::util::json).
//!
//! Requests are single-line JSON objects with at most four keys:
//!
//! ```text
//! {"id": "f1", "type": "flow", "params": {...}, "timeout_ms": 60000}
//! ```
//!
//! * `id` — string or number, echoed verbatim on the response. Optional
//!   for introspection requests, required for jobs (a job response would
//!   otherwise be unmatchable).
//! * `type` — `hello` | `stats` | `cancel` | `shutdown` (handled inline)
//!   or a job kind: `flow` | `pipeline` | `fuzz` | `explore` (queued).
//! * `params` — object; kind-specific, strictly validated (unknown keys
//!   are rejected so typos fail loudly instead of silently defaulting).
//! * `timeout_ms` — optional cooperative deadline for job requests.
//!
//! Every request line gets exactly one response line (blank lines are
//! skipped):
//!
//! ```text
//! {"id": "f1", "ok": true,  "result": {...}}
//! {"id": "f1", "ok": false, "error": {"code": "canceled", "message": "..."}}
//! ```
//!
//! Malformed input — bad JSON, a non-object, an oversized line — is
//! answered with a typed error envelope (`id` is `null` when it could
//! not be recovered) and never kills the connection, let alone the
//! daemon.

use crate::server::ops::JobRequest;
use crate::util::json::{Json, JsonObj};
use std::io::{self, ErrorKind, Read};

/// Protocol revision, reported in `hello`. Bump on breaking envelope
/// changes.
pub const PROTOCOL_VERSION: u64 = 1;

/// The crate version, reported in `hello` (and by `rsir version`) so
/// clients can detect server/CLI skew.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Default per-line byte cap. Inline designs ride inside request lines,
/// so the cap is generous; `ServeConfig::max_line` overrides it (tests
/// use tiny caps to exercise the oversize path).
pub const DEFAULT_MAX_LINE: usize = 16 * 1024 * 1024;

/// Typed error codes, stable wire strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// The line was not valid JSON (or not valid UTF-8).
    BadJson,
    /// Structurally valid JSON that violates the envelope or params
    /// schema.
    BadRequest,
    /// Unknown `type`.
    UnknownType,
    /// The line exceeded the server's byte cap.
    Oversized,
    /// `cancel` for a job this connection never submitted (or already
    /// completed).
    UnknownJob,
    /// A job id reused on the same connection.
    DuplicateJob,
    /// The job was canceled before completing.
    Canceled,
    /// The job's `timeout_ms` deadline passed before completion.
    Timeout,
    /// The bounded job queue rejected the submission.
    QueueFull,
    /// The job itself failed (deterministically — the message is part of
    /// the byte-identity contract).
    Internal,
    /// The job body panicked; the worker absorbed the unwind
    /// (`catch_unwind`) and the connection/queue kept draining. Distinct
    /// from `internal` so clients can tell a typed failure from a crash
    /// that was contained.
    InternalPanic,
}

impl ErrorCode {
    /// Every code, in wire order. The resilience oracle uses this to
    /// decide whether an error envelope is *typed* (vs. garbage).
    pub const ALL: [ErrorCode; 11] = [
        ErrorCode::BadJson,
        ErrorCode::BadRequest,
        ErrorCode::UnknownType,
        ErrorCode::Oversized,
        ErrorCode::UnknownJob,
        ErrorCode::DuplicateJob,
        ErrorCode::Canceled,
        ErrorCode::Timeout,
        ErrorCode::QueueFull,
        ErrorCode::Internal,
        ErrorCode::InternalPanic,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadJson => "bad-json",
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::UnknownType => "unknown-type",
            ErrorCode::Oversized => "oversized",
            ErrorCode::UnknownJob => "unknown-job",
            ErrorCode::DuplicateJob => "duplicate-job",
            ErrorCode::Canceled => "canceled",
            ErrorCode::Timeout => "timeout",
            ErrorCode::QueueFull => "queue-full",
            ErrorCode::Internal => "internal",
            ErrorCode::InternalPanic => "internal-panic",
        }
    }

    /// Parse a wire string back into a code (`None` for unknown strings).
    pub fn from_wire(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.into_iter().find(|c| c.as_str() == s)
    }
}

/// A request that failed validation before reaching the queue.
#[derive(Debug, Clone)]
pub struct ProtocolError {
    pub code: ErrorCode,
    pub message: String,
}

impl ProtocolError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        ProtocolError {
            code,
            message: message.into(),
        }
    }

    fn bad(message: impl Into<String>) -> Self {
        ProtocolError::new(ErrorCode::BadRequest, message)
    }
}

/// A parsed request.
#[derive(Debug)]
pub enum Request {
    Hello,
    Stats,
    Cancel { job: String },
    Shutdown,
    Job(JobRequest),
}

/// One parsed request line: the echoable id survives even when the
/// request itself failed validation, so errors stay attributable.
#[derive(Debug)]
pub struct Envelope {
    /// `Json::Null` when absent or unrecoverable.
    pub id: Json,
    /// Cooperative job deadline.
    pub timeout_ms: Option<u64>,
    pub request: Result<Request, ProtocolError>,
}

impl Envelope {
    fn err(id: Json, e: ProtocolError) -> Envelope {
        Envelope {
            id,
            timeout_ms: None,
            request: Err(e),
        }
    }
}

/// Parse one request line into an [`Envelope`]. Total: every input maps
/// to either a request or a typed error — nothing panics, nothing is
/// silently dropped.
pub fn parse_line(line: &str) -> Envelope {
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            return Envelope::err(
                Json::Null,
                ProtocolError::new(ErrorCode::BadJson, format!("invalid JSON: {e}")),
            )
        }
    };
    let Some(obj) = j.as_obj() else {
        return Envelope::err(
            Json::Null,
            ProtocolError::bad("request must be a JSON object"),
        );
    };
    let id = match obj.get("id") {
        None | Some(Json::Null) => Json::Null,
        Some(v @ (Json::Str(_) | Json::Num(_))) => v.clone(),
        Some(_) => {
            return Envelope::err(
                Json::Null,
                ProtocolError::bad("'id' must be a string or a number"),
            )
        }
    };
    for key in obj.keys() {
        if !matches!(key.as_str(), "id" | "type" | "params" | "timeout_ms") {
            return Envelope::err(
                id,
                ProtocolError::bad(format!("unknown envelope key '{key}'")),
            );
        }
    }
    let timeout_ms = match obj.get("timeout_ms") {
        None | Some(Json::Null) => None,
        Some(v) => match v.as_u64() {
            Some(ms) => Some(ms),
            None => {
                return Envelope::err(
                    id,
                    ProtocolError::bad("'timeout_ms' must be a non-negative integer"),
                )
            }
        },
    };
    let ty = match obj.get("type").map(|t| (t, t.as_str())) {
        Some((_, Some(t))) => t,
        Some((_, None)) => return Envelope::err(id, ProtocolError::bad("'type' must be a string")),
        None => return Envelope::err(id, ProtocolError::bad("missing 'type'")),
    };
    let empty = JsonObj::new();
    let params = match obj.get("params") {
        None | Some(Json::Null) => &empty,
        Some(p) => match p.as_obj() {
            Some(p) => p,
            None => return Envelope::err(id, ProtocolError::bad("'params' must be an object")),
        },
    };
    let request = match ty {
        "hello" => Ok(Request::Hello),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        "cancel" => match params.get("job").and_then(|j| j.as_str()) {
            Some(job) => Ok(Request::Cancel {
                job: job.to_string(),
            }),
            None => Err(ProtocolError::bad("cancel requires string 'params.job'")),
        },
        "flow" | "pipeline" | "fuzz" | "explore" => {
            JobRequest::parse(ty, params).map(Request::Job)
        }
        other => Err(ProtocolError::new(
            ErrorCode::UnknownType,
            format!("unknown request type '{other}'"),
        )),
    };
    Envelope {
        id,
        timeout_ms,
        request,
    }
}

/// Render a success response line (no trailing newline). `id` leads so
/// responses grep cleanly in CI logs.
pub fn ok_line(id: &Json, result: Json) -> String {
    let mut o = JsonObj::new();
    o.insert("id", id.clone());
    o.insert("ok", Json::Bool(true));
    o.insert("result", result);
    Json::Obj(o).dump()
}

/// Render an error response line (no trailing newline).
pub fn err_line(id: &Json, code: ErrorCode, message: &str) -> String {
    let mut e = JsonObj::new();
    e.insert("code", Json::str(code.as_str()));
    e.insert("message", Json::str(message));
    let mut o = JsonObj::new();
    o.insert("id", id.clone());
    o.insert("ok", Json::Bool(false));
    o.insert("error", Json::Obj(e));
    Json::Obj(o).dump()
}

/// The `hello` result payload: what a client needs to detect skew.
pub fn hello_result(workers: usize) -> Json {
    let mut o = JsonObj::new();
    o.insert("server", Json::str("rsir"));
    o.insert("version", Json::str(VERSION));
    o.insert("protocol", Json::num(PROTOCOL_VERSION as f64));
    o.insert("workers", Json::num(workers as f64));
    Json::Obj(o)
}

/// The `shutdown` acknowledgement payload.
pub fn shutdown_result() -> Json {
    let mut o = JsonObj::new();
    o.insert("shutting_down", Json::Bool(true));
    Json::Obj(o)
}

/// Canonical string form of a *job* id: the registry/cancel key. `None`
/// for anything but a string or number — job requests without a usable
/// id are rejected (their response would be unmatchable), and both the
/// daemon and the one-shot lane use this same predicate so the rejection
/// bytes agree.
pub fn job_id_string(id: &Json) -> Option<String> {
    match id {
        Json::Str(s) => Some(s.clone()),
        Json::Num(_) => Some(id.dump()),
        _ => None,
    }
}

/// One framing event from a [`LineReader`].
#[derive(Debug, PartialEq, Eq)]
pub enum LineEvent {
    /// A complete line (without the terminator).
    Line(String),
    /// The current line exceeded the byte cap; its remainder is being
    /// discarded up to the next newline. Reported once per long line.
    Oversized,
    /// No data available right now (read timed out / would block).
    Idle,
    /// Peer closed the connection. A trailing partial line (no newline
    /// before EOF) is dropped — half a request is not a request.
    Eof,
}

/// Incremental, byte-capped line framer over any [`Read`]. Handles
/// partial lines across reads, treats `WouldBlock`/`TimedOut` as
/// [`LineEvent::Idle`] (the daemon polls its shutdown flag between
/// reads), and recovers from oversized lines by discarding through the
/// next newline.
///
/// A reader built with [`with_site`](LineReader::with_site) is a fault
/// boundary: the injection plane can shorten its reads, delay them, or
/// fail them with an `io::Error` — and in every case already-buffered
/// bytes are preserved, so an injected transport error never loses data
/// that had arrived (the no-byte-loss property `tests/faults.rs`
/// verifies).
pub struct LineReader<R> {
    inner: R,
    buf: Vec<u8>,
    max: usize,
    discarding: bool,
    site: Option<&'static str>,
}

impl<R: Read> LineReader<R> {
    pub fn new(inner: R, max: usize) -> Self {
        LineReader {
            inner,
            buf: Vec::new(),
            max,
            discarding: false,
            site: None,
        }
    }

    /// A reader whose reads pass through the fault site `site`
    /// (`testing::faults`). Disarmed cost: one relaxed atomic load per
    /// `read` call.
    pub fn with_site(inner: R, max: usize, site: &'static str) -> Self {
        LineReader {
            site: Some(site),
            ..LineReader::new(inner, max)
        }
    }

    /// Advance the framer by at most one `read`.
    pub fn poll_line(&mut self) -> io::Result<LineEvent> {
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let mut line: Vec<u8> = self.buf.drain(..=pos).collect();
                line.pop(); // the newline itself
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return Ok(LineEvent::Line(
                    String::from_utf8_lossy(&line).into_owned(),
                ));
            }
            if !self.discarding && self.buf.len() > self.max {
                self.buf.clear();
                self.discarding = true;
                return Ok(LineEvent::Oversized);
            }
            let mut chunk = [0u8; 4096];
            let mut cap = chunk.len();
            if let Some(site) = self.site {
                // Injected errors return *before* the read: `buf` is
                // untouched, so no received byte is lost.
                if crate::testing::faults::fire_io(site)? {
                    cap = 1; // injected short read
                }
            }
            match self.inner.read(&mut chunk[..cap]) {
                Ok(0) => return Ok(LineEvent::Eof),
                Ok(n) => {
                    let mut data = &chunk[..n];
                    if self.discarding {
                        // Drop bytes up to and including the newline that
                        // ends the oversized line, then resume framing.
                        match data.iter().position(|&b| b == b'\n') {
                            Some(p) => {
                                data = &data[p + 1..];
                                self.discarding = false;
                            }
                            None => continue,
                        }
                    }
                    self.buf.extend_from_slice(data);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
                {
                    return Ok(LineEvent::Idle)
                }
                Err(e) => return Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ok(line: &str) -> Request {
        parse_line(line).request.expect("expected valid request")
    }

    fn parse_err(line: &str) -> ProtocolError {
        parse_line(line).request.expect_err("expected error")
    }

    #[test]
    fn parses_introspection_requests() {
        assert!(matches!(parse_ok(r#"{"type":"hello"}"#), Request::Hello));
        assert!(matches!(
            parse_ok(r#"{"id":7,"type":"stats"}"#),
            Request::Stats
        ));
        assert!(matches!(
            parse_ok(r#"{"id":"x","type":"shutdown"}"#),
            Request::Shutdown
        ));
        let Request::Cancel { job } =
            parse_ok(r#"{"id":"c1","type":"cancel","params":{"job":"f1"}}"#)
        else {
            panic!("expected cancel")
        };
        assert_eq!(job, "f1");
    }

    #[test]
    fn id_is_echoed_even_on_errors() {
        let env = parse_line(r#"{"id":"e1","type":"nope"}"#);
        assert_eq!(env.id, Json::str("e1"));
        assert_eq!(env.request.unwrap_err().code, ErrorCode::UnknownType);
        let env = parse_line(r#"{"id":42,"type":"stats"}"#);
        assert_eq!(env.id, Json::Num(42.0));
    }

    #[test]
    fn malformed_inputs_get_typed_errors() {
        assert_eq!(parse_err("not json at all").code, ErrorCode::BadJson);
        assert_eq!(parse_err("[1,2,3]").code, ErrorCode::BadRequest);
        assert_eq!(parse_err(r#"{"type":7}"#).code, ErrorCode::BadRequest);
        assert_eq!(parse_err(r#"{"id":"x"}"#).code, ErrorCode::BadRequest);
        assert_eq!(
            parse_err(r#"{"type":"hello","surprise":1}"#).code,
            ErrorCode::BadRequest
        );
        assert_eq!(
            parse_err(r#"{"id":[1],"type":"hello"}"#).code,
            ErrorCode::BadRequest
        );
        assert_eq!(
            parse_err(r#"{"type":"cancel","params":{}}"#).code,
            ErrorCode::BadRequest
        );
        assert_eq!(
            parse_err(r#"{"type":"hello","timeout_ms":-5}"#).code,
            ErrorCode::BadRequest
        );
    }

    #[test]
    fn response_lines_are_stable() {
        assert_eq!(
            ok_line(&Json::str("a"), Json::Bool(true)),
            r#"{"id":"a","ok":true,"result":true}"#
        );
        assert_eq!(
            err_line(&Json::Null, ErrorCode::Oversized, "too big"),
            r#"{"id":null,"ok":false,"error":{"code":"oversized","message":"too big"}}"#
        );
    }

    #[test]
    fn hello_reports_version_and_protocol() {
        let h = hello_result(3);
        let o = h.as_obj().unwrap();
        assert_eq!(o.get("version").unwrap().as_str(), Some(VERSION));
        assert_eq!(o.get("protocol").unwrap().as_u64(), Some(PROTOCOL_VERSION));
        assert_eq!(o.get("workers").unwrap().as_u64(), Some(3));
    }

    /// A `Read` that feeds predefined chunks, then `WouldBlock`, then EOF.
    struct Feed {
        chunks: Vec<Vec<u8>>,
        blocks: usize,
    }

    impl Read for Feed {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if let Some(c) = self.chunks.first() {
                let n = c.len().min(buf.len());
                buf[..n].copy_from_slice(&c[..n]);
                if n == c.len() {
                    self.chunks.remove(0);
                } else {
                    self.chunks[0] = c[n..].to_vec();
                }
                return Ok(n);
            }
            if self.blocks > 0 {
                self.blocks -= 1;
                return Err(io::Error::new(ErrorKind::WouldBlock, "would block"));
            }
            Ok(0)
        }
    }

    #[test]
    fn linereader_reassembles_partial_lines() {
        let feed = Feed {
            chunks: vec![b"{\"a\":".to_vec(), b"1}\n{\"b\":2}\n".to_vec()],
            blocks: 1,
        };
        let mut r = LineReader::new(feed, 1024);
        assert_eq!(
            r.poll_line().unwrap(),
            LineEvent::Line("{\"a\":1}".to_string())
        );
        assert_eq!(
            r.poll_line().unwrap(),
            LineEvent::Line("{\"b\":2}".to_string())
        );
        assert_eq!(r.poll_line().unwrap(), LineEvent::Idle);
        assert_eq!(r.poll_line().unwrap(), LineEvent::Eof);
    }

    #[test]
    fn linereader_reports_oversize_once_and_recovers() {
        let mut long = vec![b'x'; 64];
        long.extend_from_slice(b" tail\nok\n");
        let feed = Feed {
            chunks: vec![long],
            blocks: 0,
        };
        let mut r = LineReader::new(feed, 16);
        assert_eq!(r.poll_line().unwrap(), LineEvent::Oversized);
        assert_eq!(r.poll_line().unwrap(), LineEvent::Line("ok".to_string()));
        assert_eq!(r.poll_line().unwrap(), LineEvent::Eof);
    }

    #[test]
    fn linereader_drops_partial_line_at_eof() {
        let feed = Feed {
            chunks: vec![b"complete\nhalf".to_vec()],
            blocks: 0,
        };
        let mut r = LineReader::new(feed, 1024);
        assert_eq!(
            r.poll_line().unwrap(),
            LineEvent::Line("complete".to_string())
        );
        assert_eq!(r.poll_line().unwrap(), LineEvent::Eof);
    }

    #[test]
    fn linereader_strips_carriage_return() {
        let feed = Feed {
            chunks: vec![b"{\"x\":1}\r\n".to_vec()],
            blocks: 0,
        };
        let mut r = LineReader::new(feed, 1024);
        assert_eq!(
            r.poll_line().unwrap(),
            LineEvent::Line("{\"x\":1}".to_string())
        );
    }
}

//! `rsir serve` — a resident HLPS compilation daemon (§5 "infrastructure
//! for high-level physical synthesis" as a service).
//!
//! One process keeps the expensive cross-request state warm — analyzed
//! design snapshots, memoized cost models, canonical result payloads
//! (see [`cache`]) — while a bounded deterministic job queue ([`jobs`])
//! multiplexes flow/pipeline/fuzz/explore jobs ([`ops`]) onto a
//! [`util::pool`](crate::util::pool) worker set. Clients speak
//! line-delimited JSON ([`protocol`]) over a unix socket or local TCP.
//!
//! The non-negotiable invariant: **every byte a daemon returns is
//! identical to the one-shot CLI's** ([`client::run_batch_local`]).
//! Warm caches change wall time, never results — enforced structurally
//! (every cache value is a pure function of its key) and checked by the
//! fuzzed differential oracle
//! ([`testing::oracle::check_daemon_equivalence`](crate::testing::oracle::check_daemon_equivalence)).

pub mod cache;
pub mod client;
pub mod jobs;
pub mod ops;
pub mod protocol;

use crate::server::cache::CacheSet;
use crate::server::jobs::{CancelToken, Job, JobQueue};
use crate::server::protocol::{
    err_line, hello_result, job_id_string, ok_line, parse_line, shutdown_result, ErrorCode,
    LineEvent, LineReader, Request, DEFAULT_MAX_LINE, PROTOCOL_VERSION, VERSION,
};
use crate::testing::faults::{self, FaultAction};
use crate::util::json::{Json, JsonObj};
use crate::util::pool::Pool;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::io::{self, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Where the daemon listens (and where clients connect).
#[derive(Debug, Clone)]
pub enum Bind {
    /// A unix-domain socket path (stale files are replaced on bind).
    Unix(PathBuf),
    /// Loopback TCP; port 0 picks a free port (see [`Server::port`]).
    Tcp(u16),
}

impl fmt::Display for Bind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Bind::Unix(p) => write!(f, "unix:{}", p.display()),
            Bind::Tcp(port) => write!(f, "tcp:127.0.0.1:{port}"),
        }
    }
}

/// Daemon configuration, defaulted by [`ServeConfig::new`] and
/// overridden from the CLI.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub bind: Bind,
    /// Job-queue worker count (also reported in `hello`).
    pub workers: usize,
    /// Capacity of each warm cache (0 disables warm state entirely).
    pub cache_cap: usize,
    /// Bound on queued (not yet running) jobs.
    pub max_queue: usize,
    /// Per-request-line byte cap.
    pub max_line: usize,
    /// Suppress the startup banner (tests, CI).
    pub quiet: bool,
}

impl ServeConfig {
    pub fn new(bind: Bind) -> Self {
        ServeConfig {
            bind,
            workers: 2,
            cache_cap: 64,
            max_queue: 256,
            max_line: DEFAULT_MAX_LINE,
            quiet: false,
        }
    }
}

/// A connected client stream, unix or TCP.
#[derive(Debug)]
pub enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    pub fn try_clone(&self) -> io::Result<Stream> {
        match self {
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
        }
    }

    pub fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_read_timeout(d),
            Stream::Tcp(s) => s.set_read_timeout(d),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

/// Connect to a daemon at `bind` (for `Tcp`, the *actual* port — pass
/// [`Server::port`]'s value when the server bound port 0).
pub fn connect(bind: &Bind) -> io::Result<Stream> {
    match bind {
        Bind::Unix(p) => UnixStream::connect(p).map(Stream::Unix),
        Bind::Tcp(port) => TcpStream::connect(("127.0.0.1", *port)).map(Stream::Tcp),
    }
}

enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Listener::Unix(l) => l.set_nonblocking(nb),
            Listener::Tcp(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> io::Result<Stream> {
        let stream = match self {
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s))?,
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s))?,
        };
        // The accept loop is nonblocking; accepted connections must not be.
        match &stream {
            Stream::Unix(s) => s.set_nonblocking(false)?,
            Stream::Tcp(s) => s.set_nonblocking(false)?,
        }
        Ok(stream)
    }
}

/// A unique scratch socket path for tests and benches (pid + counter —
/// collision-free within and across concurrent test processes).
pub fn scratch_socket(tag: &str) -> PathBuf {
    static COUNTER: AtomicUsize = AtomicUsize::new(0);
    let n = COUNTER.fetch_add(1, Ordering::SeqCst);
    std::env::temp_dir().join(format!("rsir-{tag}-{}-{n}.sock", std::process::id()))
}

/// Lifetime job counters plus a short ring of recent per-job wall times,
/// rendered by the `stats` request. Wall times are observational —
/// `stats` is introspection, not a job, so it is exempt from the
/// canonical-payload rule.
#[derive(Default)]
struct ServerStats {
    enqueued: AtomicU64,
    completed: AtomicU64,
    canceled: AtomicU64,
    failed: AtomicU64,
    recent: Mutex<VecDeque<(String, u64)>>,
}

impl ServerStats {
    fn record(&self, id: &str, wall: Duration, code: Option<ErrorCode>) {
        match code {
            None => &self.completed,
            Some(ErrorCode::Canceled) | Some(ErrorCode::Timeout) => &self.canceled,
            Some(_) => &self.failed,
        }
        .fetch_add(1, Ordering::SeqCst);
        let mut recent = self.recent.lock().unwrap_or_else(|p| p.into_inner());
        recent.push_back((id.to_string(), wall.as_millis() as u64));
        while recent.len() > 32 {
            recent.pop_front();
        }
    }
}

/// Everything the worker pool and every connection share.
struct Shared {
    queue: JobQueue,
    caches: CacheSet,
    stats: ServerStats,
    shutdown: AtomicBool,
    workers: usize,
    max_line: usize,
}

fn stats_payload(shared: &Shared) -> Json {
    let mut jobs = JsonObj::new();
    jobs.insert(
        "enqueued",
        Json::num(shared.stats.enqueued.load(Ordering::SeqCst) as f64),
    );
    jobs.insert(
        "completed",
        Json::num(shared.stats.completed.load(Ordering::SeqCst) as f64),
    );
    jobs.insert(
        "canceled",
        Json::num(shared.stats.canceled.load(Ordering::SeqCst) as f64),
    );
    jobs.insert(
        "failed",
        Json::num(shared.stats.failed.load(Ordering::SeqCst) as f64),
    );
    let mut caches = JsonObj::new();
    for (name, s) in shared.caches.stats() {
        caches.insert(name, s.to_json());
    }
    let recent: Vec<Json> = {
        let r = shared.stats.recent.lock().unwrap_or_else(|p| p.into_inner());
        r.iter()
            .map(|(id, ms)| {
                let mut o = JsonObj::new();
                o.insert("id", Json::str(id));
                o.insert("wall_ms", Json::num(*ms as f64));
                Json::Obj(o)
            })
            .collect()
    };
    let mut o = JsonObj::new();
    o.insert("version", Json::str(VERSION));
    o.insert("protocol", Json::num(PROTOCOL_VERSION as f64));
    o.insert("workers", Json::num(shared.workers as f64));
    o.insert("queue_depth", Json::num(shared.queue.depth() as f64));
    o.insert("running", Json::num(shared.queue.running() as f64));
    o.insert("jobs", Json::Obj(jobs));
    o.insert("caches", Json::Obj(caches));
    // Entries integrity verification evicted from the digest-verified
    // cache tiers (results, placements). Nonzero means a corruption was
    // detected *and contained* — degraded to a cold recompute.
    o.insert(
        "corruptions",
        Json::num(shared.caches.corruptions() as f64),
    );
    o.insert("recent_jobs", Json::Arr(recent));
    Json::Obj(o)
}

/// One queue worker: pop, execute against the warm caches, mark done,
/// deliver. Runs until the queue is closed and drained.
fn worker_loop(shared: &Shared) {
    while let Some(job) = shared.queue.pop() {
        let t = Instant::now();
        // The panic barrier lives in `execute_caught`: a panicking job
        // answers a typed `internal-panic` envelope and the worker keeps
        // serving. (An uncaught panic here would unwind through the
        // pool's panic transparency onto the server thread itself.)
        let (line, code) = match ops::execute_caught(&job.request, &shared.caches, &job.token) {
            Ok(result) => (ok_line(&job.raw_id, result), None),
            Err(e) => (err_line(&job.raw_id, e.code, &e.message), Some(e.code)),
        };
        // Order matters: once `done` is set, a cancel for this id answers
        // `unknown-job` — so set it only after the result line is final.
        job.done.store(true, Ordering::SeqCst);
        shared.stats.record(&job.id, t.elapsed(), code);
        let _ = job.respond.send(line);
        shared.queue.finished();
    }
}

/// Drain response lines to the client. On a write failure (client went
/// away) it keeps draining without writing, so in-flight jobs for a dead
/// connection can still complete and drop their senders. The `dead` flag
/// is shared with the reader loop: once the write half is gone the
/// reader closes the connection too, so a retrying client reconnects
/// promptly instead of waiting out its deadline.
fn writer_loop(stream: Stream, rx: Receiver<String>, dead: &AtomicBool) {
    let mut w = BufWriter::new(stream);
    while let Ok(line) = rx.recv() {
        if dead.load(Ordering::SeqCst) {
            continue;
        }
        // Fault site `server.io.write`: `Delay` stalls before the write,
        // `ShortIo` splits it across two flushes (the reader must
        // reassemble), and every other action — including Panic —
        // degrades to a dead connection. A real panic on this thread
        // would only surface when the scope joins, stalling the client
        // until its deadline; killing the connection instead models the
        // same loss while keeping the failure promptly observable.
        let mut split = false;
        match faults::point("server.io.write") {
            None => {}
            Some(FaultAction::Delay) => faults::injected_sleep(),
            Some(FaultAction::ShortIo) => split = true,
            Some(_) => {
                dead.store(true, Ordering::SeqCst);
                continue;
            }
        }
        let wrote = if split && line.len() > 1 {
            let (a, b) = line.as_bytes().split_at(line.len() / 2);
            w.write_all(a)
                .and_then(|_| w.flush())
                .and_then(|_| w.write_all(b))
                .and_then(|_| w.write_all(b"\n"))
                .and_then(|_| w.flush())
        } else {
            w.write_all(line.as_bytes())
                .and_then(|_| w.write_all(b"\n"))
                .and_then(|_| w.flush())
        };
        if wrote.is_err() {
            dead.store(true, Ordering::SeqCst);
        }
    }
}

/// What a dispatched request asks the connection loop to do next.
enum Flow {
    Continue,
    /// A `shutdown` was acknowledged: stop reading from this connection.
    Stop,
}

/// Handle one parsed request line. `registry` holds this connection's
/// jobs (cancel scope is per-connection, like the ids themselves).
fn dispatch_line(
    line: &str,
    shared: &Shared,
    tx: &Sender<String>,
    registry: &mut BTreeMap<String, (CancelToken, Arc<AtomicBool>)>,
) -> Flow {
    let env = parse_line(line);
    let resp = match env.request {
        Err(e) => err_line(&env.id, e.code, &e.message),
        Ok(Request::Hello) => ok_line(&env.id, hello_result(shared.workers)),
        Ok(Request::Stats) => ok_line(&env.id, stats_payload(shared)),
        Ok(Request::Shutdown) => {
            let _ = tx.send(ok_line(&env.id, shutdown_result()));
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.queue.close();
            return Flow::Stop;
        }
        Ok(Request::Cancel { job }) => match registry.get(&job) {
            Some((token, done)) if !done.load(Ordering::SeqCst) => {
                token.cancel();
                let mut o = JsonObj::new();
                o.insert("canceled", Json::str(&job));
                ok_line(&env.id, Json::Obj(o))
            }
            Some(_) => err_line(
                &env.id,
                ErrorCode::UnknownJob,
                &format!("job '{job}' already completed"),
            ),
            None => err_line(
                &env.id,
                ErrorCode::UnknownJob,
                &format!("no such job '{job}'"),
            ),
        },
        Ok(Request::Job(req)) => {
            let Some(id) = job_id_string(&env.id) else {
                let _ = tx.send(err_line(
                    &env.id,
                    ErrorCode::BadRequest,
                    "job requests require a string or numeric id",
                ));
                return Flow::Continue;
            };
            if registry.contains_key(&id) {
                let _ = tx.send(err_line(
                    &env.id,
                    ErrorCode::DuplicateJob,
                    &format!("job id '{id}' already used on this connection"),
                ));
                return Flow::Continue;
            }
            let deadline = env
                .timeout_ms
                .map(|ms| Instant::now() + Duration::from_millis(ms));
            let token = CancelToken::new(deadline);
            let done = Arc::new(AtomicBool::new(false));
            let job = Job {
                id: id.clone(),
                raw_id: env.id.clone(),
                request: req,
                token: token.clone(),
                done: done.clone(),
                respond: tx.clone(),
            };
            match shared.queue.push(job) {
                Ok(()) => {
                    shared.stats.enqueued.fetch_add(1, Ordering::SeqCst);
                    registry.insert(id, (token, done));
                    return Flow::Continue; // response comes from the worker
                }
                Err(_) => err_line(&env.id, ErrorCode::QueueFull, "job queue is full"),
            }
        }
    };
    let _ = tx.send(resp);
    Flow::Continue
}

/// Serve one client connection: a reader loop dispatching lines and a
/// writer thread draining the response channel (workers send into it
/// concurrently, so job responses interleave with inline ones).
fn handle_conn(stream: Stream, shared: &Shared) {
    // Short read timeouts let the reader poll the shutdown flag.
    if stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .is_err()
    {
        return;
    }
    let Ok(write_half) = stream.try_clone() else {
        return;
    };
    let (tx, rx) = mpsc::channel::<String>();
    let writer_dead = AtomicBool::new(false);
    let writer_dead = &writer_dead;
    thread::scope(|s| {
        s.spawn(move || writer_loop(write_half, rx, writer_dead));
        let mut reader = LineReader::with_site(stream, shared.max_line, "server.io.read");
        let mut registry: BTreeMap<String, (CancelToken, Arc<AtomicBool>)> = BTreeMap::new();
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            // The write half died (client gone, or injected): close the
            // read half too so the client's retry loop reconnects.
            if writer_dead.load(Ordering::SeqCst) {
                break;
            }
            match reader.poll_line() {
                Ok(LineEvent::Line(line)) => {
                    if line.trim().is_empty() {
                        continue;
                    }
                    match dispatch_line(&line, shared, &tx, &mut registry) {
                        Flow::Continue => {}
                        Flow::Stop => break,
                    }
                }
                Ok(LineEvent::Oversized) => {
                    let _ = tx.send(err_line(
                        &Json::Null,
                        ErrorCode::Oversized,
                        &format!("request line exceeds {} bytes", shared.max_line),
                    ));
                }
                Ok(LineEvent::Idle) => continue,
                Ok(LineEvent::Eof) | Err(_) => break,
            }
        }
        // A vanished client abandons its jobs: cancel whatever is still
        // in flight so workers free up (responses drain to the dead
        // writer harmlessly).
        for (token, done) in registry.values() {
            if !done.load(Ordering::SeqCst) {
                token.cancel();
            }
        }
        drop(tx); // writer exits once in-flight jobs drop their senders too
    });
}

/// A bound, not-yet-running daemon. Splitting bind from run lets tests
/// and the bench learn the actual port/socket before spawning `run` on
/// its own thread.
pub struct Server {
    listener: Listener,
    cfg: ServeConfig,
}

impl Server {
    pub fn bind(mut cfg: ServeConfig) -> Result<Server> {
        cfg.workers = cfg.workers.max(1);
        let listener = match &cfg.bind {
            Bind::Unix(path) => {
                // A stale socket file from a dead daemon would fail the
                // bind; a *live* daemon's file is replaced too — callers
                // own their socket paths.
                if path.exists() {
                    let _ = std::fs::remove_file(path);
                }
                Listener::Unix(
                    UnixListener::bind(path)
                        .with_context(|| format!("binding unix socket {}", path.display()))?,
                )
            }
            Bind::Tcp(port) => {
                let l = TcpListener::bind(("127.0.0.1", *port))
                    .with_context(|| format!("binding 127.0.0.1:{port}"))?;
                // Record the real port when 0 was requested.
                if *port == 0 {
                    let actual = l.local_addr()?.port();
                    cfg.bind = Bind::Tcp(actual);
                }
                Listener::Tcp(l)
            }
        };
        Ok(Server { listener, cfg })
    }

    /// Where this server actually listens (real port for `Tcp(0)`).
    pub fn endpoint(&self) -> Bind {
        self.cfg.bind.clone()
    }

    /// The actual TCP port, when TCP-bound.
    pub fn port(&self) -> Option<u16> {
        match self.cfg.bind {
            Bind::Tcp(p) => Some(p),
            Bind::Unix(_) => None,
        }
    }

    /// Run until a `shutdown` request: accept connections, spawn one
    /// handler per connection, multiplex jobs onto the worker pool.
    /// Returns after all workers and connections have wound down.
    pub fn run(self) -> Result<()> {
        let cfg = &self.cfg;
        if !cfg.quiet {
            eprintln!(
                "rsir serve v{VERSION} (protocol {PROTOCOL_VERSION}) listening on {} — {} worker(s), cache cap {}",
                cfg.bind, cfg.workers, cfg.cache_cap
            );
        }
        let shared = Shared {
            queue: JobQueue::new(cfg.max_queue),
            caches: CacheSet::new(cfg.cache_cap),
            stats: ServerStats::default(),
            shutdown: AtomicBool::new(false),
            workers: cfg.workers,
            max_line: cfg.max_line,
        };
        let shared = &shared;
        self.listener
            .set_nonblocking(true)
            .context("nonblocking accept loop")?;
        thread::scope(|s| {
            s.spawn(move || {
                let pool = Pool::new(shared.workers);
                let loops: Vec<_> = (0..shared.workers)
                    .map(|_| move || worker_loop(shared))
                    .collect();
                pool.run(loops);
            });
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                match self.listener.accept() {
                    Ok(stream) => {
                        // Per-connection panic barrier: an unwinding
                        // handler (injected via `server.queue.push`
                        // Panic, or a real bug) takes down its own
                        // connection, never the accept loop. Without it
                        // the scope would re-raise at join and kill the
                        // daemon.
                        s.spawn(move || {
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || handle_conn(stream, shared),
                            ));
                        });
                    }
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock
                            || e.kind() == io::ErrorKind::TimedOut =>
                    {
                        thread::sleep(Duration::from_millis(25));
                    }
                    Err(_) => thread::sleep(Duration::from_millis(25)),
                }
            }
            // Belt and braces: shutdown sets this in dispatch, but close
            // here too in case the loop exits another way.
            shared.queue.close();
        });
        if let Bind::Unix(path) = &cfg.bind {
            let _ = std::fs::remove_file(path);
        }
        Ok(())
    }
}

/// Bind and run a daemon with `cfg` (the `rsir serve` entry point).
pub fn serve(cfg: ServeConfig) -> Result<()> {
    Server::bind(cfg)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::client::{run_batch_local, run_batch_remote};

    fn batch(lines: &[&str]) -> Vec<String> {
        lines.iter().map(|s| s.to_string()).collect()
    }

    /// Boot a real daemon on a scratch unix socket, run a mixed batch
    /// remotely and locally, and require byte-identical responses.
    #[test]
    fn daemon_matches_one_shot_lane() {
        let path = scratch_socket("unit");
        let mut cfg = ServeConfig::new(Bind::Unix(path.clone()));
        cfg.workers = 2;
        cfg.quiet = true;
        let server = Server::bind(cfg).unwrap();
        let endpoint = server.endpoint();
        let handle = thread::spawn(move || server.run());

        let lines = batch(&[
            r#"{"id":"p1","type":"pipeline","params":{"bench":"cnn:2x2"}}"#,
            r#"{"id":"f1","type":"flow","params":{"bench":"cnn:2x2","device":"u250","sa_refine":false}}"#,
            r#"{"id":"bad","type":"wat"}"#,
        ]);
        let remote =
            run_batch_remote(&endpoint, &lines, Duration::from_secs(60)).unwrap();
        let local = run_batch_local(&lines);
        assert_eq!(remote, local);

        let shutdown = batch(&[r#"{"id":"q","type":"shutdown"}"#]);
        let ack = run_batch_remote(&endpoint, &shutdown, Duration::from_secs(10)).unwrap();
        assert!(ack[0].contains("shutting_down"));
        handle.join().unwrap().unwrap();
        assert!(!path.exists(), "socket file not cleaned up");
    }

    /// TCP on port 0: the server reports its real port and serves there.
    #[test]
    fn tcp_port_zero_binds_and_serves() {
        let mut cfg = ServeConfig::new(Bind::Tcp(0));
        cfg.quiet = true;
        let server = Server::bind(cfg).unwrap();
        let port = server.port().unwrap();
        assert_ne!(port, 0);
        let endpoint = server.endpoint();
        let handle = thread::spawn(move || server.run());
        let out = run_batch_remote(
            &endpoint,
            &batch(&[r#"{"id":"h","type":"hello"}"#, r#"{"type":"shutdown"}"#]),
            Duration::from_secs(10),
        )
        .unwrap();
        assert!(out[0].contains(&format!("\"workers\":{}", 2)));
        handle.join().unwrap().unwrap();
    }
}

//! The deterministic, seeded fault-injection plane.
//!
//! Production code declares named fault *sites* at its fallible
//! boundaries — [`point`]`("server.io.read")`, `"server.queue.push"`,
//! `"memo.place.insert"`, … — and a test arms a [`FaultPlan`] against
//! them. Each armed [`FaultArm`] triggers deterministically on the
//! (site, hit-count) pair: the n-th time execution reaches the site, the
//! arm fires **exactly once** and injects its [`FaultAction`] (a typed
//! error, a panic, a short read/write, an artificial delay, or a
//! bit-flipped cache payload). Because every arm is one-shot, the total
//! number of injected events is bounded by the plan size, which is what
//! lets the retrying client and the [`check_fault_resilience`] oracle
//! converge.
//!
//! **Disarmed cost:** when no plan is armed (every production run), a
//! fault site costs exactly one relaxed atomic load — see [`point`].
//!
//! **Determinism:** arming, triggering and the injected payloads use no
//! wall clock and no ambient randomness. Within a single-threaded
//! scenario the hit counters are fully deterministic; under daemon
//! concurrency the k-th hit of a site is whichever thread arrives k-th,
//! which the resilience oracle's invariant is deliberately agnostic to
//! (any interleaving must still produce typed-error-or-identical-bytes).
//!
//! **Scoping:** the plane is process-global (fault sites live on hot
//! paths shared by every thread, including daemon workers), so
//! [`arm`] serializes scenarios behind a global lock and the returned
//! [`FaultGuard`] disarms on drop. Tests that arm real production sites
//! belong in the dedicated `tests/faults.rs` integration binary (its own
//! process); in-crate unit tests must only arm reserved `test.*` site
//! names, which no production path ever queries.
//!
//! [`check_fault_resilience`]: crate::testing::oracle::check_fault_resilience

use crate::util::json::{Json, JsonObj};
use crate::util::quickcheck::Gen;
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// How long an injected [`FaultAction::Delay`] sleeps. Long enough for a
/// cancellation to land mid-delay (the cancellation-under-fault tests
/// depend on that window), short enough for 64-case tier-1 lanes.
pub const INJECTED_DELAY_MS: u64 = 120;

/// What an armed site injects when it fires.
///
/// Not every action is meaningful at every site; sites degrade
/// inapplicable actions to their closest supported one (documented per
/// call site, summarized in the ARCHITECTURE.md site table). E.g. an IO
/// site treats `BitFlip` as `Error`; the pool's scheduling site treats
/// everything as `Delay`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Return the site's typed error (an `io::Error`, a queue rejection,
    /// an injected flow-stage failure, …).
    Error,
    /// Panic at the site (must be absorbed by a `catch_unwind` layer —
    /// the daemon's per-job isolation or per-connection barrier).
    Panic,
    /// Sleep [`INJECTED_DELAY_MS`] and then proceed normally.
    Delay,
    /// Truncate the current read/write to one byte (IO sites only).
    ShortIo,
    /// Corrupt a cached payload's integrity digest so verification fails
    /// on the next hit (cache/memo sites only).
    BitFlip,
}

impl FaultAction {
    pub const ALL: [FaultAction; 5] = [
        FaultAction::Error,
        FaultAction::Panic,
        FaultAction::Delay,
        FaultAction::ShortIo,
        FaultAction::BitFlip,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FaultAction::Error => "error",
            FaultAction::Panic => "panic",
            FaultAction::Delay => "delay",
            FaultAction::ShortIo => "short-io",
            FaultAction::BitFlip => "bit-flip",
        }
    }
}

/// Every production fault site the fuzzer arms. (Tests may additionally
/// arm ad-hoc `test.*` names; [`point`] accepts any site string.)
pub const SITES: &[&str] = &[
    "server.io.read",     // daemon connection reader (LineReader)
    "server.io.write",    // daemon response writer
    "server.queue.push",  // job-queue admission
    "server.cache.get",   // CacheSet result lookup
    "server.cache.insert",// CacheSet result insertion
    "memo.place.insert",  // StageMemo placement insertion
    "pool.job",           // a job body executing on a pool worker
    "pool.worker",        // pool scheduling skew (delay-only)
    "client.io.read",     // client-side response reader
    "flow.stage.start",
    "flow.stage.analysis",
    "flow.stage.baseline",
    "flow.stage.floorplan",
    "flow.stage.pipeline",
];

/// One armed injection: the `hit`-th arrival at `site` fires `action`,
/// exactly once.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultArm {
    pub site: String,
    pub hit: u64,
    pub action: FaultAction,
}

impl FaultArm {
    pub fn new(site: &str, hit: u64, action: FaultAction) -> FaultArm {
        FaultArm {
            site: site.to_string(),
            hit: hit.max(1),
            action,
        }
    }
}

/// A seeded, shrinkable set of armed faults — the fault-plane analogue
/// of `DesignPlan`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultPlan {
    pub arms: Vec<FaultArm>,
}

impl FaultPlan {
    /// A plan arming a single site.
    pub fn one(site: &str, hit: u64, action: FaultAction) -> FaultPlan {
        FaultPlan {
            arms: vec![FaultArm::new(site, hit, action)],
        }
    }

    /// Stable single-line rendering (`site#hit:action, …`) for reports
    /// and shrunken-counterexample artifacts.
    pub fn render(&self) -> String {
        if self.arms.is_empty() {
            return "(no faults)".to_string();
        }
        self.arms
            .iter()
            .map(|a| format!("{}#{}:{}", a.site, a.hit, a.action.name()))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// JSON form for the uploaded (design, fault-plan) counterexample.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.arms
                .iter()
                .map(|a| {
                    let mut o = JsonObj::new();
                    o.insert("site", Json::str(&a.site));
                    o.insert("hit", Json::num(a.hit as f64));
                    o.insert("action", Json::str(a.action.name()));
                    Json::Obj(o)
                })
                .collect(),
        )
    }
}

/// Generator for [`FaultPlan`]s: 1–3 arms over [`SITES`], hits in 1–3,
/// any action. Shrinks by dropping arms, pulling hits toward 1, and
/// weakening actions toward [`FaultAction::Error`] — so a minimized
/// counterexample is the smallest, tamest plan that still violates.
#[derive(Debug, Clone, Default)]
pub struct FaultGen;

impl Gen for FaultGen {
    type Item = FaultPlan;

    fn generate(&self, rng: &mut Rng) -> FaultPlan {
        let n = rng.range(1, 3);
        let arms = (0..n)
            .map(|_| FaultArm {
                site: rng.pick(SITES).to_string(),
                hit: rng.range(1, 3) as u64,
                action: *rng.pick(&FaultAction::ALL),
            })
            .collect();
        FaultPlan { arms }
    }

    fn shrink(&self, plan: &FaultPlan) -> Vec<FaultPlan> {
        let mut out = Vec::new();
        for i in 0..plan.arms.len() {
            let mut p = plan.clone();
            p.arms.remove(i);
            out.push(p);
        }
        for (i, arm) in plan.arms.iter().enumerate() {
            if arm.hit > 1 {
                let mut p = plan.clone();
                p.arms[i].hit = 1;
                out.push(p);
            }
            if arm.action != FaultAction::Error {
                let mut p = plan.clone();
                p.arms[i].action = FaultAction::Error;
                out.push(p);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// The process-global armed state.
// ---------------------------------------------------------------------

struct ArmState {
    site: String,
    hit: u64,
    action: FaultAction,
    fired: bool,
}

#[derive(Default)]
struct ActiveFaults {
    arms: Vec<ArmState>,
    counters: BTreeMap<String, u64>,
    fired_log: Vec<String>,
}

/// Count of not-yet-fired arms. `0` is the disarmed fast path: the only
/// cost a production run ever pays at a fault site.
static ARMED: AtomicU64 = AtomicU64::new(0);
static STATE: Mutex<Option<ActiveFaults>> = Mutex::new(None);
/// Serializes scenarios: the plane is process-global, so only one armed
/// plan may exist at a time.
static SCENARIO: Mutex<()> = Mutex::new(());

fn lock_state() -> MutexGuard<'static, Option<ActiveFaults>> {
    // A panic *is* a supported injection, so the state lock recovers
    // from poisoning instead of propagating it.
    STATE.lock().unwrap_or_else(|p| p.into_inner())
}

/// Disarms the plane (and releases the scenario lock) on drop.
pub struct FaultGuard {
    _scenario: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        ARMED.store(0, Ordering::SeqCst);
        *lock_state() = None;
    }
}

/// Arm `plan` for the duration of the returned guard. Blocks until any
/// previously armed scenario disarms; resets all hit counters.
pub fn arm(plan: &FaultPlan) -> FaultGuard {
    let scenario = SCENARIO.lock().unwrap_or_else(|p| p.into_inner());
    *lock_state() = Some(ActiveFaults {
        arms: plan
            .arms
            .iter()
            .map(|a| ArmState {
                site: a.site.clone(),
                hit: a.hit.max(1),
                action: a.action,
                fired: false,
            })
            .collect(),
        counters: BTreeMap::new(),
        fired_log: Vec::new(),
    });
    ARMED.store(plan.arms.len() as u64, Ordering::SeqCst);
    FaultGuard { _scenario: scenario }
}

/// `true` while any arm is live — exactly one relaxed atomic load.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed) != 0
}

/// A fault site. Returns the injected action when an arm fires here,
/// `None` otherwise. Disarmed cost: one relaxed atomic load.
#[inline]
pub fn point(site: &str) -> Option<FaultAction> {
    if !armed() {
        return None;
    }
    fire(site)
}

#[cold]
fn fire(site: &str) -> Option<FaultAction> {
    let mut g = lock_state();
    let st = g.as_mut()?;
    let c = st.counters.entry(site.to_string()).or_insert(0);
    *c += 1;
    let n = *c;
    for arm in st.arms.iter_mut() {
        if !arm.fired && arm.site == site && arm.hit == n {
            arm.fired = true;
            let action = arm.action;
            st.fired_log.push(format!("{site}#{n}:{}", action.name()));
            ARMED.fetch_sub(1, Ordering::SeqCst);
            return Some(action);
        }
    }
    None
}

/// Which arms have fired so far in the active scenario (empty when
/// disarmed). Diagnostics for tests and shrunken reports.
pub fn fired_log() -> Vec<String> {
    lock_state()
        .as_ref()
        .map(|s| s.fired_log.clone())
        .unwrap_or_default()
}

/// Sleep the standard injected delay.
pub fn injected_sleep() {
    std::thread::sleep(Duration::from_millis(INJECTED_DELAY_MS));
}

/// The canonical message for an injected typed error at `site`
/// (deterministic, so shrunken counterexamples replay byte-for-byte).
pub fn injected_msg(site: &str) -> String {
    format!("injected fault at {site}")
}

/// Fire `site` as an IO boundary. `Ok(true)` asks the caller to
/// truncate the current read/write to one byte; `Error`/`BitFlip`
/// surface as an `io::Error`; `Panic` panics; `Delay` sleeps first.
pub fn fire_io(site: &str) -> std::io::Result<bool> {
    match point(site) {
        None => Ok(false),
        Some(FaultAction::ShortIo) => Ok(true),
        Some(FaultAction::Delay) => {
            injected_sleep();
            Ok(false)
        }
        Some(FaultAction::Panic) => panic!("injected panic at {site}"),
        Some(FaultAction::Error) | Some(FaultAction::BitFlip) => Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            injected_msg(site),
        )),
    }
}

/// Fire `site` as a job/queue boundary: `Some(message)` means the caller
/// must raise its typed error; `Panic` panics (the daemon's per-job or
/// per-connection `catch_unwind` absorbs it); `Delay` sleeps and
/// proceeds; `ShortIo`/`BitFlip` degrade to the typed error.
pub fn fire_job(site: &str) -> Option<String> {
    match point(site) {
        None => None,
        Some(FaultAction::Delay) => {
            injected_sleep();
            None
        }
        Some(FaultAction::Panic) => panic!("injected panic at {site}"),
        Some(_) => Some(injected_msg(site)),
    }
}

/// Fire the flow-stage site for `stage` (`flow.stage.<stage>`). Same
/// semantics as [`fire_job`]. The site string is only materialized when
/// the plane is armed, keeping the disarmed checkpoint at one load.
pub fn fire_stage(stage: &str) -> Option<String> {
    if !armed() {
        return None;
    }
    fire_job(&format!("flow.stage.{stage}"))
}

/// Should a cache/memo insertion corrupt its integrity digest?
/// (`BitFlip` → yes; `Error` → the caller skips the insert entirely;
/// `Delay` sleeps; `Panic` panics.)
pub enum CacheFault {
    None,
    Corrupt,
    Skip,
}

/// Fire `site` as a cache boundary.
pub fn fire_cache(site: &str) -> CacheFault {
    match point(site) {
        None => CacheFault::None,
        Some(FaultAction::BitFlip) | Some(FaultAction::ShortIo) => CacheFault::Corrupt,
        Some(FaultAction::Error) => CacheFault::Skip,
        Some(FaultAction::Delay) => {
            injected_sleep();
            CacheFault::None
        }
        Some(FaultAction::Panic) => panic!("injected panic at {site}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests only arm reserved `test.*` sites: the plane is
    // process-global and these tests share the process with the rest of
    // the lib suite (including live daemons), so arming a production
    // site here would inject into innocent tests. `tests/faults.rs` is
    // the dedicated process for that.

    #[test]
    fn disarmed_points_return_none() {
        assert_eq!(point("test.unit.disarmed"), None);
        assert!(!armed());
    }

    #[test]
    fn arms_fire_on_exact_hit_exactly_once() {
        let plan = FaultPlan {
            arms: vec![
                FaultArm::new("test.unit.a", 2, FaultAction::Error),
                FaultArm::new("test.unit.b", 1, FaultAction::Delay),
            ],
        };
        let _g = arm(&plan);
        assert_eq!(point("test.unit.a"), None); // hit 1
        assert_eq!(point("test.unit.b"), Some(FaultAction::Delay));
        assert_eq!(point("test.unit.a"), Some(FaultAction::Error)); // hit 2
        assert_eq!(point("test.unit.a"), None); // fired arms stay quiet
        assert_eq!(point("test.unit.b"), None);
        assert_eq!(
            fired_log(),
            vec!["test.unit.b#1:delay", "test.unit.a#2:error"]
        );
        // Both arms fired: back to the single-load fast path.
        assert!(!armed());
    }

    #[test]
    fn guard_drop_disarms() {
        {
            let _g = arm(&FaultPlan::one("test.unit.c", 1, FaultAction::Panic));
            assert!(armed());
        }
        assert!(!armed());
        assert_eq!(point("test.unit.c"), None);
        assert!(fired_log().is_empty());
    }

    #[test]
    fn counters_reset_per_scenario() {
        let plan = FaultPlan::one("test.unit.d", 1, FaultAction::Error);
        {
            let _g = arm(&plan);
            assert_eq!(point("test.unit.d"), Some(FaultAction::Error));
        }
        {
            let _g = arm(&plan);
            // Fresh counters: hit 1 fires again in the new scenario.
            assert_eq!(point("test.unit.d"), Some(FaultAction::Error));
        }
    }

    #[test]
    fn generation_is_seeded_and_shrink_is_sound() {
        let g = FaultGen;
        let sample = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..10).map(|_| g.generate(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(sample(7), sample(7));
        assert_ne!(sample(7), sample(8));
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let plan = g.generate(&mut rng);
            assert!((1..=3).contains(&plan.arms.len()));
            for cand in g.shrink(&plan) {
                assert!(cand.arms.len() <= plan.arms.len());
                assert_ne!(cand, plan, "shrink must make progress");
            }
            // Shrinking terminates at the empty plan.
            assert!(g.shrink(&FaultPlan::default()).is_empty());
        }
    }

    #[test]
    fn render_and_json_are_stable() {
        let plan = FaultPlan {
            arms: vec![
                FaultArm::new("server.io.read", 2, FaultAction::ShortIo),
                FaultArm::new("pool.job", 1, FaultAction::Panic),
            ],
        };
        assert_eq!(
            plan.render(),
            "server.io.read#2:short-io, pool.job#1:panic"
        );
        assert_eq!(
            plan.to_json().dump(),
            r#"[{"site":"server.io.read","hit":2,"action":"short-io"},{"site":"pool.job","hit":1,"action":"panic"}]"#
        );
        assert_eq!(FaultPlan::default().render(), "(no faults)");
    }

    #[test]
    fn fire_io_maps_actions() {
        let plan = FaultPlan {
            arms: vec![
                FaultArm::new("test.unit.io", 1, FaultAction::ShortIo),
                FaultArm::new("test.unit.io", 2, FaultAction::Error),
            ],
        };
        let _g = arm(&plan);
        assert!(fire_io("test.unit.io").unwrap()); // short
        let err = fire_io("test.unit.io").unwrap_err();
        assert_eq!(err.to_string(), "injected fault at test.unit.io");
        assert!(!fire_io("test.unit.io").unwrap()); // exhausted
    }
}

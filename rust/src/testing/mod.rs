//! Test infrastructure shipped with the crate: the differential oracle
//! suite ([`oracle`]) and the seeded fuzz driver ([`fuzz`]) that replays
//! and shrinks counterexamples.
//!
//! This lives in `src/` (not `tests/`) deliberately: the `rsir fuzz` CLI,
//! the tier-1 integration tests and the scheduled CI job all share one
//! implementation, so a counterexample found anywhere replays everywhere.

pub mod fuzz;
pub mod oracle;

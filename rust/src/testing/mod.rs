//! Test infrastructure shipped with the crate: the differential oracle
//! suite ([`oracle`]), the seeded fuzz driver ([`fuzz`]) that replays
//! and shrinks counterexamples, and the deterministic fault-injection
//! plane ([`faults`]) the robustness lanes arm against production sites.
//!
//! This lives in `src/` (not `tests/`) deliberately: the `rsir fuzz` CLI,
//! the tier-1 integration tests and the scheduled CI job all share one
//! implementation, so a counterexample found anywhere replays everywhere.
//! (`faults` in particular *must* live in the crate: its sites are
//! compiled into server/flow hot paths, costing one relaxed atomic load
//! when disarmed.)

pub mod faults;
pub mod fuzz;
pub mod oracle;

use crate::device::model::VirtualDevice;
use crate::ir::builder::*;
use crate::ir::core::*;

/// A handshake chain of `n` stages, each consuming `frac` of one slot's
/// LUT/FF capacity on `dev`. With `n * frac` well above the device's
/// slot count the design cannot fit at any utilization limit — the ILP
/// stays infeasible even at its 0.90 relaxation ceiling — which is what
/// the sweep/DSE tests use to exercise the typed-[`Infeasible`]
/// (unroutable-row) path deterministically.
///
/// [`Infeasible`]: crate::floorplan::Infeasible
pub fn oversized_chain(dev: &VirtualDevice, n: usize, frac: f64) -> Design {
    let cap = dev.slots[dev.num_slots() - 1].capacity.lut;
    let mut d = Design::new("Top");
    let mut top = GroupedBuilder::new("Top")
        .port("ap_clk", Dir::In, 1)
        .port("ap_rst_n", Dir::In, 1)
        .iface(Interface::Clock {
            port: "ap_clk".into(),
        })
        .iface(Interface::Reset {
            port: "ap_rst_n".into(),
            active_high: false,
        });
    for i in 0..n {
        let m = LeafBuilder::verilog_stub(format!("Stage{i}"))
            .clk_rst()
            .handshake("i", Dir::In, 64)
            .handshake("o", Dir::Out, 64)
            .resource(Resources::new(cap * frac, cap * frac, 20.0, 100.0, 4.0))
            .build();
        d.add(m);
    }
    for i in 0..n.saturating_sub(1) {
        top = top
            .wire(&format!("w{i}"), 64)
            .wire(&format!("w{i}_vld"), 1)
            .wire(&format!("w{i}_rdy"), 1);
    }
    for i in 0..n {
        let mut inst = Instance::new(format!("s{i}"), format!("Stage{i}"));
        inst.connect("ap_clk", ConnExpr::id("ap_clk"));
        inst.connect("ap_rst_n", ConnExpr::id("ap_rst_n"));
        if i > 0 {
            inst.connect("i", ConnExpr::id(&format!("w{}", i - 1)));
            inst.connect("i_vld", ConnExpr::id(&format!("w{}_vld", i - 1)));
            inst.connect("i_rdy", ConnExpr::id(&format!("w{}_rdy", i - 1)));
        }
        if i + 1 < n {
            inst.connect("o", ConnExpr::id(&format!("w{i}")));
            inst.connect("o_vld", ConnExpr::id(&format!("w{i}_vld")));
            inst.connect("o_rdy", ConnExpr::id(&format!("w{i}_rdy")));
        }
        top = top.inst_full(inst);
    }
    d.add(top.build());
    d
}

//! Seeded fuzz driver over [`designs::synthetic`](crate::designs::synthetic):
//! generate `cases` plans from `seed`, run every materialized design
//! through the full [`oracle`](crate::testing::oracle) suite, and on the
//! first failure greedily shrink the plan to a minimal counterexample
//! (via [`quickcheck::minimize`](crate::util::quickcheck::minimize)).
//!
//! Shared by `tests/fuzz_pipeline.rs` and the `rsir fuzz --seed N
//! --cases M` CLI, so a CI failure is replayed locally with the exact
//! same command line.

use crate::designs::synthetic::{
    digest, materialize, materialize_sources, DesignGen, DesignPlan, MaterializedSources,
    SyntheticConfig,
};
use crate::ir::schema::design_to_json;
use crate::testing::oracle;
use crate::util::quickcheck::{minimize, Gen};
use crate::util::rng::Rng;

/// A minimized oracle failure.
#[derive(Debug, Clone)]
pub struct FuzzFailure {
    /// 0-based case index within the run (replay: same seed, same case).
    pub case: usize,
    /// Invariants violated by the original (unshrunk) design.
    pub violations: Vec<&'static str>,
    /// The shrunken plan (the replayable, human-readable form).
    pub minimal_plan: DesignPlan,
    /// Invariants violated by the minimal design.
    pub minimal_violations: Vec<&'static str>,
    /// Pretty IR JSON of the minimal design (the CI artifact).
    pub minimal_json: String,
}

/// Outcome of one fuzz run.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    pub seed: u64,
    pub cases: usize,
    pub failure: Option<FuzzFailure>,
}

/// Run `cases` generated designs through the oracle suite. Stops at (and
/// minimizes) the first failure; returns a structured report instead of
/// panicking, so the CLI can write artifacts.
pub fn run(seed: u64, cases: usize, cfg: &SyntheticConfig) -> FuzzReport {
    let gen = DesignGen { cfg: cfg.clone() };
    let mut rng = Rng::new(seed);
    let prop = |p: &DesignPlan| oracle::check_pipeline(&materialize(p)).is_clean();
    for case in 0..cases {
        let plan = gen.generate(&mut rng);
        // One oracle run per clean case; its outcome is reused on the
        // failure path instead of re-running the whole suite.
        let outcome = oracle::check_pipeline(&materialize(&plan));
        if outcome.is_clean() {
            continue;
        }
        let violations = outcome.violated();
        let minimal_plan = minimize(&gen, plan, &prop);
        let minimal = materialize(&minimal_plan);
        let minimal_violations = oracle::check_pipeline(&minimal).violated();
        return FuzzReport {
            seed,
            cases,
            failure: Some(FuzzFailure {
                case,
                violations,
                minimal_plan,
                minimal_violations,
                minimal_json: design_to_json(&minimal).pretty(),
            }),
        };
    }
    FuzzReport {
        seed,
        cases,
        failure: None,
    }
}

/// Fuzz the incremental re-flow engine (`rsir fuzz --reflow`): run
/// `cases` generated designs through
/// [`oracle::check_incremental_reflow`] — flow through a shared
/// [`StageMemo`](crate::coordinator::memo::StageMemo) cold, after a leaf
/// edit, and after pollution, each compared bit-for-bit against a
/// from-scratch run. Same report shape as [`run`], so the CLI and CI
/// artifacts are shared.
pub fn run_reflow(seed: u64, cases: usize, cfg: &SyntheticConfig) -> FuzzReport {
    let gen = DesignGen { cfg: cfg.clone() };
    let mut rng = Rng::new(seed);
    let prop = |p: &DesignPlan| oracle::check_incremental_reflow(&materialize(p)).is_clean();
    for case in 0..cases {
        let plan = gen.generate(&mut rng);
        let outcome = oracle::check_incremental_reflow(&materialize(&plan));
        if outcome.is_clean() {
            continue;
        }
        let violations = outcome.violated();
        let minimal_plan = minimize(&gen, plan, &prop);
        let minimal = materialize(&minimal_plan);
        let minimal_violations = oracle::check_incremental_reflow(&minimal).violated();
        return FuzzReport {
            seed,
            cases,
            failure: Some(FuzzFailure {
                case,
                violations,
                minimal_plan,
                minimal_violations,
                minimal_json: design_to_json(&minimal).pretty(),
            }),
        };
    }
    FuzzReport {
        seed,
        cases,
        failure: None,
    }
}

/// A minimized Verilog round-trip failure (`rsir fuzz --verilog`).
#[derive(Debug, Clone)]
pub struct VerilogFuzzFailure {
    /// 0-based case index within the run (replay: same seed, same case).
    pub case: usize,
    /// Invariants violated by the original (unshrunk) plan.
    pub violations: Vec<&'static str>,
    /// The shrunken plan.
    pub minimal_plan: DesignPlan,
    /// Invariants violated by the minimal plan.
    pub minimal_violations: Vec<&'static str>,
    /// The shrunken *Verilog source set* rendered as one `.v` text — the
    /// CI artifact a human replays the failure from.
    pub minimal_source: String,
}

/// Outcome of one Verilog round-trip fuzz run.
#[derive(Debug, Clone)]
pub struct VerilogFuzzReport {
    pub seed: u64,
    pub cases: usize,
    pub failure: Option<VerilogFuzzFailure>,
}

/// Run `cases` generated plans through the Verilog round-trip oracle
/// ([`oracle::check_verilog_roundtrip`]): materialized source text →
/// import → pipeline → export → re-import. Stops at (and minimizes) the
/// first failure, emitting the *source text* of the minimal plan.
pub fn run_verilog(seed: u64, cases: usize, cfg: &SyntheticConfig) -> VerilogFuzzReport {
    let gen = DesignGen { cfg: cfg.clone() };
    let mut rng = Rng::new(seed);
    let prop = |p: &DesignPlan| oracle::check_verilog_roundtrip(p).is_clean();
    for case in 0..cases {
        let plan = gen.generate(&mut rng);
        let outcome = oracle::check_verilog_roundtrip(&plan);
        if outcome.is_clean() {
            continue;
        }
        let violations = outcome.violated();
        let minimal_plan = minimize(&gen, plan, &prop);
        let minimal_violations = oracle::check_verilog_roundtrip(&minimal_plan).violated();
        let minimal_source = render_sources(&materialize_sources(&minimal_plan));
        return VerilogFuzzReport {
            seed,
            cases,
            failure: Some(VerilogFuzzFailure {
                case,
                violations,
                minimal_plan,
                minimal_violations,
                minimal_source,
            }),
        };
    }
    VerilogFuzzReport {
        seed,
        cases,
        failure: None,
    }
}

/// Render a materialized source set as one Verilog-compatible text:
/// the Verilog sources concatenated, with any `.xci`/`.xo` manifests
/// appended inside block comments (so the artifact stays a valid `.v`
/// file while remaining a complete reproduction of the input set).
pub fn render_sources(srcs: &MaterializedSources) -> String {
    let mut s = format!("// verilog round-trip counterexample; top={}\n", srcs.top);
    for v in &srcs.verilog {
        s.push_str(v);
        if !v.ends_with('\n') {
            s.push('\n');
        }
        s.push('\n');
    }
    for (label, manifests) in [("xci", &srcs.xci), ("xo", &srcs.xo)] {
        for man in manifests {
            s.push_str(&format!("/* {label} manifest:\n{man}\n*/\n"));
        }
    }
    s
}

/// Digest of the first design generated from each seed — the values the
/// seed-stability test pins, and what `rsir fuzz --digests` prints.
pub fn seed_digests(seeds: std::ops::Range<u64>, cfg: &SyntheticConfig) -> Vec<(u64, u64)> {
    let gen = DesignGen { cfg: cfg.clone() };
    seeds
        .map(|seed| {
            let mut rng = Rng::new(seed);
            (seed, digest(&materialize(&gen.generate(&mut rng))))
        })
        .collect()
}

/// Outcome of one daemon-equivalence fuzz run (`rsir fuzz --daemon`).
#[derive(Debug, Clone)]
pub struct DaemonFuzzReport {
    pub seed: u64,
    pub cases: usize,
    /// Rendered oracle violations from the failing batch (empty = clean).
    pub violations: Vec<String>,
    /// Pretty IR JSON of a minimized single-design counterexample, when
    /// the failure reproduces on one design alone. Batch-only failures
    /// (concurrency/cancellation races) report violations without one.
    pub minimal_json: Option<String>,
}

impl DaemonFuzzReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Fuzz the daemon's determinism contract: generate `cases` plans from
/// `seed`, materialize them, and run the whole batch through
/// [`oracle::check_daemon_equivalence`] (one daemon, two concurrent
/// connections, warm-cache resubmits, mid-flight cancellation). On
/// failure, attribute it to the first plan that also fails as a
/// single-design batch and shrink that plan; failures that only
/// reproduce with the full batch are reported unminimized.
pub fn run_daemon(seed: u64, cases: usize, cfg: &SyntheticConfig) -> DaemonFuzzReport {
    let gen = DesignGen { cfg: cfg.clone() };
    let mut rng = Rng::new(seed);
    let plans: Vec<DesignPlan> = (0..cases).map(|_| gen.generate(&mut rng)).collect();
    let designs: Vec<_> = plans.iter().map(materialize).collect();
    let outcome = oracle::check_daemon_equivalence(&designs);
    if outcome.is_clean() {
        return DaemonFuzzReport {
            seed,
            cases,
            violations: Vec::new(),
            minimal_json: None,
        };
    }
    let violations: Vec<String> = outcome.violations.iter().map(|v| v.to_string()).collect();
    let prop = |p: &DesignPlan| oracle::check_daemon_equivalence(&[materialize(p)]).is_clean();
    for plan in plans {
        if !prop(&plan) {
            let minimal_plan = minimize(&gen, plan, &prop);
            return DaemonFuzzReport {
                seed,
                cases,
                violations,
                minimal_json: Some(design_to_json(&materialize(&minimal_plan)).pretty()),
            };
        }
    }
    DaemonFuzzReport {
        seed,
        cases,
        violations,
        minimal_json: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_run_reports_no_failure() {
        let rep = run(11, 4, &SyntheticConfig::default());
        assert_eq!(rep.cases, 4);
        assert!(rep.failure.is_none(), "{:?}", rep.failure);
    }

    #[test]
    fn clean_reflow_run_reports_no_failure() {
        let rep = run_reflow(11, 2, &SyntheticConfig::default());
        assert_eq!(rep.cases, 2);
        assert!(rep.failure.is_none(), "{:?}", rep.failure);
    }

    #[test]
    fn clean_verilog_run_reports_no_failure() {
        let rep = run_verilog(11, 3, &SyntheticConfig::default());
        assert_eq!(rep.cases, 3);
        assert!(rep.failure.is_none(), "{:?}", rep.failure);
    }

    #[test]
    fn rendered_sources_parse_as_verilog() {
        let gen = DesignGen {
            cfg: SyntheticConfig::default(),
        };
        let mut rng = Rng::new(5);
        let srcs = materialize_sources(&gen.generate(&mut rng));
        let text = render_sources(&srcs);
        // The artifact is a well-formed .v file containing every
        // Verilog-path module of the plan.
        let f = crate::verilog::parser::parse_file(&text).unwrap();
        assert_eq!(f.modules.len(), srcs.verilog.len());
    }

    #[test]
    fn seed_digests_are_reproducible() {
        let cfg = SyntheticConfig::default();
        assert_eq!(seed_digests(0..5, &cfg), seed_digests(0..5, &cfg));
    }
}

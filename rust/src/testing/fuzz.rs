//! Seeded fuzz driver over [`designs::synthetic`](crate::designs::synthetic):
//! generate `cases` plans from `seed`, run every materialized design
//! through the full [`oracle`](crate::testing::oracle) suite, and on the
//! first failure greedily shrink the plan to a minimal counterexample
//! (via [`quickcheck::minimize`](crate::util::quickcheck::minimize)).
//!
//! Shared by `tests/fuzz_pipeline.rs` and the `rsir fuzz --seed N
//! --cases M` CLI, so a CI failure is replayed locally with the exact
//! same command line.

use crate::designs::synthetic::{digest, materialize, DesignGen, DesignPlan, SyntheticConfig};
use crate::ir::schema::design_to_json;
use crate::testing::oracle;
use crate::util::quickcheck::{minimize, Gen};
use crate::util::rng::Rng;

/// A minimized oracle failure.
#[derive(Debug, Clone)]
pub struct FuzzFailure {
    /// 0-based case index within the run (replay: same seed, same case).
    pub case: usize,
    /// Invariants violated by the original (unshrunk) design.
    pub violations: Vec<&'static str>,
    /// The shrunken plan (the replayable, human-readable form).
    pub minimal_plan: DesignPlan,
    /// Invariants violated by the minimal design.
    pub minimal_violations: Vec<&'static str>,
    /// Pretty IR JSON of the minimal design (the CI artifact).
    pub minimal_json: String,
}

/// Outcome of one fuzz run.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    pub seed: u64,
    pub cases: usize,
    pub failure: Option<FuzzFailure>,
}

/// Run `cases` generated designs through the oracle suite. Stops at (and
/// minimizes) the first failure; returns a structured report instead of
/// panicking, so the CLI can write artifacts.
pub fn run(seed: u64, cases: usize, cfg: &SyntheticConfig) -> FuzzReport {
    let gen = DesignGen { cfg: cfg.clone() };
    let mut rng = Rng::new(seed);
    let prop = |p: &DesignPlan| oracle::check_pipeline(&materialize(p)).is_clean();
    for case in 0..cases {
        let plan = gen.generate(&mut rng);
        // One oracle run per clean case; its outcome is reused on the
        // failure path instead of re-running the whole suite.
        let outcome = oracle::check_pipeline(&materialize(&plan));
        if outcome.is_clean() {
            continue;
        }
        let violations = outcome.violated();
        let minimal_plan = minimize(&gen, plan, &prop);
        let minimal = materialize(&minimal_plan);
        let minimal_violations = oracle::check_pipeline(&minimal).violated();
        return FuzzReport {
            seed,
            cases,
            failure: Some(FuzzFailure {
                case,
                violations,
                minimal_plan,
                minimal_violations,
                minimal_json: design_to_json(&minimal).pretty(),
            }),
        };
    }
    FuzzReport {
        seed,
        cases,
        failure: None,
    }
}

/// Digest of the first design generated from each seed — the values the
/// seed-stability test pins, and what `rsir fuzz --digests` prints.
pub fn seed_digests(seeds: std::ops::Range<u64>, cfg: &SyntheticConfig) -> Vec<(u64, u64)> {
    let gen = DesignGen { cfg: cfg.clone() };
    seeds
        .map(|seed| {
            let mut rng = Rng::new(seed);
            (seed, digest(&materialize(&gen.generate(&mut rng))))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_run_reports_no_failure() {
        let rep = run(11, 4, &SyntheticConfig::default());
        assert_eq!(rep.cases, 4);
        assert!(rep.failure.is_none(), "{:?}", rep.failure);
    }

    #[test]
    fn seed_digests_are_reproducible() {
        let cfg = SyntheticConfig::default();
        assert_eq!(seed_digests(0..5, &cfg), seed_digests(0..5, &cfg));
    }
}

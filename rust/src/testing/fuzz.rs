//! Seeded fuzz driver over [`designs::synthetic`](crate::designs::synthetic):
//! generate `cases` plans from `seed`, run every materialized design
//! through the full [`oracle`](crate::testing::oracle) suite, and on the
//! first failure greedily shrink the plan to a minimal counterexample
//! (via [`quickcheck::minimize`](crate::util::quickcheck::minimize)).
//!
//! Shared by `tests/fuzz_pipeline.rs` and the `rsir fuzz --seed N
//! --cases M` CLI, so a CI failure is replayed locally with the exact
//! same command line.

use crate::designs::synthetic::{
    digest, materialize, materialize_sources, DesignGen, DesignPlan, MaterializedSources,
    SyntheticConfig,
};
use crate::ir::schema::design_to_json;
use crate::testing::faults::{FaultAction, FaultGen, FaultPlan};
use crate::testing::oracle;
use crate::util::json::{Json, JsonObj};
use crate::util::quickcheck::{minimize, Gen};
use crate::util::rng::Rng;
use std::collections::BTreeSet;

/// A minimized oracle failure.
#[derive(Debug, Clone)]
pub struct FuzzFailure {
    /// 0-based case index within the run (replay: same seed, same case).
    pub case: usize,
    /// Invariants violated by the original (unshrunk) design.
    pub violations: Vec<&'static str>,
    /// The shrunken plan (the replayable, human-readable form).
    pub minimal_plan: DesignPlan,
    /// Invariants violated by the minimal design.
    pub minimal_violations: Vec<&'static str>,
    /// Pretty IR JSON of the minimal design (the CI artifact).
    pub minimal_json: String,
}

/// Outcome of one fuzz run.
#[derive(Debug, Clone)]
pub struct FuzzReport {
    pub seed: u64,
    pub cases: usize,
    pub failure: Option<FuzzFailure>,
}

/// Run `cases` generated designs through the oracle suite. Stops at (and
/// minimizes) the first failure; returns a structured report instead of
/// panicking, so the CLI can write artifacts.
pub fn run(seed: u64, cases: usize, cfg: &SyntheticConfig) -> FuzzReport {
    let gen = DesignGen { cfg: cfg.clone() };
    let mut rng = Rng::new(seed);
    let prop = |p: &DesignPlan| oracle::check_pipeline(&materialize(p)).is_clean();
    for case in 0..cases {
        let plan = gen.generate(&mut rng);
        // One oracle run per clean case; its outcome is reused on the
        // failure path instead of re-running the whole suite.
        let outcome = oracle::check_pipeline(&materialize(&plan));
        if outcome.is_clean() {
            continue;
        }
        let violations = outcome.violated();
        let minimal_plan = minimize(&gen, plan, &prop);
        let minimal = materialize(&minimal_plan);
        let minimal_violations = oracle::check_pipeline(&minimal).violated();
        return FuzzReport {
            seed,
            cases,
            failure: Some(FuzzFailure {
                case,
                violations,
                minimal_plan,
                minimal_violations,
                minimal_json: design_to_json(&minimal).pretty(),
            }),
        };
    }
    FuzzReport {
        seed,
        cases,
        failure: None,
    }
}

/// Fuzz the incremental re-flow engine (`rsir fuzz --reflow`): run
/// `cases` generated designs through
/// [`oracle::check_incremental_reflow`] — flow through a shared
/// [`StageMemo`](crate::coordinator::memo::StageMemo) cold, after a leaf
/// edit, and after pollution, each compared bit-for-bit against a
/// from-scratch run. Same report shape as [`run`], so the CLI and CI
/// artifacts are shared.
pub fn run_reflow(seed: u64, cases: usize, cfg: &SyntheticConfig) -> FuzzReport {
    let gen = DesignGen { cfg: cfg.clone() };
    let mut rng = Rng::new(seed);
    let prop = |p: &DesignPlan| oracle::check_incremental_reflow(&materialize(p)).is_clean();
    for case in 0..cases {
        let plan = gen.generate(&mut rng);
        let outcome = oracle::check_incremental_reflow(&materialize(&plan));
        if outcome.is_clean() {
            continue;
        }
        let violations = outcome.violated();
        let minimal_plan = minimize(&gen, plan, &prop);
        let minimal = materialize(&minimal_plan);
        let minimal_violations = oracle::check_incremental_reflow(&minimal).violated();
        return FuzzReport {
            seed,
            cases,
            failure: Some(FuzzFailure {
                case,
                violations,
                minimal_plan,
                minimal_violations,
                minimal_json: design_to_json(&minimal).pretty(),
            }),
        };
    }
    FuzzReport {
        seed,
        cases,
        failure: None,
    }
}

/// A minimized Verilog round-trip failure (`rsir fuzz --verilog`).
#[derive(Debug, Clone)]
pub struct VerilogFuzzFailure {
    /// 0-based case index within the run (replay: same seed, same case).
    pub case: usize,
    /// Invariants violated by the original (unshrunk) plan.
    pub violations: Vec<&'static str>,
    /// The shrunken plan.
    pub minimal_plan: DesignPlan,
    /// Invariants violated by the minimal plan.
    pub minimal_violations: Vec<&'static str>,
    /// The shrunken *Verilog source set* rendered as one `.v` text — the
    /// CI artifact a human replays the failure from.
    pub minimal_source: String,
}

/// Outcome of one Verilog round-trip fuzz run.
#[derive(Debug, Clone)]
pub struct VerilogFuzzReport {
    pub seed: u64,
    pub cases: usize,
    pub failure: Option<VerilogFuzzFailure>,
}

/// Run `cases` generated plans through the Verilog round-trip oracle
/// ([`oracle::check_verilog_roundtrip`]): materialized source text →
/// import → pipeline → export → re-import. Stops at (and minimizes) the
/// first failure, emitting the *source text* of the minimal plan.
pub fn run_verilog(seed: u64, cases: usize, cfg: &SyntheticConfig) -> VerilogFuzzReport {
    let gen = DesignGen { cfg: cfg.clone() };
    let mut rng = Rng::new(seed);
    let prop = |p: &DesignPlan| oracle::check_verilog_roundtrip(p).is_clean();
    for case in 0..cases {
        let plan = gen.generate(&mut rng);
        let outcome = oracle::check_verilog_roundtrip(&plan);
        if outcome.is_clean() {
            continue;
        }
        let violations = outcome.violated();
        let minimal_plan = minimize(&gen, plan, &prop);
        let minimal_violations = oracle::check_verilog_roundtrip(&minimal_plan).violated();
        let minimal_source = render_sources(&materialize_sources(&minimal_plan));
        return VerilogFuzzReport {
            seed,
            cases,
            failure: Some(VerilogFuzzFailure {
                case,
                violations,
                minimal_plan,
                minimal_violations,
                minimal_source,
            }),
        };
    }
    VerilogFuzzReport {
        seed,
        cases,
        failure: None,
    }
}

/// Render a materialized source set as one Verilog-compatible text:
/// the Verilog sources concatenated, with any `.xci`/`.xo` manifests
/// appended inside block comments (so the artifact stays a valid `.v`
/// file while remaining a complete reproduction of the input set).
pub fn render_sources(srcs: &MaterializedSources) -> String {
    let mut s = format!("// verilog round-trip counterexample; top={}\n", srcs.top);
    for v in &srcs.verilog {
        s.push_str(v);
        if !v.ends_with('\n') {
            s.push('\n');
        }
        s.push('\n');
    }
    for (label, manifests) in [("xci", &srcs.xci), ("xo", &srcs.xo)] {
        for man in manifests {
            s.push_str(&format!("/* {label} manifest:\n{man}\n*/\n"));
        }
    }
    s
}

/// Digest of the first design generated from each seed — the values the
/// seed-stability test pins, and what `rsir fuzz --digests` prints.
pub fn seed_digests(seeds: std::ops::Range<u64>, cfg: &SyntheticConfig) -> Vec<(u64, u64)> {
    let gen = DesignGen { cfg: cfg.clone() };
    seeds
        .map(|seed| {
            let mut rng = Rng::new(seed);
            (seed, digest(&materialize(&gen.generate(&mut rng))))
        })
        .collect()
}

/// Outcome of one daemon-equivalence fuzz run (`rsir fuzz --daemon`).
#[derive(Debug, Clone)]
pub struct DaemonFuzzReport {
    pub seed: u64,
    pub cases: usize,
    /// Rendered oracle violations from the failing batch (empty = clean).
    pub violations: Vec<String>,
    /// Pretty IR JSON of a minimized single-design counterexample, when
    /// the failure reproduces on one design alone. Batch-only failures
    /// (concurrency/cancellation races) report violations without one.
    pub minimal_json: Option<String>,
}

impl DaemonFuzzReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Fuzz the daemon's determinism contract: generate `cases` plans from
/// `seed`, materialize them, and run the whole batch through
/// [`oracle::check_daemon_equivalence`] (one daemon, two concurrent
/// connections, warm-cache resubmits, mid-flight cancellation). On
/// failure, attribute it to the first plan that also fails as a
/// single-design batch and shrink that plan; failures that only
/// reproduce with the full batch are reported unminimized.
pub fn run_daemon(seed: u64, cases: usize, cfg: &SyntheticConfig) -> DaemonFuzzReport {
    let gen = DesignGen { cfg: cfg.clone() };
    let mut rng = Rng::new(seed);
    let plans: Vec<DesignPlan> = (0..cases).map(|_| gen.generate(&mut rng)).collect();
    let designs: Vec<_> = plans.iter().map(materialize).collect();
    let outcome = oracle::check_daemon_equivalence(&designs);
    if outcome.is_clean() {
        return DaemonFuzzReport {
            seed,
            cases,
            violations: Vec::new(),
            minimal_json: None,
        };
    }
    let violations: Vec<String> = outcome.violations.iter().map(|v| v.to_string()).collect();
    let prop = |p: &DesignPlan| oracle::check_daemon_equivalence(&[materialize(p)]).is_clean();
    for plan in plans {
        if !prop(&plan) {
            let minimal_plan = minimize(&gen, plan, &prop);
            return DaemonFuzzReport {
                seed,
                cases,
                violations,
                minimal_json: Some(design_to_json(&materialize(&minimal_plan)).pretty()),
            };
        }
    }
    DaemonFuzzReport {
        seed,
        cases,
        violations,
        minimal_json: None,
    }
}

/// Outcome of one fault-resilience fuzz run (`rsir fuzz --faults`).
#[derive(Debug, Clone)]
pub struct FaultFuzzReport {
    pub seed: u64,
    pub cases: usize,
    /// Rendered oracle violations from the first failing case (empty =
    /// every case clean).
    pub violations: Vec<String>,
    /// Every site armed across the run — the coverage set the tier-1
    /// gate asserts spans all five fault categories.
    pub covered: BTreeSet<String>,
    /// Pretty `{"design":…, "faults":…}` JSON of the minimized
    /// (design, fault-plan) counterexample pair — the CI artifact.
    pub minimal_json: Option<String>,
    /// One-line rendering of the minimized fault plan (for logs).
    pub minimal_faults: Option<String>,
}

impl FaultFuzzReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// The deterministic coverage schedule: the first five cases arm one
/// representative site per fault category — server IO, job-queue
/// admission, a panicking pool job, stage-memo corruption, and a flow
/// stage — so even a short run exercises every hardening layer. Later
/// cases draw seeded random plans over all of
/// [`SITES`](crate::testing::faults::SITES).
fn coverage_arm(case: usize) -> Option<FaultPlan> {
    match case {
        0 => Some(FaultPlan::one("server.io.read", 1, FaultAction::Error)),
        1 => Some(FaultPlan::one("server.queue.push", 1, FaultAction::Error)),
        2 => Some(FaultPlan::one("pool.job", 1, FaultAction::Panic)),
        3 => Some(FaultPlan::one("memo.place.insert", 1, FaultAction::BitFlip)),
        4 => Some(FaultPlan::one("flow.stage.floorplan", 1, FaultAction::Error)),
        _ => None,
    }
}

/// Fuzz the daemon's fault resilience (`rsir fuzz --faults`): per case,
/// generate a (design, fault-plan) pair from an independent seed stream
/// and run [`oracle::check_fault_resilience`] — a real daemon with the
/// plan armed must answer every request with a typed error or bytes
/// identical to the fault-free one-shot lane. On failure the *pair* is
/// shrunk — fault plan first (the design held fixed), then the design
/// (the minimal faults held fixed) — and emitted as one JSON artifact.
pub fn run_faults(seed: u64, cases: usize, cfg: &SyntheticConfig) -> FaultFuzzReport {
    let dgen = DesignGen { cfg: cfg.clone() };
    let fgen = FaultGen;
    let mut covered: BTreeSet<String> = BTreeSet::new();
    for case in 0..cases {
        // Independent stream per case: a counterexample replays from
        // (seed, case) alone, without regenerating earlier cases.
        let mut rng = Rng::stream(seed, case as u64);
        let dplan = dgen.generate(&mut rng);
        let fplan = match coverage_arm(case) {
            Some(p) => p,
            None => fgen.generate(&mut rng),
        };
        for arm in &fplan.arms {
            covered.insert(arm.site.clone());
        }
        let outcome = oracle::check_fault_resilience(&[materialize(&dplan)], &fplan);
        if outcome.is_clean() {
            continue;
        }
        let violations: Vec<String> = outcome.violations.iter().map(|v| v.to_string()).collect();
        let fprop =
            |f: &FaultPlan| oracle::check_fault_resilience(&[materialize(&dplan)], f).is_clean();
        let minimal_faults = minimize(&fgen, fplan, &fprop);
        let dprop = |p: &DesignPlan| {
            oracle::check_fault_resilience(&[materialize(p)], &minimal_faults).is_clean()
        };
        let minimal_design = minimize(&dgen, dplan.clone(), &dprop);
        let mut pair = JsonObj::new();
        pair.insert("design", design_to_json(&materialize(&minimal_design)));
        pair.insert("faults", minimal_faults.to_json());
        return FaultFuzzReport {
            seed,
            cases,
            violations,
            covered,
            minimal_json: Some(Json::Obj(pair).pretty()),
            minimal_faults: Some(minimal_faults.render()),
        };
    }
    FaultFuzzReport {
        seed,
        cases,
        violations: Vec::new(),
        covered,
        minimal_json: None,
        minimal_faults: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_run_reports_no_failure() {
        let rep = run(11, 4, &SyntheticConfig::default());
        assert_eq!(rep.cases, 4);
        assert!(rep.failure.is_none(), "{:?}", rep.failure);
    }

    #[test]
    fn clean_reflow_run_reports_no_failure() {
        let rep = run_reflow(11, 2, &SyntheticConfig::default());
        assert_eq!(rep.cases, 2);
        assert!(rep.failure.is_none(), "{:?}", rep.failure);
    }

    #[test]
    fn clean_verilog_run_reports_no_failure() {
        let rep = run_verilog(11, 3, &SyntheticConfig::default());
        assert_eq!(rep.cases, 3);
        assert!(rep.failure.is_none(), "{:?}", rep.failure);
    }

    #[test]
    fn rendered_sources_parse_as_verilog() {
        let gen = DesignGen {
            cfg: SyntheticConfig::default(),
        };
        let mut rng = Rng::new(5);
        let srcs = materialize_sources(&gen.generate(&mut rng));
        let text = render_sources(&srcs);
        // The artifact is a well-formed .v file containing every
        // Verilog-path module of the plan.
        let f = crate::verilog::parser::parse_file(&text).unwrap();
        assert_eq!(f.modules.len(), srcs.verilog.len());
    }

    #[test]
    fn seed_digests_are_reproducible() {
        let cfg = SyntheticConfig::default();
        assert_eq!(seed_digests(0..5, &cfg), seed_digests(0..5, &cfg));
    }
}
